"""Perf-regression gate: compare a fresh ``BENCH_throughput.json`` against
the committed baseline.

Compares, per backend, the measured engine decode tok/s of the
decode-heavy workload (``bench == "engine_backend"`` rows, ``decode_tps``
falling back to ``tps``) AND the prefill tok/s of the prefill-heavy
workload (``bench == "engine_prefill"`` rows, ``prefill_tps``), so a
chunked-prefill regression trips the gate independently of decode
throughput.  CI machines are noisy and heterogeneous, so the threshold is
generous (default: fail only when a backend regresses more than 30% below
baseline).

The ``latency_curve`` workload (virtual-clock decode tok/s vs simulated
link latency, circular vs round-flush — see ``bench_throughput.py``) is
registered as *informational*: its deltas are printed per
(policy, latency) cell but never fail the gate, until enough CI history
exists to promote it into ``GATES``.

    python benchmarks/check_regression.py --baseline BENCH_throughput.json \
        --new bench_new.json [--threshold 0.30] [--allow-missing]

Exit codes: 0 OK, 1 regression, 2 a gated workload key (``engine_backend``
/ ``engine_prefill`` rows) is missing from the baseline or the new run —
distinct from a regression so CI can tell "the bench got slower" apart
from "the bench stopped measuring" (pass ``--allow-missing`` to downgrade
2 to a skip).  A missing/corrupt baseline *file* still exits 0: a fresh
clone without committed numbers should not hard-fail the gate.

Caveat: a committed baseline measured on one machine gates a run on
another, so part of the margin absorbs machine-speed differences, not
code.  CI therefore passes a wider ``--threshold``; the long-term plan
(ROADMAP) is to re-baseline from a prior CI artifact of the same runner
class and tighten.
"""

import argparse
import json
import sys


# gated metrics: (bench row kind, preferred field, fallback field, label)
GATES = (
    ("engine_backend", "decode_tps", "tps", "decode tok/s"),
    ("engine_prefill", "prefill_tps", None, "prefill tok/s"),
)

# informational metrics: compared and printed, but NEVER fail the gate
# (no CI history yet — promote to GATES once re-baselined from CI
# artifacts, see ROADMAP).  Rows are keyed (policy, latency).
INFORMATIONAL = (
    ("latency_curve", "vtps", "virtual decode tok/s"),
)


def _tps_by_backend(path: str, bench: str, field: str,
                    fallback) -> dict:
    with open(path) as f:
        data = json.load(f)
    out = {}
    for row in data.get("rows", []):
        if row.get("bench") != bench:
            continue
        tps = row.get(field, row.get(fallback) if fallback else None)
        if tps is not None:           # keep 0.0 — a zero-throughput run
            out[row.get("policy", "?")] = float(tps)   # must trip the gate
    return out


def _rows_by_policy_latency(path: str, bench: str, field: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    out = {}
    for row in data.get("rows", []):
        if row.get("bench") != bench or field not in row:
            continue
        out[(row.get("policy", "?"),
             float(row.get("latency", 0.0)))] = float(row[field])
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_throughput.json")
    ap.add_argument("--new", required=True)
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional drop vs baseline")
    ap.add_argument("--allow-missing", action="store_true",
                    help="treat a missing gated workload key as a skip "
                         "instead of exit code 2")
    args = ap.parse_args()

    failed = False
    missing = False
    compared = False
    for bench, field, fallback, label in GATES:
        try:
            base = _tps_by_backend(args.baseline, bench, field, fallback)
        except (OSError, json.JSONDecodeError) as e:
            print(f"perf gate: no usable baseline ({e}) — skipping")
            return 0
        new = _tps_by_backend(args.new, bench, field, fallback)
        if not base or not new:
            which = "baseline" if not base else "new run"
            print(f"perf gate: workload {bench!r} has no comparable rows "
                  f"in the {which} — "
                  + ("skipping (--allow-missing)" if args.allow_missing
                     else "exit 2 (the bench stopped measuring it)"))
            missing = True
            continue
        compared = True
        for backend, b_tps in sorted(base.items()):
            n_tps = new.get(backend)
            if n_tps is None:
                print(f"perf gate: {bench}/{backend}: missing from new "
                      "run — exit 2")
                missing = True
                continue
            if b_tps <= 0:
                print(f"perf gate: {bench}/{backend}: baseline is "
                      f"{b_tps:.1f} — nothing to compare, skipping")
                continue
            drop = 1.0 - n_tps / b_tps
            status = "OK"
            if drop > args.threshold:
                status = "REGRESSION"
                failed = True
            print(f"perf gate: {bench}/{backend}: baseline {b_tps:.1f} -> "
                  f"{n_tps:.1f} {label} ({-drop:+.1%}) [{status}]")
    if not compared:
        print("perf gate: nothing comparable — skipping")

    # non-gated, informational only: report the delta, never fail
    for bench, field, label in INFORMATIONAL:
        try:
            base = _rows_by_policy_latency(args.baseline, bench, field)
            new = _rows_by_policy_latency(args.new, bench, field)
        except (OSError, json.JSONDecodeError):
            continue
        if not base and not new:
            continue
        for key in sorted(set(base) | set(new)):
            b, n = base.get(key), new.get(key)
            pol, lat = key
            tag = f"{bench}/{pol}@{lat * 1000:.0f}ms"
            if b is None or n is None:
                print(f"perf info: {tag}: only in "
                      f"{'new run' if b is None else 'baseline'} "
                      f"({label} {n if b is None else b:.1f}) [INFO]")
            elif b > 0:
                print(f"perf info: {tag}: {b:.1f} -> {n:.1f} {label} "
                      f"({n / b - 1.0:+.1%}) [INFO, non-gated]")

    if failed:
        return 1
    if missing and not args.allow_missing:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
