"""Perf-regression gate: compare a fresh ``BENCH_throughput.json`` against
the committed baseline.

Three gated workloads:

* ``engine_backend`` rows — measured engine decode tok/s of the
  decode-heavy workload per backend (``decode_tps`` falling back to
  ``tps``);
* ``engine_prefill`` rows — prefill tok/s of the prefill-heavy workload,
  so a chunked-prefill regression trips the gate independently of decode
  throughput;
* ``latency_curve`` rows — virtual-clock decode tok/s on the real engine
  over simulated WAN links, gated per (policy, latency, bandwidth) cell:
  the circular-vs-round-flush latency sweep AND the bandwidth-capped
  fp32-vs-int8 wire columns.  The virtual clock makes these cells nearly
  machine-independent, so the shared threshold is comfortably wide for
  them.

Absolute floors ride along (``ABS_GATES``): the fused-sampling
speedup (``sampling_fast.ratio`` >= 1.15), the async-offload overlap
(``offload_overlap.hide_frac`` >= 0.80), the online-serving
prefix-cache correctness bit (``online_serving.prefix_exact`` == 1.0:
zero shared-prefix recompute + streamed tokens bit-identical to offline
``LLM.generate``; its TTFT/ITL percentiles print as informational
cells), and the flight-recorder overhead
(``tracing_overhead.ratio`` >= 0.95: decode tok/s with tracing on vs
off on the same build).  These compare the new run
against *itself* (each row is an in-bench A/B), so they need no baseline
and no machine margin; they skip with [INFO] when the producing bench
didn't run.  Measured ``kernel_roofline`` rows are printed as
informational cells and never gate.

CI machines are noisy and heterogeneous, so the relative threshold is
generous (default: fail only when a metric regresses more than 30% below
baseline).

    python benchmarks/check_regression.py --baseline BENCH_throughput.json \
        --new bench_new.json [--threshold 0.30] [--allow-missing]

Exit codes: 0 OK, 1 regression, 2 a gated workload has no comparable rows
in the baseline or the new run — distinct from a regression so CI can tell
"the bench got slower" apart from "the bench stopped measuring" (pass
``--allow-missing`` to downgrade 2 to a skip).  A missing/corrupt baseline
*file* still exits 0: a fresh clone without committed numbers should not
hard-fail the gate.

Caveat: a committed baseline measured on one machine gates a run on
another, so part of the margin absorbs machine-speed differences, not
code.  CI therefore passes a wider ``--threshold``; the long-term plan
(ROADMAP) is to re-baseline from a prior CI artifact of the same runner
class and tighten.
"""

import argparse
import json
import sys


# gated metrics: (bench row kind, preferred field, fallback field, label,
# keying).  keying "policy" compares one number per backend/policy;
# "cell" compares per (policy, latency, bandwidth) — the latency_curve
# sweep, where one policy appears at many link settings.
GATES = (
    ("engine_backend", "decode_tps", "tps", "decode tok/s", "policy"),
    ("engine_prefill", "prefill_tps", None, "prefill tok/s", "policy"),
    ("latency_curve", "vtps", None, "virtual decode tok/s", "cell"),
)

# absolute floors (PR 8): the fused-sampling and async-offload wins are
# asserted on the NEW run directly — each bench row carries its own A/B
# comparison (fast vs sorted sampling; async vs sync swap window), so no
# baseline ratio is involved and machine speed cancels out.  Checked only
# when the row is present: CI produces them in dedicated bench
# invocations, and an --only run that doesn't measure one skips it with
# [INFO] rather than exit 2.
ABS_GATES = (
    ("sampling_fast", "ratio", 1.15,
     "fused-sampling speedup vs full-vocab sort"),
    ("offload_overlap", "hide_frac", 0.80,
     "async-offload hidden host-copy fraction"),
    # online serving correctness: 1.0 iff the shared prompt prefix was
    # re-prefilled ZERO times AND the streamed tokens are bit-identical
    # to offline LLM.generate — a correctness bit, so the floor is exact
    ("online_serving", "prefix_exact", 1.0,
     "prefix-cache zero-recompute + offline bit-identity"),
    # flight-recorder overhead (PR 11): decode tok/s with tracing on vs
    # off on the same build — the recorder only appends host scalars the
    # engine already holds, so the A/B ratio must stay near 1
    ("tracing_overhead", "ratio", 0.95,
     "decode tok/s with tracing on vs off"),
)


def _load_rows(path: str) -> list:
    with open(path) as f:
        return json.load(f).get("rows", [])


def _tps_by_backend(path: str, bench: str, field: str,
                    fallback) -> dict:
    with open(path) as f:
        data = json.load(f)
    out = {}
    for row in data.get("rows", []):
        if row.get("bench") != bench:
            continue
        tps = row.get(field, row.get(fallback) if fallback else None)
        if tps is not None:           # keep 0.0 — a zero-throughput run
            out[row.get("policy", "?")] = float(tps)   # must trip the gate
    return out


def _rows_by_cell(path: str, bench: str, field: str, fallback) -> dict:
    """{(policy, latency, bandwidth) -> value}; the ratio/speedup rows
    carry no ``field`` and drop out naturally."""
    with open(path) as f:
        data = json.load(f)
    out = {}
    for row in data.get("rows", []):
        if row.get("bench") != bench or field not in row:
            continue
        out[(row.get("policy", "?"), float(row.get("latency", 0.0)),
             float(row.get("bandwidth", 0.0)))] = float(row[field])
    return out


def _fmt_key(key) -> str:
    if isinstance(key, str):
        return key
    pol, lat, bw = key
    s = f"{pol}@{lat * 1000:.0f}ms"
    if bw:
        s += f"/bw{bw / 1000:.0f}k"
    return s


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_throughput.json")
    ap.add_argument("--new", required=True)
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional drop vs baseline")
    ap.add_argument("--allow-missing", action="store_true",
                    help="treat a missing gated workload key as a skip "
                         "instead of exit code 2")
    args = ap.parse_args()

    failed = False
    missing = False
    compared = False
    for bench, field, fallback, label, keying in GATES:
        extract = _rows_by_cell if keying == "cell" else _tps_by_backend
        try:
            base = extract(args.baseline, bench, field, fallback)
        except (OSError, json.JSONDecodeError) as e:
            print(f"perf gate: no usable baseline ({e}) — skipping")
            return 0
        new = extract(args.new, bench, field, fallback)
        if not base or not new:
            which = "baseline" if not base else "new run"
            print(f"perf gate: workload {bench!r} has no comparable rows "
                  f"in the {which} — "
                  + ("skipping (--allow-missing)" if args.allow_missing
                     else "exit 2 (the bench stopped measuring it)"))
            missing = True
            continue
        compared = True
        for key, b_tps in sorted(base.items()):
            tag = f"{bench}/{_fmt_key(key)}"
            n_tps = new.get(key)
            if n_tps is None:
                print(f"perf gate: {tag}: missing from new run — exit 2")
                missing = True
                continue
            if b_tps <= 0:
                print(f"perf gate: {tag}: baseline is {b_tps:.1f} — "
                      "nothing to compare, skipping")
                continue
            drop = 1.0 - n_tps / b_tps
            status = "OK"
            if drop > args.threshold:
                status = "REGRESSION"
                failed = True
            print(f"perf gate: {tag}: baseline {b_tps:.1f} -> "
                  f"{n_tps:.1f} {label} ({-drop:+.1%}) [{status}]")
        for key in sorted(set(new) - set(base)):
            print(f"perf gate: {bench}/{_fmt_key(key)}: new cell "
                  f"({new[key]:.1f} {label}) — no baseline yet [INFO]")

    new_rows = _load_rows(args.new)
    for bench, field, floor, label in ABS_GATES:
        vals = [float(r[field]) for r in new_rows
                if r.get("bench") == bench and field in r]
        if not vals:
            print(f"perf gate: {bench}/{field}: not measured in this "
                  "run — skipping [INFO]")
            continue
        compared = True
        worst = min(vals)
        ok = worst >= floor
        if not ok:
            failed = True
        print(f"perf gate: {bench}/{field}: {worst:.3f} "
              f"(floor {floor:.2f}) — {label} "
              f"[{'OK' if ok else 'REGRESSION'}]")

    # online-serving latency percentiles: informational only — TTFT/ITL
    # are wall-clock on a shared CI runner, so they track the trajectory
    # without gating (prefix_exact above is the gated bit)
    try:
        base_ol = [r for r in _load_rows(args.baseline)
                   if r.get("bench") == "online_serving"]
    except (OSError, json.JSONDecodeError):
        base_ol = []
    for r in new_rows:
        if r.get("bench") != "online_serving":
            continue
        b = next((x for x in base_ol
                  if x.get("policy") == r.get("policy")), None)
        for f in ("ttft_p50_s", "ttft_p99_s", "itl_p50_s", "itl_p99_s",
                  "prefix_hit_rate"):
            if f not in r:
                continue
            msg = (f"perf gate: online_serving/{r.get('policy', '?')}/"
                   f"{f}: {r[f]:.4f}")
            if b and f in b:
                msg += f" (baseline {b[f]:.4f})"
            print(msg + " [INFO]")

    # measured kernel roofline: informational only — achieved-vs-peak
    # fractions are host-calibrated but still runner-sensitive, so they
    # never gate; the printout tracks the trajectory across artifacts
    try:
        base_fr = {r.get("kernel"): r for r in _load_rows(args.baseline)
                   if r.get("bench") == "kernel_roofline"}
    except (OSError, json.JSONDecodeError):
        base_fr = {}
    for r in new_rows:
        if r.get("bench") != "kernel_roofline":
            continue
        tag = f"kernel_roofline/{r.get('kernel', '?')}"
        msg = (f"perf gate: {tag}: {r['achieved']:.1f} {r.get('unit', '')} "
               f"achieved = {r['frac']:.1%} of peak")
        b = base_fr.get(r.get("kernel"))
        if b and b.get("frac"):
            msg += f" (baseline {b['frac']:.1%})"
        print(msg + " [INFO]")
    if not compared:
        print("perf gate: nothing comparable — skipping")

    if failed:
        return 1
    if missing and not args.allow_missing:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
