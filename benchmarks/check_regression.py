"""Perf-regression gate: compare a fresh ``BENCH_throughput.json`` against
the committed baseline.

Compares, per backend, the measured engine decode tok/s of the
decode-heavy workload (``bench == "engine_backend"`` rows, ``decode_tps``
falling back to ``tps``) AND the prefill tok/s of the prefill-heavy
workload (``bench == "engine_prefill"`` rows, ``prefill_tps``), so a
chunked-prefill regression trips the gate independently of decode
throughput.  CI machines are noisy and heterogeneous, so the threshold is
generous (default: fail only when a backend regresses more than 30% below
baseline).

    python benchmarks/check_regression.py --baseline BENCH_throughput.json \
        --new bench_new.json [--threshold 0.30]

Exit code 1 on regression, 0 otherwise (including when either file has no
comparable rows — a schema change should not hard-fail the gate).

Caveat: a committed baseline measured on one machine gates a run on
another, so part of the margin absorbs machine-speed differences, not
code.  CI therefore passes a wider ``--threshold``; the long-term plan
(ROADMAP) is to re-baseline from a prior CI artifact of the same runner
class and tighten.
"""

import argparse
import json
import sys


# gated metrics: (bench row kind, preferred field, fallback field, label)
GATES = (
    ("engine_backend", "decode_tps", "tps", "decode tok/s"),
    ("engine_prefill", "prefill_tps", None, "prefill tok/s"),
)


def _tps_by_backend(path: str, bench: str, field: str,
                    fallback) -> dict:
    with open(path) as f:
        data = json.load(f)
    out = {}
    for row in data.get("rows", []):
        if row.get("bench") != bench:
            continue
        tps = row.get(field, row.get(fallback) if fallback else None)
        if tps is not None:           # keep 0.0 — a zero-throughput run
            out[row.get("policy", "?")] = float(tps)   # must trip the gate
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_throughput.json")
    ap.add_argument("--new", required=True)
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional drop vs baseline")
    args = ap.parse_args()

    failed = False
    compared = False
    for bench, field, fallback, label in GATES:
        try:
            base = _tps_by_backend(args.baseline, bench, field, fallback)
        except (OSError, json.JSONDecodeError) as e:
            print(f"perf gate: no usable baseline ({e}) — skipping")
            return 0
        new = _tps_by_backend(args.new, bench, field, fallback)
        if not base or not new:
            print(f"perf gate: no comparable {bench} rows — skipping")
            continue
        compared = True
        for backend, b_tps in sorted(base.items()):
            n_tps = new.get(backend)
            if n_tps is None:
                print(f"perf gate: {bench}/{backend}: missing from new "
                      "run — skipping")
                continue
            if b_tps <= 0:
                print(f"perf gate: {bench}/{backend}: baseline is "
                      f"{b_tps:.1f} — nothing to compare, skipping")
                continue
            drop = 1.0 - n_tps / b_tps
            status = "OK"
            if drop > args.threshold:
                status = "REGRESSION"
                failed = True
            print(f"perf gate: {bench}/{backend}: baseline {b_tps:.1f} -> "
                  f"{n_tps:.1f} {label} ({-drop:+.1%}) [{status}]")
    if not compared:
        print("perf gate: nothing comparable — skipping")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
