"""Formulas 1+2 sweep: per-microbatch KV capacity vs in-flight microbatch
count, with and without offloading — the paper's synergy made quantitative —
plus a functional measurement of swap traffic from the engine's offloader."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced_config
from repro.core import offload as OF
from repro.core.offload import DoubleBufferOffloader
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams

M_KV = 2.0e9          # per-stage KV memory (llama3-70b / 8x4090, see sim)
KV_SEQ = 15.7e6       # avg per-sequence KV bytes per stage
W = 6e9               # effective swap bandwidth
T_S = 0.08


def run(quick: bool = False):
    rows = []
    m_g = min(OF.global_pool_bytes(W, T_S), M_KV / 2)
    print("\n== Formula 1/2 sweep: per-microbatch batch size vs N_B ==")
    print(f"   (M_KV={M_KV/1e9:.1f} GB, M_G=W*T_S={m_g/1e9:.2f} GB)")
    print(f"{'N_B':>4s} {'no-offload b':>13s} {'offload b':>10s} "
          f"{'floor kept':>10s}")
    for n_b in (8, 12, 16, 24, 32, 48, 64):
        c_no = OF.per_microbatch_capacity_no_offload(M_KV, n_b)
        c_off = OF.per_microbatch_capacity(M_KV, m_g, n_b)
        b_no = OF.batch_size_from_capacity(c_no, KV_SEQ)
        b_off = OF.batch_size_from_capacity(c_off, KV_SEQ)
        print(f"{n_b:4d} {b_no:13d} {b_off:10d} {str(c_off >= m_g):>10s}")
        rows.append({"bench": "offload_sweep", "n_b": n_b,
                     "batch_no_offload": b_no, "batch_offload": b_off})

    # functional swap traffic from the engine
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = reduced_config(get_arch("yi-9b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=16, n_global_pages=8,
                      max_pages_per_seq=6)
    off = DoubleBufferOffloader(pool, num_microbatches=4)
    sp = SamplingParams(temperature=0.0, max_new_tokens=6)
    eng = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=4,
                        pool=pool, sampling=sp, offloader=off)
    rng = np.random.RandomState(0)
    eng.submit([Request(i, list(rng.randint(1, cfg.vocab_size, 6)), sp)
                for i in range(8 if quick else 16)])
    eng.run(max_steps=2000)
    rep = eng.throughput_report()
    print(f"\n   engine offload traffic: {off.swap_count} swaps, "
          f"{off.bytes_swapped/1e6:.1f} MB moved, "
          f"{rep['total_tokens']} tokens served")
    rows.append({"bench": "offload_engine", "swaps": off.swap_count,
                 "bytes": off.bytes_swapped,
                 "tokens": rep["total_tokens"]})
    _overlap(rows, quick)
    return rows


def _overlap(rows, quick: bool):
    """Async-vs-sync cost of the swap-out (D2H) window — the half of the
    swap tentpole PR 8 made non-blocking.  Sync mode pays the blocking
    ``np.asarray`` snapshot per layer inside the engaged window; async
    mode only *enqueues* the copies and settles once at the end, so the
    transfer lands while the next tick computes.  ``hide_frac`` — the
    fraction of the in-window host-copy time removed — is gated
    (>= 0.80) by benchmarks/check_regression.py."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.config import get_arch, reduced_config
    from repro.models.common import Runtime
    from repro.serving import kv_cache as kvc

    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = reduced_config(get_arch("yi-9b"))
    pool = PoolConfig(page_size=16, n_local_pages=8, n_global_pages=256,
                      max_pages_per_seq=8)
    caches = kvc.build_paged_caches(cfg, batch=4, pool=pool, rt=rt)
    jax.block_until_ready(jax.tree.leaves(caches))
    sl = kvc.global_slice(pool, 0)
    n_swaps = 20 if quick else 60

    def timed(async_swap):
        off = DoubleBufferOffloader(pool, 4, async_swap=async_swap)
        layers = list(off._paged_layers(caches))
        off._stage_out(layers, sl)                   # warmup / compile
        off.settle()
        t0 = time.perf_counter()
        stores = [off._dispatch_stage_out(layers, sl)
                  for _ in range(n_swaps)]           # the tick-loop cost:
        engaged = time.perf_counter() - t0           # enqueue-only in async
        off._host = {i: s for i, s in enumerate(stores)}
        off.settle()                                 # off-window barrier
        return engaged, time.perf_counter() - t0

    timed(True)                                      # warmup both modes
    timed(False)
    t_async, t_async_total = timed(True)
    t_sync, _ = timed(False)
    hide = 1.0 - t_async / max(t_sync, 1e-12)
    print(f"\n   swap-out window ({n_swaps} swaps): "
          f"sync {t_sync * 1e3:.1f} ms, async {t_async * 1e3:.1f} ms "
          f"enqueued ({t_async_total * 1e3:.1f} ms settled) -> "
          f"{hide:.1%} of the host-copy window hidden")
    rows.append({"bench": "offload_overlap", "policy": "async",
                 "n_swaps": n_swaps, "t_sync_ms": t_sync * 1e3,
                 "t_async_ms": t_async * 1e3,
                 "t_async_settled_ms": t_async_total * 1e3,
                 "hide_frac": hide})
    return rows
