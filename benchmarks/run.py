"""Benchmark harness: one module per paper table/figure + the roofline
report.  ``python -m benchmarks.run [--quick] [--only NAME] [--json PATH]``

Besides the CSV tail, every run writes a machine-readable
``BENCH_throughput.json`` (all rows + metadata) so the perf trajectory is
tracked across PRs."""

import argparse
import csv
import json
import platform
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=["cost_model", "batch_curve", "throughput",
                             "offload", "attn_schemes", "roofline"])
    ap.add_argument("--json", default=None,
                    help="machine-readable output path ('' disables; "
                         "defaults to BENCH_throughput.json on full runs — "
                         "partial --only runs don't clobber the tracked "
                         "snapshot unless asked to)")
    ap.add_argument("--workload", default="all",
                    choices=["all", "decode", "prefill_heavy", "online",
                             "latency_curve", "tracing", "roofline"],
                    help="throughput bench workload: 'decode' / "
                         "'prefill_heavy' run just that measured engine "
                         "workload (implies --only throughput, no "
                         "simulator pass); 'online' runs the Poisson "
                         "online-serving workload through OnlineLLM "
                         "with prefix caching (p50/p99 TTFT + ITL, "
                         "prefix-hit correctness); 'latency_curve' sweeps "
                         "simulated link latency on the real engine "
                         "(virtual clock, circular vs round-flush); "
                         "'tracing' runs the flight-recorder overhead "
                         "A/B (trace on vs off, gated >= 0.95x) and "
                         "exports bench_timeline.json; "
                         "'roofline' runs just the roofline report "
                         "incl. the measured per-kernel "
                         "achieved-vs-peak rows (implies --only "
                         "roofline)")
    args = ap.parse_args()
    if args.workload != "all" and args.only is None:
        args.only = "roofline" if args.workload == "roofline" \
            else "throughput"
    if args.json is None:
        args.json = "" if args.only else "BENCH_throughput.json"

    from benchmarks import (bench_attention_schemes, bench_batch_curve,
                            bench_cost_model, bench_offload, bench_roofline,
                            bench_throughput)
    benches = {
        "cost_model": bench_cost_model.run,       # paper Table 2
        "batch_curve": bench_batch_curve.run,     # paper Table 3
        "throughput": bench_throughput.run,       # paper Table 4 (headline)
        "offload": bench_offload.run,             # Formulas 1-2
        "attn_schemes": bench_attention_schemes.run,  # SPerf cell D
        "roofline": bench_roofline.run,           # deliverable (g)
    }
    rows = []
    timings = {}
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        if name == "throughput":
            rows.extend(fn(quick=args.quick, workload=args.workload) or [])
        else:
            rows.extend(fn(quick=args.quick) or [])
        timings[name] = round(time.perf_counter() - t0, 1)
        print(f"   [{name}: {timings[name]:.1f}s]")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
                       "host": platform.node(),
                       "python": platform.python_version(),
                       "quick": args.quick,
                       "bench_seconds": timings,
                       "rows": rows}, f, indent=1, default=str)
        print(f"\nwrote {args.json} ({len(rows)} rows)")

    # machine-readable tail
    print("\n== CSV ==")
    w = csv.writer(sys.stdout)
    w.writerow(["bench", "key", "value"])
    for r in rows:
        bench = r.pop("bench")
        key = str(r.pop("name", "") or r.pop("arch", "") or r.pop(
            "policy", "") or r.pop("kernel", "") or "")
        shape = str(r.pop("shape", "") or r.pop("latency", "") or "")
        for k, v in r.items():
            if isinstance(v, (int, float)) and v is not None:
                tag = "/".join(x for x in (key, shape, k) if x)
                w.writerow([bench, tag, v])


if __name__ == "__main__":
    main()
