"""Benchmark harness: one module per paper table/figure + the roofline
report.  ``python -m benchmarks.run [--quick] [--only NAME]``"""

import argparse
import csv
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=["cost_model", "batch_curve", "throughput",
                             "offload", "attn_schemes", "roofline"])
    args = ap.parse_args()

    from benchmarks import (bench_attention_schemes, bench_batch_curve,
                            bench_cost_model, bench_offload, bench_roofline,
                            bench_throughput)
    benches = {
        "cost_model": bench_cost_model.run,       # paper Table 2
        "batch_curve": bench_batch_curve.run,     # paper Table 3
        "throughput": bench_throughput.run,       # paper Table 4 (headline)
        "offload": bench_offload.run,             # Formulas 1-2
        "attn_schemes": bench_attention_schemes.run,  # SPerf cell D
        "roofline": bench_roofline.run,           # deliverable (g)
    }
    rows = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        rows.extend(fn(quick=args.quick) or [])
        print(f"   [{name}: {time.perf_counter()-t0:.1f}s]")

    # machine-readable tail
    print("\n== CSV ==")
    w = csv.writer(sys.stdout)
    w.writerow(["bench", "key", "value"])
    for r in rows:
        bench = r.pop("bench")
        key = str(r.pop("name", "") or r.pop("arch", "") or r.pop(
            "policy", "") or "")
        shape = str(r.pop("shape", "") or r.pop("latency", "") or "")
        for k, v in r.items():
            if isinstance(v, (int, float)) and v is not None:
                tag = "/".join(x for x in (key, shape, k) if x)
                w.writerow([bench, tag, v])


if __name__ == "__main__":
    main()
