"""SPerf cell D evidence: wall-time of the two causal flash-attention
schemes on this host (XLA's CPU FLOP counter can't see the difference; the
clock can).  blockpair ~= exact lower-triangular FLOPs -> ~2x at long S."""

import time

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(quick: bool = False):
    rows = []
    S = 1024 if quick else 2048
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, S, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, S, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, S, 2, 64), jnp.float32)
    print(f"\n== causal attention schemes (S={S}, host wall time) ==")
    times = {}
    for scheme in ("masked", "blockpair"):
        fn = jax.jit(lambda q, k, v, s=scheme: flash_attention(
            q, k, v, causal=True, q_chunk=256, kv_chunk=256, scheme=s))
        times[scheme] = _time(fn, q, k, v)
        print(f"  {scheme:10s}: {times[scheme]*1e3:8.1f} ms/call")
        rows.append({"bench": "attn_scheme", "name": scheme,
                     "ms": times[scheme] * 1e3})
    speed = times["masked"] / times["blockpair"]
    print(f"  blockpair speedup: {speed:.2f}x (theoretical 2x as S grows)")
    rows.append({"bench": "attn_scheme", "name": "speedup", "x": speed})
    assert speed > 1.2, "blockpair should beat masked at this length"
    return rows
