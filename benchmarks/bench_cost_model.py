"""Paper Table 2: break-even throughput per compute platform."""

from repro.core import cost_model as CM


def run(quick: bool = False):
    rows = []
    t2 = CM.table2()
    print("\n== Table 2: cost / break-even throughput "
          "(paper values in brackets) ==")
    print(f"{'platform':10s} {'$/h':>7s} {'min tok/s':>10s} {'paper':>9s}")
    for name, row in t2.items():
        paper = CM.PAPER_TABLE2.get(name)
        ps = f"{paper:9.2f}" if paper else "        -"
        print(f"{name:10s} {row['cost_per_hour']:7.2f} "
              f"{row['min_throughput_tps']:10.2f} {ps}")
        rows.append({"bench": "cost_model", "name": name,
                     "min_tps": row["min_throughput_tps"],
                     "paper_tps": paper,
                     "match": (abs(row["min_throughput_tps"] - paper) / paper
                               < 0.01) if paper else None})
    return rows
