"""Roofline analysis (deliverable g): read the dry-run artifacts and emit
the per-(arch × shape × mesh) three-term roofline table.

Terms (seconds, per step, per chip):
    compute    = HLO_FLOPs / peak_FLOPs          (197 bf16 TFLOP/s v5e)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = collective_bytes / ICI_bw       (~50 GB/s/link)

Cross-check column: MODEL_FLOPS / HLO_FLOPs with MODEL_FLOPS = 6·N_active·T
(train) or 2·N_active·T (serve).  Ratios < 1 mean the compiled program does
extra work (remat recompute, MoE capacity padding, masked-attention
overcount); ratios > 1 mean XLA's counter *under-reports* (CPU fusions,
nested while loops — see the MoE note in models/moe.py), in which case the
analytic bound is the honest compute term and the table uses
``compute_eff = max(HLO, analytic)``.
"""

import glob
import json
import os

from repro.config import SHAPES, get_arch

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}
HBM_BYTES = 16e9      # v5e per-chip HBM

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")


def memory_floor_bytes(arch: str, shape_name: str, n_dev: int,
                       kv_bytes_per_elem: int = 2) -> float:
    """Analytic lower bound on HBM traffic per chip per step: every live
    parameter is read once (weight-stationary decode reads them all), the
    KV/state cache is read (+1 token written), and train adds grad+moment
    writes.  Used as a floor under XLA's (CPU-lossy) bytes counter."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    p_bytes = cfg.active_param_count() * 2
    if shape.kind == "train":
        # params read fwd+bwd + grads written + adam m/v read+write (fp32)
        traffic = cfg.param_count() * (2 * 3 + 4 + 4 * 4)
        acts = shape.seq_len * shape.global_batch * cfg.d_model * 2 * \
            cfg.num_layers * 2
        return (traffic + acts) / n_dev
    kv = cfg.kv_bytes_per_token(kv_bytes_per_elem) * shape.seq_len * \
        shape.global_batch
    if shape.kind == "prefill":
        return (p_bytes + kv) / n_dev
    return (p_bytes * 1.0 + kv) / n_dev          # decode reads all KV


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def load_cells():
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyse(rec: dict) -> dict:
    n_dev = rec.get("n_devices", 256)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo = rec["flops_per_device"] * n_dev
    ratio = mf / hlo if hlo > 0 else float("inf")
    flops_eff = max(rec["flops_per_device"], mf / n_dev)
    compute_eff = flops_eff / HW["peak_flops"]
    kv_b = 1 if rec.get("kv_dtype") == "int8" else 2
    mem_floor = memory_floor_bytes(rec["arch"], rec["shape"], n_dev, kv_b)
    terms = {
        "compute_s": compute_eff,
        "memory_s": max(rec["bytes_per_device"], mem_floor) / HW["hbm_bw"],
        "collective_s": rec["collectives"]["total"] / HW["ici_bw"],
    }
    dom = max(terms, key=terms.get)
    bound = terms[dom]
    useful = (mf / n_dev) / HW["peak_flops"]
    return {
        **terms,
        "dominant": dom,
        "bound_s": bound,
        "model_hlo_ratio": ratio,
        # the score: fraction of the bound spent on *useful* model FLOPs
        "roofline_fraction": useful / bound if bound > 0 else 0.0,
        "peak_gb": rec["memory"]["peak_per_device"] / 1e9,
        "fits_hbm": rec["memory"]["peak_per_device"] <= HBM_BYTES,
        "tok_per_s_bound": rec.get("tokens_per_step", 0) / bound
        if bound > 0 else 0.0,
    }


def run(quick: bool = False):
    all_cells = load_cells()
    by_variant = {}
    for c in all_cells:
        by_variant.setdefault(c.get("variant", ""), []).append(c)
    rows = []
    for variant in sorted(by_variant):
        label = variant or "baseline"
        if variant not in ("", "opt"):
            continue                      # hillclimb singles live in SPerf
        rows.extend(_run_table(by_variant[variant], label))
    _kernel_roofline(rows, quick)
    return rows


def _kernel_roofline(rows, quick: bool):
    """Measured per-kernel achieved-vs-peak fractions (PR 8 satellite).

    Unlike the dry-run table above (analytic v5e numbers from compiled
    HLO), these rows *time* the attention implementation that actually
    serves on this backend — the Pallas kernels on TPU, the XLA oracles
    on CPU (interpret-mode Pallas timings would measure the Python
    evaluator, not the machine).  Peaks are calibrated in-process on the
    same host: a large f32 matmul for FLOP/s, a large read+write map for
    bytes/s.  Decode attention is scored against the bandwidth peak (its
    arithmetic intensity is ~1 FLOP/byte), prefill flash attention
    against the FLOP peak.  Rows land in BENCH_throughput.json and
    check_regression.py surfaces them as informational (non-gated)
    cells."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.paged_attention import paged_decode_attention as _pl

    def best_s(fn, *args, iters=None):
        iters = iters or (5 if quick else 10)
        out = fn(*args)
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    # -- host peak calibration (same process, same thread pool) -----------
    n = 768 if quick else 1024
    a = jnp.zeros((n, n), jnp.float32)
    peak_flops = 2.0 * n ** 3 / best_s(jax.jit(jnp.dot), a, a)
    big = jnp.zeros((32 * 1024 * 1024,), jnp.float32)   # 128 MB stream
    t_bw = best_s(jax.jit(lambda x: x + 1.0), big)
    peak_bw = 2.0 * big.nbytes / t_bw                   # read + write

    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.RandomState(0)
    print("\n== Kernel roofline (measured on this host; "
          f"{'Pallas' if on_tpu else 'XLA oracle'} path) ==")
    print(f"   calibrated peaks: {peak_flops / 1e9:.1f} GFLOP/s, "
          f"{peak_bw / 1e9:.1f} GB/s")

    # -- paged decode attention: bandwidth-bound (reads the whole KV) -----
    b, hq, hk, dh = 8, 8, 2, 128
    page, maxp = 16, 8 if quick else 16
    npool = 1 + b * maxp
    q = jnp.asarray(rng.randn(b, hq, dh), jnp.float32)
    kp = jnp.asarray(rng.randn(npool, page, hk, dh), jnp.float32)
    vp = jnp.asarray(rng.randn(npool, page, hk, dh), jnp.float32)
    pt = jnp.arange(1, 1 + b * maxp, dtype=jnp.int32).reshape(b, maxp)
    lens = jnp.full((b,), page * maxp, jnp.int32)
    if on_tpu:
        f = jax.jit(lambda *xs: _pl(*xs))
    else:
        f = jax.jit(lambda *xs: ref.paged_decode_attention_ref(*xs))
    t = best_s(f, q, kp, vp, pt, lens)
    kv_bytes = 2 * b * page * maxp * hk * dh * 4        # k+v, f32
    bw = kv_bytes / t
    rows.append({"bench": "kernel_roofline", "kernel": "paged_decode",
                 "t_us": t * 1e6, "achieved": bw / 1e9,
                 "peak": peak_bw / 1e9, "unit": "GB/s",
                 "frac": bw / peak_bw})
    print(f"   paged_decode   {t * 1e6:9.1f} us  {bw / 1e9:7.1f} GB/s "
          f"({bw / peak_bw:6.1%} of stream peak)")

    # -- flash prefill attention: compute-bound (causal QK^T + PV) --------
    s = 256 if quick else 512
    bq = 2
    qf = jnp.asarray(rng.randn(bq, s, hq, dh), jnp.float32)
    kf = jnp.asarray(rng.randn(bq, s, hq, dh), jnp.float32)
    vf = jnp.asarray(rng.randn(bq, s, hq, dh), jnp.float32)
    if on_tpu:
        g = jax.jit(lambda *xs: flash_attention_pallas(*xs, causal=True))
    else:
        g = jax.jit(lambda *xs: ref.flash_attention_ref(*xs, causal=True))
    t = best_s(g, qf, kf, vf)
    flops = 2.0 * bq * hq * s * s * dh                  # 4·B·H·S²·D / 2
    fl = flops / t
    rows.append({"bench": "kernel_roofline", "kernel": "flash_prefill",
                 "t_us": t * 1e6, "achieved": fl / 1e9,
                 "peak": peak_flops / 1e9, "unit": "GFLOP/s",
                 "frac": fl / peak_flops})
    print(f"   flash_prefill  {t * 1e6:9.1f} us  {fl / 1e9:7.1f} GFLOP/s "
          f"({fl / peak_flops:6.1%} of matmul peak)")
    return rows


def _run_table(cells, label):
    rows = []
    ok = skipped = failed = 0
    lines = ["| arch | shape | mesh | peak GB | fits | compute s | "
             "memory s | coll s | dominant | MF/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    print(f"\n== Roofline [{label}] (per chip, per step; "
          "from the dry-run artifacts) ==")
    print(f"{'arch':25s}{'shape':13s}{'mesh':11s}{'pkGB':>6s}{'fit':>4s}"
          f"{'comp_s':>10s}{'mem_s':>10s}{'coll_s':>10s} {'dom':10s}"
          f"{'MF/HLO':>7s}{'frac':>7s}")
    for rec in cells:
        if rec.get("skipped"):
            skipped += 1
            continue
        if not rec.get("ok"):
            failed += 1
            print(f"{rec['arch']:25s}{rec['shape']:13s}{rec['mesh']:11s}"
                  f"  FAILED: {rec.get('error', '')[:60]}")
            continue
        ok += 1
        a = analyse(rec)
        dom = a["dominant"].replace("_s", "")
        print(f"{rec['arch']:25s}{rec['shape']:13s}{rec['mesh']:11s}"
              f"{a['peak_gb']:6.1f}{'y' if a['fits_hbm'] else 'N':>4s}"
              f"{a['compute_s']:10.2e}{a['memory_s']:10.2e}"
              f"{a['collective_s']:10.2e} {dom:10s}"
              f"{a['model_hlo_ratio']:7.2f}{a['roofline_fraction']:7.3f}")
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{a['peak_gb']:.1f} | {'yes' if a['fits_hbm'] else 'NO'} | "
            f"{a['compute_s']:.2e} | {a['memory_s']:.2e} | "
            f"{a['collective_s']:.2e} | {dom} | "
            f"{a['model_hlo_ratio']:.2f} | {a['roofline_fraction']:.3f} |")
        rows.append({"bench": f"roofline_{label}", "arch": rec["arch"],
                     "shape": rec["shape"], "mesh": rec["mesh"], **a})
    print(f"\n   cells: {ok} compiled, {skipped} skipped "
          f"(long_500k on full-attention archs), {failed} failed")
    out = OUT_MD.replace(".md", f"_{label}.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"   table written to {out}")
    return rows
