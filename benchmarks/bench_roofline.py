"""Roofline analysis (deliverable g): read the dry-run artifacts and emit
the per-(arch × shape × mesh) three-term roofline table.

Terms (seconds, per step, per chip):
    compute    = HLO_FLOPs / peak_FLOPs          (197 bf16 TFLOP/s v5e)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = collective_bytes / ICI_bw       (~50 GB/s/link)

Cross-check column: MODEL_FLOPS / HLO_FLOPs with MODEL_FLOPS = 6·N_active·T
(train) or 2·N_active·T (serve).  Ratios < 1 mean the compiled program does
extra work (remat recompute, MoE capacity padding, masked-attention
overcount); ratios > 1 mean XLA's counter *under-reports* (CPU fusions,
nested while loops — see the MoE note in models/moe.py), in which case the
analytic bound is the honest compute term and the table uses
``compute_eff = max(HLO, analytic)``.
"""

import glob
import json
import os

from repro.config import SHAPES, get_arch

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}
HBM_BYTES = 16e9      # v5e per-chip HBM

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")


def memory_floor_bytes(arch: str, shape_name: str, n_dev: int,
                       kv_bytes_per_elem: int = 2) -> float:
    """Analytic lower bound on HBM traffic per chip per step: every live
    parameter is read once (weight-stationary decode reads them all), the
    KV/state cache is read (+1 token written), and train adds grad+moment
    writes.  Used as a floor under XLA's (CPU-lossy) bytes counter."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    p_bytes = cfg.active_param_count() * 2
    if shape.kind == "train":
        # params read fwd+bwd + grads written + adam m/v read+write (fp32)
        traffic = cfg.param_count() * (2 * 3 + 4 + 4 * 4)
        acts = shape.seq_len * shape.global_batch * cfg.d_model * 2 * \
            cfg.num_layers * 2
        return (traffic + acts) / n_dev
    kv = cfg.kv_bytes_per_token(kv_bytes_per_elem) * shape.seq_len * \
        shape.global_batch
    if shape.kind == "prefill":
        return (p_bytes + kv) / n_dev
    return (p_bytes * 1.0 + kv) / n_dev          # decode reads all KV


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def load_cells():
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyse(rec: dict) -> dict:
    n_dev = rec.get("n_devices", 256)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo = rec["flops_per_device"] * n_dev
    ratio = mf / hlo if hlo > 0 else float("inf")
    flops_eff = max(rec["flops_per_device"], mf / n_dev)
    compute_eff = flops_eff / HW["peak_flops"]
    kv_b = 1 if rec.get("kv_dtype") == "int8" else 2
    mem_floor = memory_floor_bytes(rec["arch"], rec["shape"], n_dev, kv_b)
    terms = {
        "compute_s": compute_eff,
        "memory_s": max(rec["bytes_per_device"], mem_floor) / HW["hbm_bw"],
        "collective_s": rec["collectives"]["total"] / HW["ici_bw"],
    }
    dom = max(terms, key=terms.get)
    bound = terms[dom]
    useful = (mf / n_dev) / HW["peak_flops"]
    return {
        **terms,
        "dominant": dom,
        "bound_s": bound,
        "model_hlo_ratio": ratio,
        # the score: fraction of the bound spent on *useful* model FLOPs
        "roofline_fraction": useful / bound if bound > 0 else 0.0,
        "peak_gb": rec["memory"]["peak_per_device"] / 1e9,
        "fits_hbm": rec["memory"]["peak_per_device"] <= HBM_BYTES,
        "tok_per_s_bound": rec.get("tokens_per_step", 0) / bound
        if bound > 0 else 0.0,
    }


def run(quick: bool = False):
    all_cells = load_cells()
    by_variant = {}
    for c in all_cells:
        by_variant.setdefault(c.get("variant", ""), []).append(c)
    rows = []
    for variant in sorted(by_variant):
        label = variant or "baseline"
        if variant not in ("", "opt"):
            continue                      # hillclimb singles live in SPerf
        rows.extend(_run_table(by_variant[variant], label))
    return rows


def _run_table(cells, label):
    rows = []
    ok = skipped = failed = 0
    lines = ["| arch | shape | mesh | peak GB | fits | compute s | "
             "memory s | coll s | dominant | MF/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    print(f"\n== Roofline [{label}] (per chip, per step; "
          "from the dry-run artifacts) ==")
    print(f"{'arch':25s}{'shape':13s}{'mesh':11s}{'pkGB':>6s}{'fit':>4s}"
          f"{'comp_s':>10s}{'mem_s':>10s}{'coll_s':>10s} {'dom':10s}"
          f"{'MF/HLO':>7s}{'frac':>7s}")
    for rec in cells:
        if rec.get("skipped"):
            skipped += 1
            continue
        if not rec.get("ok"):
            failed += 1
            print(f"{rec['arch']:25s}{rec['shape']:13s}{rec['mesh']:11s}"
                  f"  FAILED: {rec.get('error', '')[:60]}")
            continue
        ok += 1
        a = analyse(rec)
        dom = a["dominant"].replace("_s", "")
        print(f"{rec['arch']:25s}{rec['shape']:13s}{rec['mesh']:11s}"
              f"{a['peak_gb']:6.1f}{'y' if a['fits_hbm'] else 'N':>4s}"
              f"{a['compute_s']:10.2e}{a['memory_s']:10.2e}"
              f"{a['collective_s']:10.2e} {dom:10s}"
              f"{a['model_hlo_ratio']:7.2f}{a['roofline_fraction']:7.3f}")
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{a['peak_gb']:.1f} | {'yes' if a['fits_hbm'] else 'NO'} | "
            f"{a['compute_s']:.2e} | {a['memory_s']:.2e} | "
            f"{a['collective_s']:.2e} | {dom} | "
            f"{a['model_hlo_ratio']:.2f} | {a['roofline_fraction']:.3f} |")
        rows.append({"bench": f"roofline_{label}", "arch": rec["arch"],
                     "shape": rec["shape"], "mesh": rec["mesh"], **a})
    print(f"\n   cells: {ok} compiled, {skipped} skipped "
          f"(long_500k on full-attention archs), {failed} failed")
    out = OUT_MD.replace(".md", f"_{label}.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"   table written to {out}")
    return rows
