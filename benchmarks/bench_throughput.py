"""Paper Table 4 (the headline): output throughput vs link latency for the
three serving policies, from the calibrated discrete-event simulator —
plus a measured engine comparison of the two execution backends on a
decode-heavy and a prefill-heavy (``--workload prefill_heavy``) workload,
plus the Table-4-shaped ``latency_curve`` on the REAL engine over
simulated WAN links (virtual clock, circular vs round-flush)."""

from repro.core.simulator import PAPER_TABLE4, table4

LATS = (0.0, 0.016, 0.032, 0.064, 0.256)


def _engine_backends(rows, quick: bool, workload: str = "all"):
    """Measured tok/s through the LLM front end on both execution backends
    (reduced config; pipelined runs 2 stages when the host has the
    devices, else a 1-stage pipe — same code path, no fake-device fork).
    Timing comes from the engine's own phase-split clock
    (``stats.prefill_time_s`` / ``stats.decode_time_s``), with warmup
    steps (jit compiles + pipe fill) snapshot-subtracted."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_arch, reduced_config
    from repro.models import model as M
    from repro.models.common import Runtime
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.llm import LLM, EngineConfig, SamplingParams

    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = reduced_config(get_arch("yi-9b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                      max_pages_per_seq=8)
    n_stages = 2 if len(jax.devices()) >= 2 else 1

    # two workloads: decode-heavy (short prompts, the Table-4 regime) and
    # prefill-heavy (long prompts, short generations — the open-model
    # serving regime chunked admission targets).  Both are recorded in
    # BENCH_throughput.json and gated by benchmarks/check_regression.py.
    workloads = {
        "engine_backend": dict(n_req=6 if quick else 12, prompt_len=8,
                               max_new=16 if quick else 24),
        "engine_prefill": dict(n_req=6 if quick else 12, prompt_len=48,
                               max_new=4),
    }
    if workload == "decode":
        workloads.pop("engine_prefill")
    elif workload == "prefill_heavy":
        workloads.pop("engine_backend")
    for bench, wl in workloads.items():
        print(f"\n-- {bench} (measured, reduced config, "
              f"prompt={wl['prompt_len']} max_new={wl['max_new']}) --")
        sp = SamplingParams(temperature=0.0, max_new_tokens=wl["max_new"])
        for backend in ("local", "pipelined"):
            llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
                mb_size=2, num_microbatches=2, pool=pool, offload=True,
                backend=backend, n_stages=n_stages, prefill_chunk=16,
                max_prefill_tokens_per_tick=32))
            rng = np.random.RandomState(0)
            # fixed prompt length: one prefill shape.  Warmup is a full
            # pass of the same workload, so every jit variant compiles
            # there and the timed pass is pure steady state.
            prompts = [list(rng.randint(1, cfg.vocab_size, wl["prompt_len"]))
                       for _ in range(wl["n_req"])]
            llm.generate(prompts, sp, max_steps=5000)       # warmup pass
            stats = llm.engine.stats
            warm = (stats.total_tokens, stats.decode_tokens,
                    stats.prefill_tokens, stats.wall_time_s,
                    stats.decode_time_s, stats.prefill_time_s)
            llm.generate(prompts, sp, max_steps=5000)       # timed pass
            rep = llm.stats()
            dt = rep["wall_time_s"] - warm[3]
            tps = (rep["total_tokens"] - warm[0]) / dt
            decode_tps = (rep["decode_tokens"] - warm[1]) / \
                max(rep["decode_time_s"] - warm[4], 1e-9)
            prefill_tps = (rep["prefill_tokens"] - warm[2]) / \
                max(rep["prefill_time_s"] - warm[5], 1e-9)
            print(f"  {backend:10s} {tps:8.1f} tok/s "
                  f"({decode_tps:.1f} decode tok/s, "
                  f"{prefill_tps:.1f} prefill tok/s, {rep['finished']} reqs, "
                  f"{rep['swaps']} swaps, mean latency "
                  f"{rep['mean_latency_steps']:.0f} steps, "
                  f"stages={n_stages if backend == 'pipelined' else 1})")
            rows.append({"bench": bench, "policy": backend,
                         "tps": tps, "decode_tps": decode_tps,
                         "prefill_tps": prefill_tps,
                         "tokens": rep["total_tokens"],
                         "swaps": rep["swaps"],
                         "mean_latency_steps": rep["mean_latency_steps"]})


_BW_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.config import get_arch, reduced_config
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.kv_cache import PoolConfig
from repro.distributed.transport import SimulatedLinkTransport
from repro.serving.llm import LLM, EngineConfig, SamplingParams

quick = bool(int(os.environ.get("BW_QUICK", "1")))
rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg = reduced_config(get_arch("yi-9b"))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=8, n_local_pages=64, n_global_pages=0,
                  max_pages_per_seq=4)
T, n_stages, n_b = 0.016, 2, 4
sp = SamplingParams(temperature=0.0, max_new_tokens=10 if quick else 16)
rng = np.random.RandomState(0)
prompts = [list(rng.randint(1, cfg.vocab_size, 6)) for _ in range(n_b)]
rows = []
for bw in ((8000.0,) if quick else (8000.0, 32000.0)):
    for policy, wire in (("circular", "fp32"), ("circular_int8", "int8")):
        tr = SimulatedLinkTransport.uniform(n_stages, 0.0, bandwidth_bps=bw,
                                            stage_time_s=T)
        llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
            mb_size=1, num_microbatches=n_b, pool=pool, offload=False,
            backend="pipelined", n_stages=n_stages, transport=tr,
            schedule="circular", wire_dtype=wire, prefill_chunk=8,
            max_prefill_tokens_per_tick=8))
        outs = llm.generate(prompts, sp, max_steps=5000)
        assert all(o.finished for o in outs)
        rep = llm.stats()
        rows.append({"bench": "latency_curve", "policy": policy,
                     "latency": 0.0, "bandwidth": bw,
                     "vtps": rep["virtual_decode_tok_per_s"],
                     "n_b": n_b, "n_stages": n_stages, "wire_dtype": wire,
                     "virtual_time_s": rep["transport"]["virtual_time_s"]})
for bw in {r["bandwidth"] for r in rows}:
    cell = {r["policy"]: r["vtps"] for r in rows if r["bandwidth"] == bw}
    assert cell["circular_int8"] > cell["circular"], (
        f"int8 wire must strictly beat fp32 on a {bw:.0f} B/s pipe: {cell}")
print("BWROWS " + json.dumps(rows))
"""


def _bandwidth_columns(rows, quick: bool):
    """Bandwidth-capped cells: a *thin* ring (bytes/s) instead of a long
    one — the wire codec's regime.  Same circular schedule and depth;
    the only difference between the two policies is the payload packing
    on the link, so ``circular_int8`` strictly beating ``circular`` is
    the wire-speed acceptance.  Needs real stage boundaries (payloads
    only cross between stages), so it runs a 2-stage pipe on two fake
    host devices in a fresh interpreter, whatever this host has."""
    import json
    import os
    import subprocess
    import sys
    env = dict(os.environ, BW_QUICK="1" if quick else "0")
    env["PYTHONPATH"] = os.pathsep.join(filter(None, [
        os.path.join(os.path.dirname(__file__), "..", "src"),
        env.get("PYTHONPATH")]))
    r = subprocess.run([sys.executable, "-c", _BW_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    if r.returncode != 0:
        print("  bandwidth columns FAILED (2-device subprocess):")
        print("  " + r.stderr[-800:].replace("\n", "\n  "))
        raise RuntimeError("bandwidth-capped latency_curve cells failed")
    bw_rows = json.loads(r.stdout.split("BWROWS ", 1)[1])
    rows.extend(bw_rows)
    for bw in sorted({x["bandwidth"] for x in bw_rows}):
        cell = {x["policy"]: x["vtps"] for x in bw_rows
                if x["bandwidth"] == bw}
        ratio = cell["circular_int8"] / cell["circular"]
        print(f"  BW={bw/1000:4.0f}kB/s circular      "
              f"{cell['circular']:7.1f} virtual tok/s (fp32 wire)")
        print(f"  BW={bw/1000:4.0f}kB/s circular_int8 "
              f"{cell['circular_int8']:7.1f} virtual tok/s "
              f"({ratio:.2f}x: packed payload on the thin pipe)")
        rows.append({"bench": "latency_curve", "policy": "wire_speedup",
                     "latency": 0.0, "bandwidth": bw, "ratio": ratio})


def _latency_curve(rows, quick: bool):
    """The Table-4-shaped curve on the REAL engine: decode tok/s vs
    one-way link latency, planner-chosen N_B circular schedule vs the
    round-flush (vLLM-PP) N_B = N_S baseline, through
    ``SimulatedLinkTransport`` on a virtual clock (fixed virtual stage
    time, so the numbers are machine-independent and the run costs CPU
    milliseconds).  Each cell is cross-checked against the discrete-event
    simulator's round-time mechanics (``sim_tps`` — the same
    ``PipelineSimulator._round_time`` code that produces Table 4).
    Bandwidth-capped columns (``_bandwidth_columns``) compare the int8
    wire codec against raw fp32 payloads on a thin pipe.  Recorded in
    BENCH_throughput.json and gated per cell by
    benchmarks/check_regression.py."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_arch, reduced_config
    from repro.core.scheduler import optimal_microbatches
    from repro.core.simulator import PipelineSimulator, SimConfig
    from repro.models import model as M
    from repro.models.common import Runtime
    from repro.serving.kv_cache import PoolConfig
    from repro.distributed.transport import SimulatedLinkTransport
    from repro.serving.llm import LLM, EngineConfig, SamplingParams

    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = reduced_config(get_arch("yi-9b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=64, n_global_pages=0,
                      max_pages_per_seq=4)
    n_stages = 2 if len(jax.devices()) >= 2 else 1
    T = 0.016                           # virtual stage time (seconds)
    lats = (0.0, 0.064) if quick else (0.0, 0.016, 0.032, 0.064)
    max_new = 10 if quick else 16
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new)
    rng = np.random.RandomState(0)

    print(f"\n-- latency_curve (real engine, virtual clock: "
          f"T_S={T*1000:.0f}ms, {n_stages} stage(s)) --")
    for lat in lats:
        # planner-chosen depth, floored so the L=0 cell still has a few
        # microbatches in flight; ONE admission wave (n_req == circular
        # slots) so steady state dominates — a lone tail request cannot
        # hide latency under any schedule and would blur the comparison
        n_b_star = max(4, min(12, optimal_microbatches(n_stages, T, lat)))
        n_req = n_b_star
        prompts = [list(rng.randint(1, cfg.vocab_size, 6))
                   for _ in range(n_req)]
        for policy, n_b, schedule in (
                ("circular", n_b_star, "circular"),
                ("round_flush", n_stages, "round_flush")):
            tr = SimulatedLinkTransport.uniform(n_stages, lat,
                                                stage_time_s=T)
            llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
                mb_size=1, num_microbatches=n_b, pool=pool, offload=False,
                backend="pipelined", n_stages=n_stages, transport=tr,
                schedule=schedule, prefill_chunk=8,
                max_prefill_tokens_per_tick=8))
            outs = llm.generate(prompts, sp, max_steps=5000)
            assert all(o.finished for o in outs)
            rep = llm.stats()
            vtps = rep["virtual_decode_tok_per_s"]
            # DES cross-check: the simulator's round-time mechanics at
            # this exact (N_S, N_B, T_S, L) — steady state, no prefill
            sim = PipelineSimulator(SimConfig(
                policy="vllm_pp" if schedule == "round_flush"
                else "deserve_pp", n_stages=n_stages, latency=lat))
            sim_tps = n_b / sim._round_time(T, n_b)
            print(f"  L={lat*1000:5.1f}ms {policy:12s} N_B={n_b:2d} "
                  f"{vtps:7.1f} virtual tok/s (sim predicts "
                  f"{sim_tps:7.1f})")
            rows.append({"bench": "latency_curve", "policy": policy,
                         "latency": lat, "vtps": vtps, "sim_tps": sim_tps,
                         "n_b": n_b, "n_stages": n_stages,
                         "virtual_time_s":
                             rep["transport"]["virtual_time_s"]})
    by = {(r["policy"], r["latency"]): r["vtps"] for r in rows
          if r["bench"] == "latency_curve"}
    hi = max(lats)
    ratio = by[("circular", hi)] / by[("round_flush", hi)]
    print(f"  circular/round_flush at {hi*1000:.0f}ms: {ratio:.1f}x "
          "(acceptance floor: 3x)")
    rows.append({"bench": "latency_curve", "policy": "speedup",
                 "latency": hi, "ratio": ratio})
    _bandwidth_columns(rows, quick)


def _online_serving(rows, quick: bool):
    """Poisson multi-tenant online workload through ``OnlineLLM``: every
    request shares a 24-token system prompt, arrivals are seeded
    exponential gaps submitted into the LIVE engine loop, and the prefix
    cache serves the shared pages without re-prefilling them.  Reports
    p50/p99 TTFT and inter-token latency (informational — wall-clock) and
    two gated correctness fields: ``prefix_exact`` (1.0 iff the shared
    prefix was re-prefilled ZERO times — computed prefill tokens exactly
    equal submitted prompt tokens minus cache-hit tokens, and every
    post-warmup request hit all three shared pages) and bit-identity of
    the streamed tokens against offline ``LLM.generate`` on a fresh
    cache-less engine."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_arch, reduced_config
    from repro.models import model as M
    from repro.models.common import Runtime
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.llm import LLM, EngineConfig, SamplingParams
    from repro.serving.online import OnlineLLM

    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = reduced_config(get_arch("yi-9b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=64, n_global_pages=8,
                      max_pages_per_seq=8)
    n_req = 6 if quick else 12
    max_new = 8 if quick else 16
    rate = 50.0                         # req/s — arrivals overlap decode
    rng = np.random.RandomState(0)
    system = list(rng.randint(1, cfg.vocab_size, 24))   # 3 shared pages
    prompts = [system + list(rng.randint(1, cfg.vocab_size,
                                         rng.randint(4, 16)))
               for _ in range(n_req)]
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new)

    def build(prefix_cache):
        return LLM(cfg, params=params, rt=rt, config=EngineConfig(
            mb_size=2, num_microbatches=2, pool=pool, offload=True,
            backend="local", prefill_chunk=16,
            max_prefill_tokens_per_tick=32, prefix_cache=prefix_cache))

    # offline reference: same prompts, fresh engine, NO cache — greedy
    # decoding makes the token streams request-id independent, so this is
    # the bit-identity baseline for the online run below
    ref = build(False).generate(prompts, sp)

    online = OnlineLLM(llm=build(True))
    # warm the cache deterministically: one throwaway request prefills +
    # inserts the system pages, so every measured request is a hit
    online.submit(system + list(rng.randint(1, cfg.vocab_size, 4)),
                  SamplingParams(temperature=0.0, max_new_tokens=2)).result()
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_req))
    streams = []
    nxt = 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while nxt < n_req and arrivals[nxt] <= now:
            streams.append(online.submit(prompts[nxt], sp))
            nxt += 1
        if not online.step():
            if nxt >= n_req:
                break
            time.sleep(min(0.002, max(
                0.0, arrivals[nxt] - (time.perf_counter() - t0))))
    outs = [s.result() for s in streams]
    rep = online.stats()
    stats = online.engine.stats

    # gated correctness: zero shared-prefix recompute + offline identity
    total_prompt = sum(len(p) for p in prompts) + 24 + 4   # + warmup
    zero_recompute = (
        stats.prefix_hits == n_req
        and stats.prefix_hit_tokens == 24 * n_req
        and stats.prefill_tokens == total_prompt - stats.prefix_hit_tokens)
    identical = all(o.token_ids == r.token_ids and o.finished
                    for o, r in zip(outs, ref))
    prefix_exact = 1.0 if (zero_recompute and identical) else 0.0

    def _pct(vals, q):
        return float(np.percentile(vals, q)) if vals else 0.0
    ttfts = [s.ttft_s for s in streams if s.ttft_s is not None]
    itls = [d for s in streams for d in s.inter_token_s()]
    row = {"bench": "online_serving", "policy": "prefix_cache",
           "n_req": n_req, "arrival_rate": rate,
           "ttft_p50_s": _pct(ttfts, 50), "ttft_p99_s": _pct(ttfts, 99),
           "itl_p50_s": _pct(itls, 50), "itl_p99_s": _pct(itls, 99),
           "prefix_hit_rate": rep.get("prefix_hit_rate", 0.0),
           "prefix_hit_tokens": stats.prefix_hit_tokens,
           "prefix_exact": prefix_exact}
    print(f"\n-- online_serving (Poisson {rate:.0f} req/s, {n_req} reqs, "
          f"shared 24-token system prompt, prefix cache on) --\n"
          f"  TTFT p50={row['ttft_p50_s']*1e3:7.1f}ms "
          f"p99={row['ttft_p99_s']*1e3:7.1f}ms   "
          f"ITL p50={row['itl_p50_s']*1e3:6.1f}ms "
          f"p99={row['itl_p99_s']*1e3:6.1f}ms\n"
          f"  prefix: hit rate {row['prefix_hit_rate']:.2f} "
          f"({stats.prefix_hit_tokens} tokens never re-prefilled), "
          f"exact={prefix_exact:.0f} (zero recompute + offline "
          f"bit-identity)")
    rows.append(row)


def _sampling_epilogue(rows, quick: bool):
    """Fused sampling-epilogue microbench: the top-k partition fast path
    vs the full-vocab sort, both jitted, bit-identical by construction
    (asserted here on every run).  The ratio is gated (>= 1.15x at B<=8)
    by benchmarks/check_regression.py."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving.sampler import sample_batched

    B, V = 8, 32768
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    logits = jax.random.normal(ks[0], (B, V), jnp.float32)
    keys = jax.random.key_data(
        jax.random.split(ks[1], B)).astype(jnp.uint32)
    temp = jnp.full((B,), 0.8, jnp.float32)
    top_k = jnp.full((B,), 40, jnp.int32)
    top_p = jnp.full((B,), 0.95, jnp.float32)

    f_fast = jax.jit(lambda l, k: sample_batched(l, k, temp, top_k, top_p,
                                                 fast_path=True))
    f_sort = jax.jit(lambda l, k: sample_batched(l, k, temp, top_k, top_p,
                                                 fast_path=False))
    a = jax.block_until_ready(f_fast(logits, keys))      # compile
    b = jax.block_until_ready(f_sort(logits, keys))
    assert np.array_equal(np.asarray(a), np.asarray(b)), \
        "fast path is not bit-identical to the sort path"

    iters = 100 if quick else 400
    out = {}
    for name, fn in (("fast", f_fast), ("sorted", f_sort)):
        t0 = time.perf_counter()
        for _ in range(iters):
            r = fn(logits, keys)
        jax.block_until_ready(r)
        out[name] = (time.perf_counter() - t0) / iters
    ratio = out["sorted"] / out["fast"]
    print(f"\n-- sampling epilogue (B={B}, V={V}, top-k on) --\n"
          f"  fast   {out['fast'] * 1e6:8.1f} us/call\n"
          f"  sorted {out['sorted'] * 1e6:8.1f} us/call   "
          f"({ratio:.2f}x speedup)")
    rows.append({"bench": "sampling_fast", "policy": "epilogue",
                 "batch": B, "vocab": V,
                 "t_fast_us": out["fast"] * 1e6,
                 "t_sorted_us": out["sorted"] * 1e6, "ratio": ratio})


def _tracing_overhead(rows, quick: bool):
    """Flight-recorder overhead A/B: the decode-heavy workload with
    ``EngineConfig(trace=...)`` off vs on, same engine build otherwise.
    The recorder only appends host scalars the engine already holds
    (the obs-hot-path lint rule enforces that shape), so tracing must be
    near-free: the ``ratio`` (on/off decode tok/s, best-of-2 per arm) is
    gated at >= 0.95 by benchmarks/check_regression.py — an in-bench A/B,
    no baseline or machine margin involved.  The trace-on run's timeline
    is exported to ``bench_timeline.json`` and schema-validated here (CI
    re-checks the artifact with ``python -m repro.obs.timeline
    --check``)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_arch, reduced_config
    from repro.models import model as M
    from repro.models.common import Runtime
    from repro.obs.timeline import validate_chrome_trace, write_chrome_trace
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.llm import LLM, EngineConfig, SamplingParams

    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = reduced_config(get_arch("yi-9b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                      max_pages_per_seq=8)
    n_req = 6 if quick else 12
    max_new = 16 if quick else 24
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new)
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab_size, 8)) for _ in range(n_req)]

    def decode_tps(trace: bool):
        llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
            mb_size=2, num_microbatches=2, pool=pool, offload=True,
            backend="local", prefill_chunk=16,
            max_prefill_tokens_per_tick=32, trace=trace))
        llm.generate(prompts, sp, max_steps=5000)       # warmup pass
        best = 0.0
        for _ in range(2):                              # best-of-2 per arm
            stats = llm.engine.stats
            warm = (stats.decode_tokens, stats.decode_time_s)
            llm.generate(prompts, sp, max_steps=5000)
            stats = llm.engine.stats
            best = max(best, (stats.decode_tokens - warm[0]) /
                       max(stats.decode_time_s - warm[1], 1e-9))
        return best, llm.engine

    off_tps, _ = decode_tps(False)
    on_tps, eng = decode_tps(True)
    ratio = on_tps / max(off_tps, 1e-9)
    trace = write_chrome_trace(eng.recorder, "bench_timeline.json")
    errs = validate_chrome_trace(trace)
    assert not errs, f"trace-on timeline failed schema check: {errs[:3]}"
    print(f"\n-- tracing_overhead (decode-heavy, trace off vs on) --\n"
          f"  trace off {off_tps:8.1f} decode tok/s\n"
          f"  trace on  {on_tps:8.1f} decode tok/s   "
          f"({ratio:.3f}x — gate floor 0.95)\n"
          f"  timeline: {len(trace['traceEvents'])} events "
          f"({len(eng.recorder.events)} recorded, "
          f"{eng.recorder.dropped} dropped) -> bench_timeline.json")
    rows.append({"bench": "tracing_overhead", "policy": "flight_recorder",
                 "decode_tps_off": off_tps, "decode_tps_on": on_tps,
                 "ratio": ratio, "events": len(trace["traceEvents"])})


def run(quick: bool = False, workload: str = "all"):
    """``workload``: "all" (both engine workloads + Table 4), "decode" /
    "prefill_heavy" (one measured engine workload, no simulator pass),
    "online" (the Poisson online-serving workload through ``OnlineLLM``
    with prefix caching), "latency_curve" (throughput-vs-link-latency
    on the real engine over simulated WAN links, cross-checked against
    the DES), or "tracing" (the flight-recorder overhead A/B +
    ``bench_timeline.json`` export)."""
    rows = []
    if workload == "latency_curve":
        _latency_curve(rows, quick)
        return rows
    if workload == "online":
        _online_serving(rows, quick)
        return rows
    if workload == "tracing":
        _tracing_overhead(rows, quick)
        return rows
    _engine_backends(rows, quick, workload)
    _sampling_epilogue(rows, quick)
    if workload != "all":
        return rows
    _tracing_overhead(rows, quick)
    _online_serving(rows, quick)
    _latency_curve(rows, quick)         # virtual clock — CPU-cheap
    res = table4(sim_seconds=200 if quick else 400,
                 warmup=50 if quick else 100)
    print("\n== Table 4: output throughput (tok/s) vs one-way latency ==")
    hdr = "policy        " + "".join(f"{int(l*1000):>8d}ms" for l in LATS)
    print(hdr + "   (sim | paper)")
    for pol in ("vllm_pp", "deserve_pp", "deserve_opt"):
        line = f"{pol:14s}"
        for lat in LATS:
            line += f"{res[pol][lat].output_tps:10.1f}"
        paper = PAPER_TABLE4.get(pol, {})
        pline = " | paper: " + " ".join(
            f"{paper.get(l, float('nan')):7.1f}" for l in LATS)
        print(line + pline)
        for lat in LATS:
            rows.append({"bench": "table4", "policy": pol, "latency": lat,
                         "tps": res[pol][lat].output_tps,
                         "paper": paper.get(lat)})

    print("\n-- headline speedups (DeServe opt / vLLM pp) --")
    for lat in (0.016, 0.032, 0.064):
        s = res["deserve_opt"][lat].output_tps / \
            res["vllm_pp"][lat].output_tps
        pp = PAPER_TABLE4["deserve_opt"][lat] / PAPER_TABLE4["vllm_pp"][lat]
        print(f"  @{int(lat*1000):3d}ms: {s:5.1f}x   (paper: {pp:.1f}x)")
        rows.append({"bench": "speedup", "latency": lat, "speedup": s,
                     "paper_speedup": pp})
    o = res["deserve_opt"]
    flat = min(o[l].output_tps for l in LATS) / \
        max(o[l].output_tps for l in LATS)
    print(f"  DeServe(opt) flatness across 0-256 ms: {flat:.2f} "
          f"(paper: {442.9/458.5:.2f})")
    rows.append({"bench": "flatness", "value": flat})
    return rows
