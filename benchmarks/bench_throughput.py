"""Paper Table 4 (the headline): output throughput vs link latency for the
three serving policies, from the calibrated discrete-event simulator —
plus a measured engine comparison of the two execution backends on a
decode-heavy and a prefill-heavy (``--workload prefill_heavy``) workload."""

from repro.core.simulator import PAPER_TABLE4, table4

LATS = (0.0, 0.016, 0.032, 0.064, 0.256)


def _engine_backends(rows, quick: bool, workload: str = "all"):
    """Measured tok/s through the LLM front end on both execution backends
    (reduced config; pipelined runs 2 stages when the host has the
    devices, else a 1-stage pipe — same code path, no fake-device fork).
    Timing comes from the engine's own phase-split clock
    (``stats.prefill_time_s`` / ``stats.decode_time_s``), with warmup
    steps (jit compiles + pipe fill) snapshot-subtracted."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_arch, reduced_config
    from repro.models import model as M
    from repro.models.common import Runtime
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.llm import LLM, EngineConfig, SamplingParams

    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = reduced_config(get_arch("yi-9b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                      max_pages_per_seq=8)
    n_stages = 2 if len(jax.devices()) >= 2 else 1

    # two workloads: decode-heavy (short prompts, the Table-4 regime) and
    # prefill-heavy (long prompts, short generations — the open-model
    # serving regime chunked admission targets).  Both are recorded in
    # BENCH_throughput.json and gated by benchmarks/check_regression.py.
    workloads = {
        "engine_backend": dict(n_req=6 if quick else 12, prompt_len=8,
                               max_new=16 if quick else 24),
        "engine_prefill": dict(n_req=6 if quick else 12, prompt_len=48,
                               max_new=4),
    }
    if workload == "decode":
        workloads.pop("engine_prefill")
    elif workload == "prefill_heavy":
        workloads.pop("engine_backend")
    for bench, wl in workloads.items():
        print(f"\n-- {bench} (measured, reduced config, "
              f"prompt={wl['prompt_len']} max_new={wl['max_new']}) --")
        sp = SamplingParams(temperature=0.0, max_new_tokens=wl["max_new"])
        for backend in ("local", "pipelined"):
            llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
                mb_size=2, num_microbatches=2, pool=pool, offload=True,
                backend=backend, n_stages=n_stages, prefill_chunk=16,
                max_prefill_tokens_per_tick=32))
            rng = np.random.RandomState(0)
            # fixed prompt length: one prefill shape.  Warmup is a full
            # pass of the same workload, so every jit variant compiles
            # there and the timed pass is pure steady state.
            prompts = [list(rng.randint(1, cfg.vocab_size, wl["prompt_len"]))
                       for _ in range(wl["n_req"])]
            llm.generate(prompts, sp, max_steps=5000)       # warmup pass
            stats = llm.engine.stats
            warm = (stats.total_tokens, stats.decode_tokens,
                    stats.prefill_tokens, stats.wall_time_s,
                    stats.decode_time_s, stats.prefill_time_s)
            llm.generate(prompts, sp, max_steps=5000)       # timed pass
            rep = llm.stats()
            dt = rep["wall_time_s"] - warm[3]
            tps = (rep["total_tokens"] - warm[0]) / dt
            decode_tps = (rep["decode_tokens"] - warm[1]) / \
                max(rep["decode_time_s"] - warm[4], 1e-9)
            prefill_tps = (rep["prefill_tokens"] - warm[2]) / \
                max(rep["prefill_time_s"] - warm[5], 1e-9)
            print(f"  {backend:10s} {tps:8.1f} tok/s "
                  f"({decode_tps:.1f} decode tok/s, "
                  f"{prefill_tps:.1f} prefill tok/s, {rep['finished']} reqs, "
                  f"{rep['swaps']} swaps, mean latency "
                  f"{rep['mean_latency_steps']:.0f} steps, "
                  f"stages={n_stages if backend == 'pipelined' else 1})")
            rows.append({"bench": bench, "policy": backend,
                         "tps": tps, "decode_tps": decode_tps,
                         "prefill_tps": prefill_tps,
                         "tokens": rep["total_tokens"],
                         "swaps": rep["swaps"],
                         "mean_latency_steps": rep["mean_latency_steps"]})


def run(quick: bool = False, workload: str = "all"):
    """``workload``: "all" (both engine workloads + Table 4), "decode" or
    "prefill_heavy" (one measured engine workload, no simulator pass)."""
    rows = []
    _engine_backends(rows, quick, workload)
    if workload != "all":
        return rows
    res = table4(sim_seconds=200 if quick else 400,
                 warmup=50 if quick else 100)
    print("\n== Table 4: output throughput (tok/s) vs one-way latency ==")
    hdr = "policy        " + "".join(f"{int(l*1000):>8d}ms" for l in LATS)
    print(hdr + "   (sim | paper)")
    for pol in ("vllm_pp", "deserve_pp", "deserve_opt"):
        line = f"{pol:14s}"
        for lat in LATS:
            line += f"{res[pol][lat].output_tps:10.1f}"
        paper = PAPER_TABLE4.get(pol, {})
        pline = " | paper: " + " ".join(
            f"{paper.get(l, float('nan')):7.1f}" for l in LATS)
        print(line + pline)
        for lat in LATS:
            rows.append({"bench": "table4", "policy": pol, "latency": lat,
                         "tps": res[pol][lat].output_tps,
                         "paper": paper.get(lat)})

    print("\n-- headline speedups (DeServe opt / vLLM pp) --")
    for lat in (0.016, 0.032, 0.064):
        s = res["deserve_opt"][lat].output_tps / \
            res["vllm_pp"][lat].output_tps
        pp = PAPER_TABLE4["deserve_opt"][lat] / PAPER_TABLE4["vllm_pp"][lat]
        print(f"  @{int(lat*1000):3d}ms: {s:5.1f}x   (paper: {pp:.1f}x)")
        rows.append({"bench": "speedup", "latency": lat, "speedup": s,
                     "paper_speedup": pp})
    o = res["deserve_opt"]
    flat = min(o[l].output_tps for l in LATS) / \
        max(o[l].output_tps for l in LATS)
    print(f"  DeServe(opt) flatness across 0-256 ms: {flat:.2f} "
          f"(paper: {442.9/458.5:.2f})")
    rows.append({"bench": "flatness", "value": flat})
    return rows
