"""Paper Table 4 (the headline): output throughput vs link latency for the
three serving policies, from the calibrated discrete-event simulator."""

from repro.core.simulator import PAPER_TABLE4, table4

LATS = (0.0, 0.016, 0.032, 0.064, 0.256)


def run(quick: bool = False):
    rows = []
    res = table4(sim_seconds=200 if quick else 400,
                 warmup=50 if quick else 100)
    print("\n== Table 4: output throughput (tok/s) vs one-way latency ==")
    hdr = "policy        " + "".join(f"{int(l*1000):>8d}ms" for l in LATS)
    print(hdr + "   (sim | paper)")
    for pol in ("vllm_pp", "deserve_pp", "deserve_opt"):
        line = f"{pol:14s}"
        for lat in LATS:
            line += f"{res[pol][lat].output_tps:10.1f}"
        paper = PAPER_TABLE4.get(pol, {})
        pline = " | paper: " + " ".join(
            f"{paper.get(l, float('nan')):7.1f}" for l in LATS)
        print(line + pline)
        for lat in LATS:
            rows.append({"bench": "table4", "policy": pol, "latency": lat,
                         "tps": res[pol][lat].output_tps,
                         "paper": paper.get(lat)})

    print("\n-- headline speedups (DeServe opt / vLLM pp) --")
    for lat in (0.016, 0.032, 0.064):
        s = res["deserve_opt"][lat].output_tps / \
            res["vllm_pp"][lat].output_tps
        pp = PAPER_TABLE4["deserve_opt"][lat] / PAPER_TABLE4["vllm_pp"][lat]
        print(f"  @{int(lat*1000):3d}ms: {s:5.1f}x   (paper: {pp:.1f}x)")
        rows.append({"bench": "speedup", "latency": lat, "speedup": s,
                     "paper_speedup": pp})
    o = res["deserve_opt"]
    flat = min(o[l].output_tps for l in LATS) / \
        max(o[l].output_tps for l in LATS)
    print(f"  DeServe(opt) flatness across 0-256 ms: {flat:.2f} "
          f"(paper: {442.9/458.5:.2f})")
    rows.append({"bench": "flatness", "value": flat})
    return rows
