"""Paper Table 3: decode step time vs batch size.

Two views: (a) the paper's curve as interpolated by the simulator's stage
time model (the calibration input), and (b) a *measured* curve from our
engine's jitted decode step on a reduced model on this host — the claim
being reproduced is the *shape*: near-flat time until the arithmetic
intensity saturates, then linear growth (per-instance time collapsing
~b^-1 first, flattening later)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced_config
from repro.core.simulator import TABLE3_BATCH, TABLE3_MS, stage_time
from repro.models import model as M
from repro.models.common import Runtime


def run(quick: bool = False):
    rows = []
    print("\n== Table 3: batch size -> decode step time ==")
    print(f"{'batch':>6s} {'paper ms':>9s} {'interp ms':>10s} "
          f"{'host ms':>9s} {'host ms/seq':>12s}")

    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = reduced_config(get_arch("llama3-70b"), num_layers=4, d_model=128)
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    cap = 64
    batches = [1, 2, 4, 8, 16, 32] if quick else [1, 2, 4, 8, 16, 32, 64,
                                                  128]

    step = jax.jit(lambda p, t, c, cp: M.decode_step(p, t, c, cp, cfg, rt))
    host_ms = {}
    for b in batches:
        caches = M.init_caches(cfg, b, cap, rt)
        toks = jnp.zeros((b,), jnp.int32)
        pos = jnp.full((b,), 8, jnp.int32)
        logits, caches = step(params, toks, caches, pos)   # compile
        jax.block_until_ready(logits)
        n = 3 if quick else 10
        t0 = time.perf_counter()
        for _ in range(n):
            logits, caches = step(params, toks, caches, pos)
        jax.block_until_ready(logits)
        host_ms[b] = (time.perf_counter() - t0) / n * 1e3

    for i, b in enumerate(TABLE3_BATCH):
        interp = stage_time(b) * 1e3
        hm = host_ms.get(b)
        print(f"{b:6d} {TABLE3_MS[i]:9.1f} {interp:10.1f} "
              f"{hm if hm else float('nan'):9.2f} "
              f"{(hm / b) if hm else float('nan'):12.3f}")
        rows.append({"bench": "batch_curve", "batch": b,
                     "paper_ms": TABLE3_MS[i], "interp_ms": interp,
                     "host_ms": hm})
    # the reproduced property: sub-linear total time -> falling per-seq cost
    bs = sorted(host_ms)
    per_seq = [host_ms[b] / b for b in bs]
    assert per_seq[-1] < per_seq[0] / 2, "batching efficiency not visible"
    print("   (per-seq host time falls "
          f"{per_seq[0] / per_seq[-1]:.1f}x from b={bs[0]} to b={bs[-1]} — "
          "the Table 3 batching effect)")
    return rows
