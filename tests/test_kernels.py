"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape and
dtype sweeps per kernel, as required for every kernel in kernels/."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.rglru_scan import rglru_scan_pallas

FLASH_SWEEP = [
    # (b, sq, skv, h, hk, dh, causal, window, qblk, kvblk)
    (1, 64, 64, 2, 1, 64, True, 0, 32, 32),
    (2, 128, 128, 4, 2, 64, True, 0, 64, 64),
    (1, 96, 96, 8, 8, 128, True, 0, 32, 32),
    (1, 100, 100, 4, 2, 64, True, 0, 32, 32),     # non-multiple seq
    (1, 128, 128, 4, 2, 64, True, 48, 64, 64),    # sliding window
    (1, 64, 64, 2, 2, 64, False, 0, 32, 32),      # non-causal
    (1, 64, 64, 4, 1, 256, True, 0, 32, 32),      # big head dim (MQA)
]


@pytest.mark.parametrize("case", FLASH_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_vs_oracle(case, dtype):
    b, sq, skv, h, hk, dh, causal, window, qb, kb = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, skv, hk, dh), dtype)
    v = jax.random.normal(ks[2], (b, skv, hk, dh), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 q_blk=qb, kv_blk=kb, interpret=True)
    oracle = ref.flash_attention_ref(q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32),
                                     causal=causal, window=window,
                                     q_chunk=qb, kv_chunk=kb)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle), rtol=tol, atol=tol)


PAGED_SWEEP = [
    # (b, h, hk, dh, page, max_pages, n_pool, window)
    (2, 4, 2, 64, 8, 4, 16, 0),
    (3, 8, 1, 128, 16, 3, 8, 0),
    (2, 4, 4, 64, 8, 5, 32, 20),
    (1, 16, 2, 64, 8, 8, 64, 0),
    (4, 2, 2, 128, 32, 2, 8, 0),
]


@pytest.mark.parametrize("case", PAGED_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_vs_oracle(case, dtype):
    b, h, hk, dh, page, maxp, npool, win = case
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    kp = jax.random.normal(ks[1], (npool, page, hk, dh), dtype)
    vp = jax.random.normal(ks[2], (npool, page, hk, dh), dtype)
    pt = jax.random.randint(ks[3], (b, maxp), 0, npool)
    lens = jax.random.randint(ks[4], (b,), 1, maxp * page + 1)
    out = paged_decode_attention(q, kp, vp, pt, lens, window=win,
                                 interpret=True)
    oracle = ref.paged_decode_attention_ref(
        q.astype(jnp.float32), kp.astype(jnp.float32),
        vp.astype(jnp.float32), pt, lens, window=win)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle), rtol=tol, atol=tol)


RGLRU_SWEEP = [
    (1, 32, 128, 16, 128), (2, 100, 256, 32, 128), (3, 17, 128, 8, 128),
    (1, 257, 512, 64, 256),
]


@pytest.mark.parametrize("case", RGLRU_SWEEP)
def test_rglru_kernel_vs_oracle(case):
    b, s, dr, sblk, dblk = case
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, dr)))
    bb = jax.random.normal(ks[1], (b, s, dr))
    h0 = jax.random.normal(ks[2], (b, dr))
    out = rglru_scan_pallas(a, bb, h0, s_blk=sblk, d_blk=dblk,
                            interpret=True)
    oracle = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-5)


def test_ops_routing_falls_back_on_unaligned():
    """head dim 24 is not TPU-tileable -> jnp path, still correct."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 16, 2, 24))
    k = jax.random.normal(ks[1], (1, 16, 1, 24))
    v = jax.random.normal(ks[2], (1, 16, 1, 24))
    out = ops.flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    oracle = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=8,
                                     kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-6)


def test_ops_routing_uses_pallas_on_aligned():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 64))
    k = jax.random.normal(ks[1], (1, 64, 1, 64))
    v = jax.random.normal(ks[2], (1, 64, 1, 64))
    out = ops.flash_attention(q, k, v, causal=True)
    oracle = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-5)


def test_vmem_budget_static():
    from repro.kernels.flash_attention import vmem_bytes
    # default tiling must fit a v5e 16 MB VMEM comfortably for every
    # assigned head layout
    for g, dh in [(1, 64), (2, 128), (4, 256), (16, 128), (32, 64)]:
        assert vmem_bytes(128, 128, g, dh) < 12 * 2 ** 20, (g, dh)
