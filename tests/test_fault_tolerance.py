"""Fault-tolerant elastic serving: stage-fault injection (dropped decode
ticks / prefill chunks re-injected bit-transparently), straggler-fed
admission, and mid-run backend re-sharding with page-table replay —
verified through the shared cross-backend equivalence fixture
(tests/equivalence.py)."""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import tiny
from equivalence import (assert_equivalent, golden_runs, mixed_sps,
                         random_prompts, subprocess_env)
from repro.distributed.elastic import (FailureDetector, FaultEvent,
                                       FaultPlan, StragglerMitigator)
from repro.models import model as M
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.llm import EngineConfig
from repro.serving.request import SamplingParams

POOL = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                  max_pages_per_seq=8)
# all-local pool for the fast reshard tests (no offload traffic to keep
# them quick); engaged-offload migration is covered by
# test_reshard_migrates_engaged_offload_host_store and RESHARD_SCRIPT
LOCAL_POOL = PoolConfig(page_size=8, n_local_pages=48, n_global_pages=0,
                        max_pages_per_seq=8)


# ------------------------------------------------------------- FaultPlan ---

def test_fault_plan_parse_take_and_validation():
    fp = FaultPlan.parse(["drop@decode:12:1", "delay@prefill:3:0:0.25"])
    assert fp.pending() == 2 and bool(fp)
    assert fp.take("decode", 11) == []
    hit = fp.take("decode", 12)
    assert len(hit) == 1 and hit[0].stage == 1 and hit[0].kind == "drop"
    assert fp.take("decode", 12) == []          # consumed, fires once
    [ev] = fp.take("prefill", 3)
    assert ev.kind == "delay" and ev.delay_s == 0.25
    assert fp.pending() == 0 and not fp
    assert [e.tick for e in fp.triggered] == [12, 3]

    with pytest.raises(ValueError, match="fault spec"):
        FaultPlan.parse(["decode:12:1"])
    with pytest.raises(ValueError, match="plane"):
        FaultEvent("ring", 0, 0)
    with pytest.raises(ValueError, match="kind"):
        FaultEvent("decode", 0, 0, kind="explode")
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent("decode", -1, 0)


def test_fault_plan_gates(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    fp = FaultPlan([FaultEvent("decode", 0, 0)])
    # local backends have no stages to drop
    with pytest.raises(ValueError, match="pipelined"):
        OfflineEngine(cfg, params, rt, pool=POOL, fault_plan=fp)
    with pytest.raises(ValueError, match="pipelined"):
        EngineConfig(backend="local", fault_plan=fp)
    # recurrent state updates are cumulative: a replayed tick would
    # double-step them, so fault injection is gated to paged/ring archs
    rcfg = tiny("recurrentgemma-9b")
    rparams = M.init_params(rcfg, jax.random.PRNGKey(0), rt)
    with pytest.raises(ValueError, match="recurrent"):
        OfflineEngine(rcfg, rparams, rt, pool=POOL, backend="pipelined",
                      n_stages=1, mb_size=1, num_microbatches=1,
                      fault_plan=fp)
    # a stage index beyond the pipe depth is rejected at construction,
    # not as an IndexError mid-drill
    with pytest.raises(ValueError, match="stage"):
        OfflineEngine(cfg, params, rt, pool=POOL, backend="pipelined",
                      n_stages=1, mb_size=1, num_microbatches=1,
                      fault_plan=FaultPlan([FaultEvent("decode", 5, 3)]))


# ------------------------------------------ drop recovery (single stage) ---

def test_dropped_ticks_recovered_bit_identical(rt):
    """A dropped decode tick and a dropped prefill-chunk tick are
    re-injected by the engine: outputs (greedy AND sampled) stay
    bit-identical to an undisturbed pipelined run and to LocalBackend."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    prompts = random_prompts(cfg, 6, seed=3, lo=3, hi=16)
    sps = mixed_sps(6)
    fp = FaultPlan([FaultEvent("decode", 6, 0), FaultEvent("prefill", 1, 0)])
    common = dict(mb_size=2, num_microbatches=2, pool=POOL, offload=True,
                  prefill_chunk=4, max_prefill_tokens_per_tick=8)
    runs = golden_runs(cfg, params, rt, prompts, sps, {
        "local": dict(backend="local", **common),
        "pipelined": dict(backend="pipelined", n_stages=1, **common),
        "faulted": dict(backend="pipelined", n_stages=1, fault_plan=fp,
                        **common),
    })
    assert_equivalent(runs, base="local")
    assert fp.pending() == 0 and len(fp.triggered) == 2


def test_multi_fault_storm_recovered_bit_identical(rt):
    """A fault STORM — back-to-back decode drops in one recovery window
    (consecutive ticks, so the first re-injection is itself dropped)
    plus a pair of prefill-chunk drops — still recovers bit-identical:
    every lost tick is re-injected with the same tokens at the same
    positions, no matter how many land together."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    prompts = random_prompts(cfg, 6, seed=11, lo=4, hi=16)
    sps = mixed_sps(6)
    fp = FaultPlan([FaultEvent("decode", 5, 0), FaultEvent("decode", 6, 0),
                    FaultEvent("decode", 7, 0), FaultEvent("prefill", 1, 0),
                    FaultEvent("prefill", 2, 0)])
    common = dict(mb_size=2, num_microbatches=2, pool=POOL, offload=True,
                  prefill_chunk=4, max_prefill_tokens_per_tick=8)
    runs = golden_runs(cfg, params, rt, prompts, sps, {
        "local": dict(backend="local", **common),
        "stormed": dict(backend="pipelined", n_stages=1, fault_plan=fp,
                        **common),
    })
    assert_equivalent(runs, base="local")
    assert fp.pending() == 0 and len(fp.triggered) == 5


def test_lost_tick_stats_and_reinjection(rt):
    """The lost work is visible in stats, the plan is consumed, and the
    retry actually re-runs the work (extra backend ticks vs undisturbed)."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    prompts = random_prompts(cfg, 4, seed=1, lo=6, hi=14)
    sps = SamplingParams(temperature=0.0, max_new_tokens=4)

    def run(fault_plan):
        eng = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=2,
                            pool=POOL, backend="pipelined", n_stages=1,
                            prefill_chunk=4, fault_plan=fault_plan)
        from repro.serving.request import Request
        eng.submit([Request(i, p, sps) for i, p in enumerate(prompts)])
        done = eng.run(max_steps=400)
        assert len(done) == 4
        return eng

    clean = run(None)
    assert clean.stats.decode_ticks_lost == 0
    assert clean.stats.prefill_chunks_lost == 0
    fp = FaultPlan([FaultEvent("decode", 5, 0), FaultEvent("prefill", 1, 0)])
    faulted = run(fp)
    assert faulted.stats.decode_ticks_lost == 1
    assert faulted.stats.prefill_chunks_lost == 1
    # the lost prefill chunk was re-emitted, never double-counted
    assert faulted.stats.prefill_tokens == clean.stats.prefill_tokens
    assert faulted.stats.decode_tokens == clean.stats.decode_tokens
    # retrying costs backend ticks: the faulted pipe ticked more often
    assert faulted.backend._decode_ticks > clean.backend._decode_ticks \
        or faulted.backend._prefill_ticks > clean.backend._prefill_ticks


# ------------------------------------------------ straggler-fed admission ---

def test_delay_fault_lightens_prefill_admission(rt):
    """Delay observations feed the StragglerMitigator; while a stage is
    flagged, the per-tick prefill admission width shrinks (floored at one
    chunk) and recovers when the EWMA drains."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=2,
                        pool=POOL, backend="pipelined", n_stages=1,
                        prefill_chunk=2, max_prefill_tokens_per_tick=8)
    assert eng.prefill_rows == 4
    assert eng.straggler is not None
    assert eng._tick_prefill_rows() == 4        # cold: no straggler
    # a 4-stage mitigator with one slow stage (the engine logic is
    # stage-count agnostic — reshard swaps mitigators the same way)
    sm = StragglerMitigator(4)
    for _ in range(5):
        for s in range(3):
            sm.observe(s, 0.1)
        sm.observe(3, 1.0)
    eng.straggler = sm
    assert sm.stragglers() == [3]
    assert eng._tick_prefill_rows() < 4
    assert eng._tick_prefill_rows() >= 1        # never starves admission
    # straggler clears -> full width again
    for _ in range(50):
        sm.observe(3, 0.1)
    assert sm.stragglers() == []
    assert eng._tick_prefill_rows() == 4


def test_backend_stage_time_observations_reach_engine(rt):
    """Every decode tick yields one observation per stage, drained into
    the engine's mitigator (EWMA warm after a run)."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    from repro.serving.request import Request
    eng = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=2,
                        pool=POOL, backend="pipelined", n_stages=1,
                        prefill_chunk=4)
    eng.submit([Request(0, [3, 4, 5], SamplingParams(temperature=0.0,
                                                     max_new_tokens=3))])
    eng.run(max_steps=100)
    assert all(t > 0 for t in eng.straggler.ewma)
    assert eng.backend.drain_stage_times() == []    # drained every step


# ------------------------------------------------------- reshard (fast) ---

def _reshard_engine(rt, cfg, params, fault_plan=None, pool=LOCAL_POOL):
    return OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=2,
                         pool=pool, backend="pipelined", n_stages=1,
                         prefill_chunk=4, fault_plan=fault_plan)


def test_reshard_rejects_local_backend_and_overdeep_pipe(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=1,
                        pool=POOL)
    with pytest.raises(ValueError, match="pipelined"):
        eng.reshard(n_stages=1)
    peng = _reshard_engine(rt, cfg, params)
    with pytest.raises(ValueError, match="N_B >= N_S"):
        peng.reshard(n_stages=3)                # N_B=2 cannot feed 3 stages
    with pytest.raises(ValueError, match="live_devices"):
        peng.reshard()


def test_reshard_migrates_engaged_offload_host_store(rt):
    """Offloaded global pools hold per-stage host content keyed to the old
    split: reshard concatenates the per-stage ranges into full-period
    host arrays and re-splits them for the new stage count, so the
    swapped-out parity replays byte-identical — no token recomputed,
    outputs bit-identical to an undisturbed run."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    from repro.core.offload import DoubleBufferOffloader
    from repro.serving.request import Request
    pool = PoolConfig(page_size=8, n_local_pages=4, n_global_pages=16,
                      max_pages_per_seq=8)
    sp = SamplingParams(temperature=0.0, max_new_tokens=6)
    prompts = [list(range(3 + i, 12 + i)) for i in range(3)]

    def run(reshard_at=None):
        eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=2,
                            pool=pool, backend="pipelined", n_stages=1,
                            prefill_chunk=4,
                            offloader=DoubleBufferOffloader(pool, 2))
        seqs = eng.submit([Request(i, p, sp)
                           for i, p in enumerate(prompts)])
        snap = {}
        steps = 0
        while eng.step():
            steps += 1
            if steps == reshard_at:
                assert eng.backend.swap_count > 0, \
                    "offloader never engaged — the drill tests nothing"
                snap = {s.request.request_id: list(s.generated)
                        for s in seqs}
                eng.reshard(n_stages=1)
            assert steps < 500
        return ({s.request.request_id: tuple(s.generated) for s in seqs},
                snap, eng)

    ref, _, _ = run()
    out, snap, eng = run(reshard_at=8)
    assert eng.stats.reshards == 1
    assert any(snap.values()), "reshard happened before any token"
    for rid, toks in out.items():
        pre = snap.get(rid, [])
        assert list(toks[:len(pre)]) == pre, \
            f"request {rid} re-generated tokens across reshard"
    assert out == ref


def test_reshard_mid_run_replays_state_single_device(rt):
    """Mid-run teardown + rebuild + page-table replay on one device
    (stage count unchanged — the multi-device resize is the slow test):
    in-flight requests resume with no re-generated tokens and finish
    bit-identical to an undisturbed run."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    prompts = random_prompts(cfg, 5, seed=7, lo=4, hi=14)
    sps = mixed_sps(5, max_new=6)
    from repro.serving.request import Request

    def run(reshard_at=None):
        eng = _reshard_engine(rt, cfg, params)
        seqs = eng.submit([Request(i, p, sp)
                           for i, (p, sp) in enumerate(zip(prompts, sps))])
        snap = {}
        steps = 0
        while eng.step():
            steps += 1
            if steps == reshard_at:
                snap = {s.request.request_id: list(s.generated)
                        for s in seqs}
                old_backend = eng.backend
                # detector-driven: 7 live devices -> pow2 4, clamped by
                # N_B=2 and the single local device back to 1 stage
                fd = FailureDetector(timeout=5.0)
                for d in range(7):
                    fd.beat(d, now=0.0)
                plan = eng.reshard(detector=fd, now=1.0)
                assert eng.backend is not old_backend   # full rebuild
                assert eng.backend.n_stages == 1
                # a 1 -> 1 stage resize moves nothing: same data axis,
                # model axis preserved
                assert plan["batch_reshard"] is False
                assert plan["params_move"] is False
                # page table replayed into the fresh cache layout
                pt = np.asarray(
                    eng.backend.caches["scan"][0]["page_table"][0])
                np.testing.assert_array_equal(pt, eng.table)
            assert steps < 500
        return ({s.request.request_id: tuple(s.generated) for s in seqs},
                snap, eng)

    ref, _, _ = run()
    out, snap, eng = run(reshard_at=8)
    assert eng.stats.reshards == 1
    assert snap, "reshard happened before any token was generated"
    for rid, toks in out.items():
        pre = snap.get(rid, [])
        assert list(toks[:len(pre)]) == pre, \
            f"request {rid} re-generated tokens across reshard"
    assert out == ref


# -------------------------------------------- acceptance (SPMD subprocess) ---

FAULT_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from equivalence import assert_equivalent, golden_runs, mixed_sps, \
    random_prompts
from repro.config import get_arch, reduced_config
from repro.distributed.elastic import FaultEvent, FaultPlan
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.kv_cache import PoolConfig
import jax.numpy as jnp

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg = reduced_config(get_arch("yi-9b"))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                  max_pages_per_seq=8)
prompts = random_prompts(cfg, 6, seed=3, lo=3, hi=16)
sps = mixed_sps(6)
# one drop mid-decode on the drain stage, one mid-prefill-chunk on the
# inject stage, plus a synthetic straggle on stage 0 — offloading ON
fp = FaultPlan([FaultEvent("decode", 7, 1), FaultEvent("prefill", 2, 0),
                FaultEvent("decode", 4, 0, kind="delay", delay_s=5.0)])
common = dict(mb_size=2, num_microbatches=2, pool=pool, offload=True,
              prefill_chunk=4, max_prefill_tokens_per_tick=8)
runs = golden_runs(cfg, params, rt, prompts, sps, {
    "local": dict(backend="local", **common),
    "pipelined": dict(backend="pipelined", n_stages=2, **common),
    "faulted": dict(backend="pipelined", n_stages=2, fault_plan=fp,
                    **common),
})
assert_equivalent(runs, base="local")
assert fp.pending() == 0, fp.events
assert len(fp.triggered) == 3
print("FAULT-EQUIV-OK")
"""


@pytest.mark.slow
def test_fault_recovery_equivalence_across_backends():
    """Acceptance: with a FaultPlan dropping one stage tick mid-decode and
    one mid-prefill-chunk on the 2-stage pipe (offloading on, mixed
    greedy+sampled), the engine re-injects the lost work and final outputs
    are bit-identical to an undisturbed PipelinedBackend run and to
    LocalBackend — via the shared equivalence fixture."""
    r = subprocess.run([sys.executable, "-c", FAULT_EQUIV_SCRIPT],
                       env=subprocess_env(), capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "FAULT-EQUIV-OK" in r.stdout


RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
import jax.numpy as jnp
from equivalence import random_prompts
from repro.config import get_arch, reduced_config
from repro.distributed.elastic import FaultEvent, FaultPlan
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams

from repro.core.offload import DoubleBufferOffloader

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg0 = get_arch("yi-9b")
period = len(cfg0.block_pattern)
cfg = reduced_config(cfg0, num_layers=4 * period + 1)   # >= 4 stages
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
# small local pool + offloaded global pools: requests spill into the
# parity-swapped pools, so each reshard must migrate per-stage host
# stores across DIFFERENT layer splits (2 -> 4 -> 1 stages)
pool = PoolConfig(page_size=8, n_local_pages=10, n_global_pages=16,
                  max_pages_per_seq=8)
prompts = random_prompts(cfg, 8, seed=3, lo=3, hi=14)
sp = SamplingParams(temperature=0.0, max_new_tokens=8)

def build(n_stages, fault_plan=None):
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=4,
                        pool=pool, backend="pipelined", n_stages=n_stages,
                        prefill_chunk=4, fault_plan=fault_plan,
                        offloader=DoubleBufferOffloader(pool, 4))
    seqs = eng.submit([Request(i, p, sp) for i, p in enumerate(prompts)])
    return eng, seqs

ref_eng, ref_seqs = build(2)
ref_eng.run(max_steps=800)
ref = {s.request.request_id: tuple(s.generated) for s in ref_eng.finished}
assert len(ref) == 8

# the fault plan rides across both reshards: tick counters are carried, so
# the stage-0 drop at absolute decode tick 30 fires after the collapse to
# one stage; the stage-1 event either fires while stages >= 2 or is pruned
# at the 4 -> 1 reshard (a stage that no longer exists cannot fault)
fp = FaultPlan([FaultEvent("decode", 26, 1), FaultEvent("decode", 30, 0)])
eng, seqs = build(2, fault_plan=fp)
for _ in range(12):
    assert eng.step()
snap = {s.request.request_id: list(s.generated) for s in seqs}
assert any(snap.values()), "nothing in flight at the first reshard"
assert eng.backend.swap_count > 0, "offloader never engaged"
# a drop DURING the reshard drain: target the stage actually holding an
# in-flight payload at the very next decode tick — the drain flushes the
# pipe, the lost tick books nothing, the round-robin re-injects it after
# the rebuild
occ = [s for s, e in enumerate(eng.backend._entries) if e is not None]
assert occ, "pipe empty at the reshard point — drain-drop tests nothing"
fp.events.append(FaultEvent("decode", eng.backend._decode_ticks, occ[0]))
eng.reshard(n_stages=4)                       # a node joined
assert eng.backend.n_stages == 4
for _ in range(10):
    eng.step()
eng.reshard(live_devices=1)                   # nodes left: collapse to 1
assert eng.backend.n_stages == 1
eng.run(max_steps=800)
out = {s.request.request_id: tuple(s.generated) for s in eng.finished}
assert len(out) == 8
for rid, toks in out.items():
    pre = snap.get(rid, [])
    assert list(toks[:len(pre)]) == pre, (rid, pre, toks)
assert out == ref, (out, ref)
assert eng.stats.reshards == 2
# the stage-0 drop certainly fired (tick 30 < total decode ticks), the
# drain-tick drop fired during the first reshard's flush, and the whole
# plan is settled — triggered or pruned, never left dangling
assert eng.stats.decode_ticks_lost >= 2, eng.stats
assert fp.pending() == 0, fp.events
print("RESHARD-OK")
"""


@pytest.mark.slow
def test_reshard_mid_run_changes_stage_count():
    """Acceptance: a mid-run reshard to a different stage count (2 -> 4 on
    join, then -> 1 on loss) completes every in-flight request with no
    re-generated tokens — page table replayed, seq cursors preserved —
    and outputs bit-identical to an undisturbed 2-stage run."""
    r = subprocess.run([sys.executable, "-c", RESHARD_SCRIPT],
                       env=subprocess_env(), capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "RESHARD-OK" in r.stdout
