"""Execution-backend seam: LocalBackend row isolation, planned engine
construction, submit-time admission control, and Local == Pipelined greedy
equivalence through the N_S-stage shard_map pipe (subprocess, fake devices).
"""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.core import scheduler as SC
from repro.models import model as M
from repro.serving.backend import LocalBackend, PipelinedBackend
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams


def _per_slot_rows(caches, lo, hi):
    """Numpy snapshot of every per-slot leaf's rows [lo, hi)."""
    rows = []
    for part, axis in (("scan", 1), ("tail", 0)):
        for c in caches[part]:
            for k in sorted(c):
                if k.endswith("_pages"):
                    continue
                leaf = np.asarray(c[k])
                rows.append(leaf[:, lo:hi] if axis == 1 else leaf[lo:hi])
    return rows


def test_local_decode_touches_only_microbatch_rows(rt):
    """Satellite: decode feeds only the microbatch's mb_size view through
    the model — rows of other microbatches stay bit-identical."""
    cfg = tiny("recurrentgemma-9b")     # recurrent states + ring + paged
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=16, max_pages_per_seq=2)
    sp = SamplingParams(temperature=0.0, max_new_tokens=4)
    eng = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=2,
                        pool=pool, sampling=sp)
    rng = np.random.RandomState(0)
    eng.submit([Request(i, list(rng.randint(1, cfg.vocab_size, 4)), sp)
                for i in range(4)])
    assert eng.step()                   # admits all four, decodes mb 0
    before_mb0 = _per_slot_rows(eng.backend.caches, 0, 2)
    assert eng.step()                   # decodes mb 1: rows 2..4
    after_mb0 = _per_slot_rows(eng.backend.caches, 0, 2)
    for a, b in zip(before_mb0, after_mb0):
        np.testing.assert_array_equal(a, b)
    # sanity: mb 1's rows did change
    changed = any(
        not np.array_equal(a, b)
        for a, b in zip(_per_slot_rows(eng.backend.caches, 2, 4),
                        before_mb0))
    assert changed or eng.cur_pos[2] > 0


def test_submit_rejects_over_capacity_prompt(rt):
    """Satellite: a prompt that fills the whole per-sequence page budget
    would be admitted with zero generation budget — reject at submit."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=4, n_local_pages=16, max_pages_per_seq=2)
    sp = SamplingParams(temperature=0.0, max_new_tokens=8)
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=1,
                        pool=pool, sampling=sp)
    cap = pool.max_pages_per_seq * pool.page_size            # 8 tokens
    with pytest.raises(ValueError, match="KV capacity"):
        eng.submit([Request(0, list(range(1, cap + 1)), sp)])
    assert not eng.queue                # nothing was admitted
    # one token under capacity is admissible and yields exactly one token
    eng.submit([Request(1, list(range(1, cap)), sp)])
    done = eng.run(max_steps=50)
    assert len(done) == 1 and len(done[0].generated) == 1
    assert done[0].budget == 1


def test_from_plan_honors_schedule_choice(rt):
    """Satellite: a pre-computed ScheduleChoice is honored as-is — N_B,
    per-microbatch batch, and the offload pool split all follow it."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    choice = SC.ScheduleChoice(n_microbatches=3, per_mb_batch=2,
                               per_mb_kv_bytes=0.0, utilisation=1.0,
                               offload=True)
    pb = 2 * cfg.num_layers * 8 * cfg.num_kv_heads * cfg.head_dim * 4
    sp = SamplingParams(temperature=0.0, max_new_tokens=3)
    eng = OfflineEngine.from_plan(
        cfg, params, rt, n_stages=2, stage_time=0.1, latency=0.05,
        m_kv_bytes=64.0 * pb, bandwidth=160.0 * pb, page_size=8,
        max_pages_per_seq=4, choice=choice, sampling=sp)
    assert eng.num_microbatches == choice.n_microbatches
    assert eng.mb_size == choice.per_mb_batch
    assert eng.schedule_choice is choice
    assert eng.pool.n_global_pages > 0          # offload=True -> split pool
    assert eng.backend.name == "local"
    assert eng.backend.offloader is not None
    # and the planned engine actually serves
    rng = np.random.RandomState(1)
    eng.submit([Request(i, list(rng.randint(1, cfg.vocab_size, 4)), sp)
                for i in range(4)])
    assert len(eng.run(max_steps=200)) == 4


def test_from_plan_invokes_planner(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pb = 2 * cfg.num_layers * 8 * cfg.num_kv_heads * cfg.head_dim * 4
    eng = OfflineEngine.from_plan(
        cfg, params, rt, n_stages=2, stage_time=0.1, latency=0.02,
        m_kv_bytes=32.0 * pb, bandwidth=40.0 * pb, page_size=8,
        max_pages_per_seq=4, mb_size_cap=2, max_microbatches=8)
    assert eng.schedule_choice.n_microbatches >= 2      # >= n_stages
    assert eng.schedule_choice.n_microbatches <= 8
    assert eng.mb_size <= 2                             # cap applied


def test_plan_schedule_respects_max_microbatches():
    """Satellite: when the bubble-free N_B* exceeds the cap, the planner
    must stay at or under the cap (host memory bounds the pipe depth)."""
    n_star = SC.optimal_microbatches(4, 0.01, 1.0)
    assert n_star > 16
    choice = SC.plan_schedule(
        n_stages=4, stage_time=0.01, latency=1.0, m_kv_bytes=1e9,
        kv_bytes_per_seq=1e6, use_offload=True, max_microbatches=16)
    assert choice.n_microbatches <= 16
    with pytest.raises(ValueError, match="max_microbatches"):
        SC.plan_schedule(n_stages=4, stage_time=0.01, latency=0.0,
                         m_kv_bytes=1e9, kv_bytes_per_seq=1e6,
                         max_microbatches=2)


def test_pipelined_backend_rejects_shallow_queue(rt):
    """N_B < N_S would re-inject a microbatch before its previous tick
    drained — rejected at construction."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    with pytest.raises(ValueError, match="N_B >= N_S"):
        OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=1,
                      backend="pipelined", n_stages=2)


# ---------------------------------------------------------------- SPMD ---

EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from equivalence import assert_equivalent, golden_runs, random_prompts
from repro.config import get_arch, reduced_config
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import SamplingParams

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
arch = os.environ["PIPE_ARCH"]
cfg0 = get_arch(arch)
period = len(cfg0.block_pattern)
cfg = reduced_config(cfg0, num_layers=2 * period + (2 if period > 1 else 1))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
# offloading ON (n_global_pages > 0) and N_B=3 > 2 pools: microbatches 0
# and 2 contend for global pool parity 0 — the hard case
pool = PoolConfig(page_size=4, n_local_pages=32, n_global_pages=12,
                  max_pages_per_seq=6)
sp = SamplingParams(temperature=0.0, max_new_tokens=6)
# 10 requests > slots: replenishment while the pipe is in flight
prompts = random_prompts(cfg, 10, seed=7, lo=3, hi=10)
runs = golden_runs(cfg, params, rt, prompts, sp, {
    backend: dict(backend=backend, n_stages=2, mb_size=2,
                  num_microbatches=3, pool=pool, offload=True)
    for backend in ("local", "pipelined")}, max_steps=800)
assert_equivalent(runs, base="local")
print("OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi-9b", "gemma3-12b"])
def test_local_pipelined_greedy_equivalence(arch):
    """Acceptance: identical greedy token streams per request on
    LocalBackend vs PipelinedBackend, offloading enabled, continuous
    batching replenishing slots while the pipe is in flight."""
    from equivalence import subprocess_env
    r = subprocess.run([sys.executable, "-c", EQUIV_SCRIPT],
                       env=subprocess_env({"PIPE_ARCH": arch}),
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "OK" in r.stdout
