"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward/train step + prefill/decode on CPU, asserting shapes + no NaNs.
Also: prefill+decode consistency against a full-sequence forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ASSIGNED_ARCHS, tiny
from repro.models import model as M


def _batch(cfg, key, B=2, S=12):
    ks = jax.random.split(key, 3)
    out = {"labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "audio_frames":
        out["frames"] = jax.random.normal(ks[0], (B, S, cfg.d_model))
    elif cfg.frontend == "vision_patches":
        out["tokens"] = jax.random.randint(ks[0], (B, S), 1, cfg.vocab_size)
        out["patches"] = jax.random.normal(ks[1],
                                           (B, cfg.num_patch_tokens,
                                            cfg.d_model)) * 0.1
    else:
        out["tokens"] = jax.random.randint(ks[0], (B, S), 1, cfg.vocab_size)
    return out


@pytest.mark.parametrize("name", ASSIGNED_ARCHS + ["llama3-70b"])
def test_smoke_train_step(name, rt, key):
    cfg = tiny(name)
    params = M.init_params(cfg, key, rt)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: M.train_loss(p, batch, cfg, rt))(params)
    assert jnp.isfinite(loss), name
    assert float(loss) > 0.0
    gnorms = [float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms), name
    assert any(g > 0 for g in gnorms), f"{name}: all-zero grads"


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_smoke_prefill_decode(name, rt, key):
    cfg = tiny(name)
    params = M.init_params(cfg, key, rt)
    B, S, cap = 2, 10, 32
    batch = {k: v for k, v in _batch(cfg, key, B, S).items()
             if k != "labels"}
    logits, caches = M.prefill(params, batch, cfg, rt, cap)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos0 = S + (cfg.num_patch_tokens if cfg.frontend == "vision_patches"
                else 0)
    cur = jnp.full((B,), pos0, jnp.int32)
    logits2, caches = M.decode_step(params, tok, caches, cur, cfg, rt)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("name", ["yi-9b", "gemma3-12b", "recurrentgemma-9b",
                                  "xlstm-1.3b", "musicgen-large"])
def test_prefill_decode_matches_full_forward(name, rt, key):
    """Teacher-forced decode after prefill == one long prefill."""
    cfg = tiny(name)
    params = M.init_params(cfg, key, rt)
    B, S1, S2, cap = 1, 8, 4, 32
    toks = jax.random.randint(key, (B, S1 + S2), 1, cfg.vocab_size)
    inp = ({"frames": jax.random.normal(key, (B, S1 + S2, cfg.d_model))}
           if cfg.frontend == "audio_frames" else {"tokens": toks})
    # full prefill of S1+S2 gives logits at the last position
    full_logits, _ = M.prefill(params, inp, cfg, rt, cap)

    if cfg.frontend == "audio_frames":
        pytest.skip("frame frontend has no token-by-token decode of frames")
    # prefill S1 then teacher-force S2 tokens one at a time
    logits, caches = M.prefill(params, {"tokens": toks[:, :S1]}, cfg, rt, cap)
    step = jax.jit(lambda p, t, c, cp: M.decode_step(p, t, c, cp, cfg, rt))
    for i in range(S2):
        logits, caches = step(params, toks[:, S1 + i], caches,
                              jnp.full((B,), S1 + i, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_ce_loss_chunked_equals_unchunked(rt, key):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, key, rt)
    B, S = 2, 16
    x = jax.random.normal(key, (B, S, cfg.d_model))
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    l0 = M.ce_loss(params, x, labels, cfg, rt.replace(vocab_chunk=0))
    l1 = M.ce_loss(params, x, labels, cfg, rt.replace(vocab_chunk=4))
    l2 = M.ce_loss(params, x, labels, cfg, rt.replace(vocab_chunk=5))
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    np.testing.assert_allclose(float(l0), float(l2), rtol=1e-5)


def test_loss_mask(rt, key):
    cfg = tiny("minitron-4b")
    params = M.init_params(cfg, key, rt)
    B, S = 2, 8
    batch = _batch(cfg, key, B, S)
    m0 = jnp.ones((B, S), jnp.float32)
    full = M.train_loss(params, {**batch, "loss_mask": m0}, cfg, rt)
    # masking all but one position changes the loss to that position's nll
    m1 = jnp.zeros((B, S), jnp.float32).at[:, 3].set(1.0)
    part = M.train_loss(params, {**batch, "loss_mask": m1}, cfg, rt)
    assert not np.isclose(float(full), float(part))
    # scaling the mask must not change the mean
    m2 = m0 * 7.0
    scaled = M.train_loss(params, {**batch, "loss_mask": m2}, cfg, rt)
    np.testing.assert_allclose(float(full), float(scaled), rtol=1e-6)


def test_window_ring_cache_matches_big_cache(rt, key):
    """A ring cache of exactly window size must behave like a huge cache."""
    cfg = tiny("gemma3-1b")      # local pattern with window
    assert cfg.window_size > 0
    params = M.init_params(cfg, key, rt)
    B, S = 1, 6
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    logits_a, caches_a = M.prefill(params, {"tokens": toks}, cfg, rt,
                                   capacity=64)
    # decode past the window boundary with a jitted step
    step = jax.jit(lambda p, t, c, cp: M.decode_step(p, t, c, cp, cfg, rt))
    seq_a = []
    tok = jnp.argmax(logits_a, -1).astype(jnp.int32)
    for i in range(cfg.window_size + 6):
        seq_a.append(int(tok[0]))
        logits_a, caches_a = step(params, tok, caches_a,
                                  jnp.full((B,), S + i, jnp.int32))
        tok = jnp.argmax(logits_a, -1).astype(jnp.int32)
    assert all(np.isfinite(x) for x in seq_a)


def test_int8_kv_cache_decode(rt, key):
    """Quantized KV decode: argmax-identical on the smoke model, small err."""
    from conftest import tiny
    cfg = tiny("yi-9b")
    rt8 = rt.replace(kv_dtype="int8")
    params = M.init_params(cfg, key, rt)
    B, S = 2, 10
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    lg, ca = M.prefill(params, {"tokens": toks}, cfg, rt, 32)
    lg8, ca8 = M.prefill(params, {"tokens": toks}, cfg, rt8, 32)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg8))
    # int8 caches really are int8 + scales present
    c0 = ca8["scan"][0]
    assert c0["k"].dtype == jnp.int8 and "k_scale" in c0
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    cur = jnp.full((B,), S, jnp.int32)
    d, _ = M.decode_step(params, tok, ca, cur, cfg, rt)
    d8, _ = M.decode_step(params, tok, ca8, cur, cfg, rt8)
    assert bool(jnp.all(jnp.argmax(d, -1) == jnp.argmax(d8, -1)))
    assert float(jnp.max(jnp.abs(d - d8))) < 0.1


def test_int8_kv_windowed_ring(rt, key):
    """int8 KV on the sliding-window ring cache (gemma3 local layers)."""
    from conftest import tiny
    cfg = tiny("gemma3-1b")
    rt8 = rt.replace(kv_dtype="int8")
    params = M.init_params(cfg, key, rt8)
    toks = jax.random.randint(key, (1, 6), 1, cfg.vocab_size)
    lg, ca = M.prefill(params, {"tokens": toks}, cfg, rt8, 64)
    step = jax.jit(lambda p, t, c, cp: M.decode_step(p, t, c, cp, cfg, rt8))
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    for i in range(cfg.window_size + 4):     # cross the ring boundary
        lg, ca = step(params, tok, ca, jnp.full((1,), 6 + i, jnp.int32))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        assert bool(jnp.all(jnp.isfinite(lg)))
