"""Networked stage transport: link math, virtual-clock timelines,
wire-byte accounting, registry-driven deployment plans, and the
latency-hiding acceptance — bit-identical outputs across transports and
the planner-chosen circular schedule beating round-flush ≥ 3x at 64 ms
one-way link latency, on the real engine's virtual clock."""

import subprocess
import sys

import numpy as np
import pytest

from repro.core.scheduler import optimal_microbatches
from repro.core.simulator import PipelineSimulator, SimConfig, simulate_links
from repro.distributed.transport import (CompressedTransport, DeploymentPlan,
                                         InProcessTransport, LinkSpec,
                                         SimulatedLinkTransport,
                                         make_transport)
from repro.framework.registry import Registry, region_latency


# ---------------------------------------------------------------- links ---


def test_link_spec_delay_components():
    assert LinkSpec(0.05).delay(1 << 20) == 0.05
    assert LinkSpec(0.05, bandwidth_bps=1e6).delay(500_000) == \
        pytest.approx(0.55)
    rng = np.random.RandomState(0)
    jit = LinkSpec(0.05, jitter_s=0.01)
    ds = {jit.delay(0, rng) for _ in range(16)}
    assert all(0.05 <= d <= 0.06 for d in ds) and len(ds) > 1
    assert jit.delay(0, None) == 0.05           # jitter needs an rng
    with pytest.raises(ValueError):
        LinkSpec(-0.1)


def test_make_transport_factory():
    assert isinstance(make_transport(None, 2), InProcessTransport)
    assert isinstance(make_transport(0.05, 3), SimulatedLinkTransport)
    t = SimulatedLinkTransport.uniform(2, 0.01)
    assert make_transport(t, 2) is t
    with pytest.raises(ValueError, match="link"):
        make_transport(t, 3)                    # ring size mismatch
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("warp", 2)


# ----------------------------------------------------- virtual timeline ---


def _run_schedule(n_stages, n_b, T, L, tokens, flush):
    """Token-level emulation of both schedules over the pure timeline —
    exactly the shift-register sequence ``PipelinedBackend._decode_tick``
    drives.  Returns virtual seconds per drained token."""
    tr = SimulatedLinkTransport.uniform(n_stages, L, stage_time_s=T)
    pipe = [None] * n_stages
    ret = {}
    drained = 0

    def tick(inject_mb):
        nonlocal pipe, drained
        entries = list(pipe)
        entries[0] = inject_mb
        occ = [e is not None for e in entries]
        if any(occ):
            obs = tr.tick(occ, 1024, [0.0] * n_stages,
                          inject_t=ret.get(inject_mb, 0.0)
                          if inject_mb is not None else 0.0)
            if entries[-1] is not None:
                ret[entries[-1]] = obs.return_ready
                drained += 1
        pipe = [None] + entries[:-1]

    injected, last_mb, guard = 0, -1, 0
    while drained < tokens:
        mb = injected % n_b
        if flush and mb <= last_mb:
            while any(e is not None for e in pipe):
                tick(None)
            last_mb = -1
        tick(mb)
        last_mb = mb
        injected += 1
        guard += 1
        assert guard < 100 * tokens, "schedule emulation diverged"
    return tr.clock.now / drained


def test_circular_hides_latency_round_flush_pays_it():
    """The §4.3 mechanics on the pure timeline (no jax): with the
    planner's N_B*, steady-state cost per token is T_S regardless of L;
    with round-flush N_B = N_S it is ~(T_S + L).  The 64 ms acceptance
    ratio (≥ 3x) must already hold at this layer."""
    T, L, n_s = 0.016, 0.064, 2
    n_star = optimal_microbatches(n_s, T, L)
    assert n_star == 10                         # ceil(2 * 0.080 / 0.016)
    per_tok_circ = _run_schedule(n_s, n_star, T, L, tokens=120, flush=False)
    per_tok_rf = _run_schedule(n_s, n_s, T, L, tokens=120, flush=True)
    assert per_tok_circ == pytest.approx(T, rel=0.15)   # latency hidden
    assert per_tok_rf >= T + L / n_s                    # latency paid
    assert per_tok_rf / per_tok_circ >= 3.0
    # under-provisioned circular (N_B < N_B*) must stall
    per_tok_starved = _run_schedule(n_s, n_s, T, L, tokens=120, flush=False)
    assert per_tok_starved > 1.5 * per_tok_circ


def test_zero_latency_schedules_tie():
    T = 0.01
    a = _run_schedule(1, 4, T, 0.0, tokens=60, flush=False)
    b = _run_schedule(1, 1, T, 0.0, tokens=60, flush=True)
    assert a == pytest.approx(b, rel=0.05) == pytest.approx(T, rel=0.05)


def test_stall_lands_on_the_stage_behind_the_slow_link():
    """Heterogeneous ring: the stage *downstream* of the slow link is the
    one that waits — the observation straggler mitigation needs.  Once
    the pipe is full the downstream stage runs offset-but-busy (that is
    the latency-hiding), so the stall shows on the fill transition."""
    tr = SimulatedLinkTransport([LinkSpec(0.2), LinkSpec(0.0)],
                                stage_time_s=0.01).bind(2)
    stalls = np.zeros((2,))
    entries = [None, None]
    for k in range(8):
        entries = [k] + entries[:-1]            # distinct mbs: the stall
        occ = [e is not None for e in entries]  # can only come from the
        obs = tr.tick(occ, 64, [0.0, 0.0])      # inter-stage link
        stalls += obs.stalls
    assert stalls[1] >= 0.19                    # the 200ms link's wait
    assert stalls[0] == 0.0                     # injections never gated


def test_inprocess_transport_is_free_and_silent():
    tr = InProcessTransport().bind(3)
    obs = tr.tick([True, True, True], 1 << 20, [1.0, 1.0, 1.0])
    assert not obs.stalls.any() and obs.return_ready == 0.0
    assert tr.stats() == {}


def test_for_stages_retargets_and_carries_the_clock():
    tr = SimulatedLinkTransport([LinkSpec(0.01), LinkSpec(0.2)],
                                stage_time_s=0.01).bind(2)
    tr.tick([True, True], 128, [0.0, 0.0])
    before = tr.clock.now
    assert before > 0
    shrunk = tr.for_stages(1)
    assert len(shrunk.links) == 1
    assert shrunk.links[0].latency_s == 0.2     # worst-link envelope
    assert shrunk.clock.now == before           # accounting continuity
    same = tr.for_stages(2)
    assert [l.latency_s for l in same.links] == [0.01, 0.2]


# ------------------------------------------------------ wire accounting ---


def test_compressed_transport_wire_bytes():
    inner = SimulatedLinkTransport.uniform(2, 0.0, stage_time_s=0.01)
    tr = CompressedTransport(inner, method="int8").bind(2)
    nbytes = 4096                               # 1024 f32 activations
    for k in range(4):
        tr.tick([True, True], nbytes, [0.0, 0.0])
    st = tr.stats()
    # int8: ~4x on the wire (1 byte/elem + scale), plus tiny return
    # payloads that the inner link books uncompressed
    assert st["raw_bytes"] == 4 * nbytes        # one boundary send/tick
    assert 3.0 < st["compression_ratio"] < 4.1
    assert st["transport"].startswith("compressed[int8]>")
    with pytest.raises(ValueError, match="int8"):
        CompressedTransport(inner, method="gzip")


def test_compressed_topk_fraction_scales_wire_bytes():
    a = CompressedTransport(SimulatedLinkTransport.uniform(
        2, 0.0, stage_time_s=0.01), method="topk", topk_frac=0.01).bind(2)
    b = CompressedTransport(SimulatedLinkTransport.uniform(
        2, 0.0, stage_time_s=0.01), method="topk", topk_frac=0.10).bind(2)
    assert a._wire(40_000) < b._wire(40_000)
    # top-k wire bytes = k * (value + index)
    assert a._wire(40_000) == max(1, int(10_000 * 0.01)) * 8


# ----------------------------------------------------- deployment plans ---


def test_deployment_plan_from_regions():
    plan = DeploymentPlan.from_regions(["us-west", "us-west", "us-east"])
    assert plan.n_stages == 3
    assert plan.link_latencies == [0.002, 0.058, 0.058]
    assert plan.max_link_latency == 0.058
    assert plan.max_pairwise_latency == 0.058
    tr = plan.transport(stage_time_s=0.01)
    assert isinstance(tr, SimulatedLinkTransport)
    assert [l.latency_s for l in tr.links] == plan.link_latencies
    assert isinstance(plan.transport(compress="int8"), CompressedTransport)
    assert "--58ms-->" in plan.describe()


def test_deployment_plan_from_registry_match():
    """The registry's latency-minimising match output IS the deployment:
    stage order = machine order, links priced from the region table."""
    reg = Registry()
    for i in range(2):
        reg.register_machine(f"w{i}", 24 << 30, "us-west", stake=100)
    reg.register_machine("e0", 24 << 30, "us-east", stake=100)
    t = reg.register_task("alice", "m", 55 << 30, 4, 1.0)   # needs all 3
    m = reg.match(t.task_id)
    assert m is not None and m.n_stages == 3
    plan = DeploymentPlan.from_match(m)
    assert plan.n_stages == 3
    assert plan.regions == [x.region for x in m.machines]
    assert plan.max_pairwise_latency == pytest.approx(m.max_latency)
    assert plan.max_link_latency <= m.max_latency
    assert plan.machines is m.machines or plan.machines == m.machines
    # the planner consumes the slowest ring link
    from repro.serving.llm import EngineConfig
    cfg = EngineConfig.plan(deployment=plan, stage_time=0.05,
                            m_kv_bytes=1e6, backend="pipelined")
    assert cfg.n_stages == 3
    assert cfg.plan_args["latency"] == plan.max_link_latency
    assert isinstance(cfg.transport, SimulatedLinkTransport)


def test_deployment_plan_validation_and_uniform():
    with pytest.raises(ValueError, match="inconsistent"):
        DeploymentPlan(stages=["a", "b"], regions=["x"],
                       latency_matrix=np.zeros((2, 2)))
    plan = DeploymentPlan.uniform(4, 0.064)
    assert plan.link_latencies == [0.064] * 4
    assert plan.max_link_latency == 0.064


def test_engine_config_plan_requires_geometry():
    from repro.serving.llm import EngineConfig
    with pytest.raises(ValueError, match="n_stages"):
        EngineConfig.plan(stage_time=0.05, m_kv_bytes=1e6)


def test_engine_config_rejects_transport_on_local_backend():
    from repro.serving.llm import EngineConfig
    with pytest.raises(ValueError, match="pipelined"):
        EngineConfig(backend="local", transport=0.05)
    with pytest.raises(ValueError, match="pipelined"):
        EngineConfig(backend="local", schedule="round_flush")
    with pytest.raises(ValueError, match="schedule"):
        EngineConfig(backend="pipelined", num_microbatches=2,
                     schedule="eager")


# ------------------------------------------------ simulator cross-check ---


def test_simulator_per_link_uniform_matches_scalar():
    for pol in ("vllm_pp", "deserve_pp", "deserve_opt"):
        a = PipelineSimulator(SimConfig(
            policy=pol, n_stages=4, latency=0.032,
            sim_seconds=120, warmup_seconds=30)).run()
        b = PipelineSimulator(SimConfig(
            policy=pol, n_stages=4, link_latencies=(0.032,) * 4,
            sim_seconds=120, warmup_seconds=30)).run()
        assert a.output_tps == pytest.approx(b.output_tps, abs=1e-9)


def test_simulator_heterogeneous_links():
    het = (0.002, 0.002, 0.002, 0.128)
    circ = simulate_links("deserve_pp", het, sim_seconds=120, warmup=30)
    rf = simulate_links("vllm_pp", het, sim_seconds=120, warmup=30)
    assert circ.output_tps > rf.output_tps
    # one slow link costs the circular ring only its share of the sum;
    # a uniform ring at the same max latency must be strictly worse
    uni = PipelineSimulator(SimConfig(
        policy="deserve_pp", n_stages=4, latency=0.128,
        sim_seconds=120, warmup_seconds=30)).run()
    assert circ.output_tps >= uni.output_tps
    with pytest.raises(ValueError, match="link_latencies"):
        SimConfig(n_stages=4, link_latencies=(0.1, 0.1))


# --------------------------------------- real engine, fast (one device) ---


@pytest.fixture(scope="module")
def tiny_llm_setup():
    import jax
    import jax.numpy as jnp

    from conftest import tiny
    from repro.models import model as M
    from repro.models.common import Runtime
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    return cfg, params, rt


def test_transport_equivalence_and_speedup_one_stage(tiny_llm_setup):
    """Acceptance (1-stage form, in-process): InProcess vs SimulatedLink
    at L = 64 ms produce bit-identical streams — greedy AND sampled,
    chunked prefill exercising the prefill plane — and the planner-N_B
    circular schedule beats round-flush N_B = N_S ≥ 3x on the virtual
    clock.  The 2-stage SPMD form runs in the slow suite."""
    from equivalence import (assert_equivalent, mixed_sps, random_prompts,
                             run_llm)
    from repro.serving.kv_cache import PoolConfig
    cfg, params, rt = tiny_llm_setup
    pool = PoolConfig(page_size=4, n_local_pages=32, n_global_pages=0,
                      max_pages_per_seq=6)
    T, L = 0.016, 0.064
    n_star = optimal_microbatches(1, T, L)      # 5
    prompts = random_prompts(cfg, n_star, seed=3, lo=3, hi=8)
    sps = mixed_sps(n_star, max_new=6)
    common = dict(backend="pipelined", n_stages=1, mb_size=1, pool=pool,
                  offload=False, prefill_chunk=8)
    runs = {}
    runs["inproc"], _ = run_llm(cfg, params, rt, prompts, sps,
                                num_microbatches=n_star, **common)
    runs["simlink"], llm_circ = run_llm(
        cfg, params, rt, prompts, sps, num_microbatches=n_star,
        transport=SimulatedLinkTransport.uniform(1, L, stage_time_s=T),
        **common)
    runs["round_flush"], llm_rf = run_llm(
        cfg, params, rt, prompts, sps, num_microbatches=1,
        schedule="round_flush",
        transport=SimulatedLinkTransport.uniform(1, L, stage_time_s=T),
        **common)
    assert_equivalent(runs, base="inproc")

    rep_c, rep_rf = llm_circ.stats(), llm_rf.stats()
    assert rep_c["transport"]["virtual_time_s"] > 0
    assert rep_c["transport"]["max_link_latency_s"] == L
    ratio = rep_c["virtual_decode_tok_per_s"] / \
        rep_rf["virtual_decode_tok_per_s"]
    assert ratio >= 3.0, f"circular/round_flush = {ratio:.2f} < 3x"
    # and the InProcess run keeps no books
    out, llm_ip = run_llm(cfg, params, rt, prompts[:1], sps[:1],
                          num_microbatches=1, **common)
    assert "transport" not in llm_ip.stats()


def test_transport_survives_reshard(tiny_llm_setup):
    """for_stages carries the link policy through Engine.reshard: a
    1 → 1 stage rebuild keeps the simulated link and its clock."""
    from equivalence import random_prompts
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.llm import LLM, EngineConfig
    from repro.serving.request import SamplingParams
    cfg, params, rt = tiny_llm_setup
    pool = PoolConfig(page_size=4, n_local_pages=32, n_global_pages=0,
                      max_pages_per_seq=6)
    llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
        backend="pipelined", n_stages=1, mb_size=1, num_microbatches=2,
        pool=pool, offload=False, transport=0.032))
    prompts = random_prompts(cfg, 2, seed=5, lo=3, hi=6)
    sp = SamplingParams(temperature=0.0, max_new_tokens=6)
    step = 0
    for _ in llm.generate_iter(prompts, sp, max_steps=300):
        step += 1
        if step == 6:
            vt_before = llm.engine.backend.transport.clock.now
            assert vt_before > 0
            llm.engine.reshard(n_stages=1)
            tr = llm.engine.backend.transport
            assert isinstance(tr, SimulatedLinkTransport)
            assert tr.clock.now >= vt_before
    assert llm.engine.stats.reshards == 1
    assert llm.stats()["transport"]["virtual_time_s"] >= vt_before


# ------------------------------------------------- SPMD acceptance (2x) ---


ACCEPT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from equivalence import assert_equivalent, mixed_sps, random_prompts, run_llm
from repro.config import get_arch, reduced_config
from repro.core.scheduler import optimal_microbatches
from repro.distributed.transport import SimulatedLinkTransport
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.kv_cache import PoolConfig

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg = reduced_config(get_arch("yi-9b"))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=4, n_local_pages=64, n_global_pages=0,
                  max_pages_per_seq=6)
T, L = 0.016, 0.064
n_star = optimal_microbatches(2, T, L)          # 10
prompts = random_prompts(cfg, n_star, seed=7, lo=3, hi=9)
sps = mixed_sps(n_star, max_new=8)              # greedy AND sampled
common = dict(backend="pipelined", n_stages=2, mb_size=1, pool=pool,
              offload=False, prefill_chunk=8)
runs = {}
runs["inproc"], _ = run_llm(cfg, params, rt, prompts, sps,
                            num_microbatches=n_star, **common)
runs["simlink"], llm_c = run_llm(
    cfg, params, rt, prompts, sps, num_microbatches=n_star,
    transport=SimulatedLinkTransport.uniform(2, L, stage_time_s=T), **common)
runs["round_flush"], llm_rf = run_llm(
    cfg, params, rt, prompts, sps, num_microbatches=2,
    schedule="round_flush",
    transport=SimulatedLinkTransport.uniform(2, L, stage_time_s=T), **common)
assert_equivalent(runs, base="inproc")
ratio = llm_c.stats()["virtual_decode_tok_per_s"] / \
    llm_rf.stats()["virtual_decode_tok_per_s"]
assert ratio >= 3.0, f"circular/round_flush = {ratio:.2f} < 3x at 64ms"
print(f"OK ratio={ratio:.2f}")
"""


@pytest.mark.slow
def test_acceptance_two_stage_spmd():
    """ISSUE 5 acceptance: at L = 64 ms one-way on the 2-stage SPMD pipe,
    the planner-chosen N_B circular schedule ≥ 3x round-flush N_B = N_S
    decode tok/s (virtual clock), with InProcess and SimulatedLink runs
    bit-identical (greedy + sampled, decode and prefill planes)."""
    from equivalence import subprocess_env
    r = subprocess.run([sys.executable, "-c", ACCEPT_SCRIPT],
                       env=subprocess_env(), capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "OK ratio=" in r.stdout
