"""Networked stage transport: link math, virtual-clock timelines,
wire-byte accounting, registry-driven deployment plans, and the
latency-hiding acceptance — bit-identical outputs across transports and
the planner-chosen circular schedule beating round-flush ≥ 3x at 64 ms
one-way link latency, on the real engine's virtual clock."""

import subprocess
import sys

import numpy as np
import pytest

from repro.core.scheduler import optimal_microbatches
from repro.core.simulator import PipelineSimulator, SimConfig, simulate_links
from repro.distributed.transport import (CompressedTransport, DeploymentPlan,
                                         InProcessTransport, LinkSpec,
                                         SimulatedLinkTransport,
                                         make_transport)
from repro.framework.registry import Registry, region_latency


# ---------------------------------------------------------------- links ---


def test_link_spec_delay_components():
    assert LinkSpec(0.05).delay(1 << 20) == 0.05
    assert LinkSpec(0.05, bandwidth_bps=1e6).delay(500_000) == \
        pytest.approx(0.55)
    rng = np.random.RandomState(0)
    jit = LinkSpec(0.05, jitter_s=0.01)
    ds = {jit.delay(0, rng) for _ in range(16)}
    assert all(0.05 <= d <= 0.06 for d in ds) and len(ds) > 1
    assert jit.delay(0, None) == 0.05           # jitter needs an rng
    with pytest.raises(ValueError):
        LinkSpec(-0.1)


def test_make_transport_factory():
    assert isinstance(make_transport(None, 2), InProcessTransport)
    assert isinstance(make_transport(0.05, 3), SimulatedLinkTransport)
    t = SimulatedLinkTransport.uniform(2, 0.01)
    assert make_transport(t, 2) is t
    with pytest.raises(ValueError, match="link"):
        make_transport(t, 3)                    # ring size mismatch
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("warp", 2)


# ----------------------------------------------------- virtual timeline ---


def _run_schedule(n_stages, n_b, T, L, tokens, flush):
    """Token-level emulation of both schedules over the pure timeline —
    exactly the shift-register sequence ``PipelinedBackend._decode_tick``
    drives.  Returns virtual seconds per drained token."""
    tr = SimulatedLinkTransport.uniform(n_stages, L, stage_time_s=T)
    pipe = [None] * n_stages
    ret = {}
    drained = 0

    def tick(inject_mb):
        nonlocal pipe, drained
        entries = list(pipe)
        entries[0] = inject_mb
        occ = [e is not None for e in entries]
        if any(occ):
            obs = tr.tick(occ, 1024, [0.0] * n_stages,
                          inject_t=ret.get(inject_mb, 0.0)
                          if inject_mb is not None else 0.0)
            if entries[-1] is not None:
                ret[entries[-1]] = obs.return_ready
                drained += 1
        pipe = [None] + entries[:-1]

    injected, last_mb, guard = 0, -1, 0
    while drained < tokens:
        mb = injected % n_b
        if flush and mb <= last_mb:
            while any(e is not None for e in pipe):
                tick(None)
            last_mb = -1
        tick(mb)
        last_mb = mb
        injected += 1
        guard += 1
        assert guard < 100 * tokens, "schedule emulation diverged"
    return tr.clock.now / drained


def test_circular_hides_latency_round_flush_pays_it():
    """The §4.3 mechanics on the pure timeline (no jax): with the
    planner's N_B*, steady-state cost per token is T_S regardless of L;
    with round-flush N_B = N_S it is ~(T_S + L).  The 64 ms acceptance
    ratio (≥ 3x) must already hold at this layer."""
    T, L, n_s = 0.016, 0.064, 2
    n_star = optimal_microbatches(n_s, T, L)
    assert n_star == 10                         # ceil(2 * 0.080 / 0.016)
    per_tok_circ = _run_schedule(n_s, n_star, T, L, tokens=120, flush=False)
    per_tok_rf = _run_schedule(n_s, n_s, T, L, tokens=120, flush=True)
    assert per_tok_circ == pytest.approx(T, rel=0.15)   # latency hidden
    assert per_tok_rf >= T + L / n_s                    # latency paid
    assert per_tok_rf / per_tok_circ >= 3.0
    # under-provisioned circular (N_B < N_B*) must stall
    per_tok_starved = _run_schedule(n_s, n_s, T, L, tokens=120, flush=False)
    assert per_tok_starved > 1.5 * per_tok_circ


def test_zero_latency_schedules_tie():
    T = 0.01
    a = _run_schedule(1, 4, T, 0.0, tokens=60, flush=False)
    b = _run_schedule(1, 1, T, 0.0, tokens=60, flush=True)
    assert a == pytest.approx(b, rel=0.05) == pytest.approx(T, rel=0.05)


def test_stall_lands_on_the_stage_behind_the_slow_link():
    """Heterogeneous ring: the stage *downstream* of the slow link is the
    one that waits — the observation straggler mitigation needs.  Once
    the pipe is full the downstream stage runs offset-but-busy (that is
    the latency-hiding), so the stall shows on the fill transition."""
    tr = SimulatedLinkTransport([LinkSpec(0.2), LinkSpec(0.0)],
                                stage_time_s=0.01).bind(2)
    stalls = np.zeros((2,))
    entries = [None, None]
    for k in range(8):
        entries = [k] + entries[:-1]            # distinct mbs: the stall
        occ = [e is not None for e in entries]  # can only come from the
        obs = tr.tick(occ, 64, [0.0, 0.0])      # inter-stage link
        stalls += obs.stalls
    assert stalls[1] >= 0.19                    # the 200ms link's wait
    assert stalls[0] == 0.0                     # injections never gated


def test_inprocess_transport_is_free_and_silent():
    tr = InProcessTransport().bind(3)
    obs = tr.tick([True, True, True], 1 << 20, [1.0, 1.0, 1.0])
    assert not obs.stalls.any() and obs.return_ready == 0.0
    assert tr.stats() == {}


def test_for_stages_retargets_and_carries_the_clock():
    tr = SimulatedLinkTransport([LinkSpec(0.01), LinkSpec(0.2)],
                                stage_time_s=0.01).bind(2)
    tr.tick([True, True], 128, [0.0, 0.0])
    before = tr.clock.now
    assert before > 0
    shrunk = tr.for_stages(1)
    assert len(shrunk.links) == 1
    assert shrunk.links[0].latency_s == 0.2     # worst-link envelope
    assert shrunk.clock.now == before           # accounting continuity
    same = tr.for_stages(2)
    assert [l.latency_s for l in same.links] == [0.01, 0.2]


# ------------------------------------------------------ wire accounting ---


def test_compressed_transport_wire_bytes():
    inner = SimulatedLinkTransport.uniform(2, 0.0, stage_time_s=0.01)
    tr = CompressedTransport(inner, method="int8").bind(2)
    nbytes = 4096                               # 1024 f32 activations
    for k in range(4):
        tr.tick([True, True], nbytes, [0.0, 0.0])
    st = tr.stats()
    # int8: ~4x on the wire (1 byte/elem + scale), plus tiny return
    # payloads that the inner link books uncompressed
    assert st["raw_bytes"] == 4 * nbytes        # one boundary send/tick
    assert 3.0 < st["compression_ratio"] < 4.1
    assert st["transport"].startswith("compressed[int8]>")
    with pytest.raises(ValueError, match="int8"):
        CompressedTransport(inner, method="gzip")


def test_compressed_topk_fraction_scales_wire_bytes():
    a = CompressedTransport(SimulatedLinkTransport.uniform(
        2, 0.0, stage_time_s=0.01), method="topk", topk_frac=0.01).bind(2)
    b = CompressedTransport(SimulatedLinkTransport.uniform(
        2, 0.0, stage_time_s=0.01), method="topk", topk_frac=0.10).bind(2)
    assert a._wire(40_000) < b._wire(40_000)
    # top-k wire bytes = k * (value + index)
    assert a._wire(40_000) == max(1, int(10_000 * 0.01)) * 8


# ----------------------------------------------------- deployment plans ---


def test_deployment_plan_from_regions():
    plan = DeploymentPlan.from_regions(["us-west", "us-west", "us-east"])
    assert plan.n_stages == 3
    assert plan.link_latencies == [0.002, 0.058, 0.058]
    assert plan.max_link_latency == 0.058
    assert plan.max_pairwise_latency == 0.058
    tr = plan.transport(stage_time_s=0.01)
    assert isinstance(tr, SimulatedLinkTransport)
    assert [l.latency_s for l in tr.links] == plan.link_latencies
    assert isinstance(plan.transport(compress="int8"), CompressedTransport)
    assert "--58ms-->" in plan.describe()


def test_deployment_plan_from_registry_match():
    """The registry's latency-minimising match output IS the deployment:
    stage order = machine order, links priced from the region table."""
    reg = Registry()
    for i in range(2):
        reg.register_machine(f"w{i}", 24 << 30, "us-west", stake=100)
    reg.register_machine("e0", 24 << 30, "us-east", stake=100)
    t = reg.register_task("alice", "m", 55 << 30, 4, 1.0)   # needs all 3
    m = reg.match(t.task_id)
    assert m is not None and m.n_stages == 3
    plan = DeploymentPlan.from_match(m)
    assert plan.n_stages == 3
    assert plan.regions == [x.region for x in m.machines]
    assert plan.max_pairwise_latency == pytest.approx(m.max_latency)
    assert plan.max_link_latency <= m.max_latency
    assert plan.machines is m.machines or plan.machines == m.machines
    # the planner consumes the slowest ring link
    from repro.serving.llm import EngineConfig
    cfg = EngineConfig.plan(deployment=plan, stage_time=0.05,
                            m_kv_bytes=1e6, backend="pipelined")
    assert cfg.n_stages == 3
    assert cfg.plan_args["latency"] == plan.max_link_latency
    assert isinstance(cfg.transport, SimulatedLinkTransport)


def test_deployment_plan_validation_and_uniform():
    with pytest.raises(ValueError, match="inconsistent"):
        DeploymentPlan(stages=["a", "b"], regions=["x"],
                       latency_matrix=np.zeros((2, 2)))
    plan = DeploymentPlan.uniform(4, 0.064)
    assert plan.link_latencies == [0.064] * 4
    assert plan.max_link_latency == 0.064


def test_placement_groups_colocated_machines():
    """Uniform weights: place_stages is the shortest-Hamiltonian-cycle
    pass — an alternating west/east match order pays the WAN on every
    link; the placed ring crosses the ocean exactly twice."""
    plan = DeploymentPlan.from_regions(
        ["us-west", "us-east", "us-west", "us-east"])
    assert sum(plan.link_latencies) == pytest.approx(4 * 0.058)
    placed = plan.place_stages()
    assert sum(placed.link_latencies) == pytest.approx(
        2 * 0.058 + 2 * 0.002)
    assert sorted(placed.regions) == sorted(plan.regions)
    assert placed.regions[0] == "us-west"       # anchor: stage 0 stays
    # idempotent: the placed ring is already optimal
    again = placed.place_stages()
    assert sum(again.link_latencies) == \
        pytest.approx(sum(placed.link_latencies))
    # the original plan is untouched
    assert plan.link_latencies == [0.058] * 4


def test_placement_weights_put_slow_link_next_to_light_stages():
    """Heterogeneous stage weights: the slowest link must land on the
    ring boundary between the *lightest* stages (ring positions carry
    the compute slices; machines permute around them)."""
    mat = np.zeros((3, 3))
    mat[0, 1] = mat[1, 0] = 0.1                 # the one slow pair
    plan = DeploymentPlan(stages=["m0", "m1", "m2"],
                          regions=["r"] * 3, latency_matrix=mat)
    w = [1.0, 1.0, 0.1]                         # position 2 is light
    # order (0,2,1): the 0.1 link spans positions 2->0, weight 0.55;
    # order (0,1,2) puts it on positions 0->1, weight 1.0
    assert plan.placement_cost((0, 2, 1), w) == pytest.approx(0.055)
    assert plan.placement_cost((0, 1, 2), w) == pytest.approx(0.1)
    placed = plan.place_stages(w)
    assert placed.placement_cost((0, 1, 2), w) == pytest.approx(0.055)
    assert placed.stages == ["m0", "m2", "m1"]
    with pytest.raises(ValueError, match="weight"):
        plan.placement_cost((0, 1, 2), [1.0])


def test_prefill_chunk_cap_honours_codec_and_bandwidth():
    """Thin-link rule, unit form: the cap is the largest chunk whose
    wire time fits one stage tick, and the int8 codec buys ~4x more
    tokens through the same pipe."""
    import jax.numpy as jnp

    from conftest import tiny
    from repro.models.common import Runtime
    from repro.serving.engine import prefill_chunk_cap
    cfg = tiny("yi-9b")
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    thin = LinkSpec(0.01, bandwidth_bps=128_000.0)
    cap_fp = prefill_chunk_cap(cfg, rt, thin, stage_time=0.016)
    assert cap_fp == int(0.016 * 128_000) // (cfg.d_model * 4)
    cap_q = prefill_chunk_cap(cfg, rt, thin, stage_time=0.016,
                              wire_dtype="int8")
    assert cap_q == int(0.016 * 128_000) // (cfg.d_model + 4)
    assert cap_q > 3 * cap_fp
    # nothing to cap: no link, or a fat pipe
    assert prefill_chunk_cap(cfg, rt, None, stage_time=0.016) == 0
    assert prefill_chunk_cap(cfg, rt, LinkSpec(0.01), stage_time=0.016) == 0
    # never degenerates below one token
    assert prefill_chunk_cap(cfg, rt, LinkSpec(0.0, bandwidth_bps=1.0),
                             stage_time=0.016) == 1


def test_bandwidth_shapes_planned_prefill_chunk(tiny_llm_setup):
    """from_plan integration: under a thin worst link the auto-derived
    chunk shrinks to the wire cap (and the one-chunk admission budget
    follows); an explicit prefill_chunk is always respected."""
    from repro.serving.engine import OfflineEngine
    cfg, params, rt = tiny_llm_setup
    kw = dict(n_stages=1, stage_time=0.016, latency=0.0, m_kv_bytes=1e6,
              page_size=4, max_pages_per_seq=4, backend="pipelined",
              mb_size_cap=1, use_offload=False)
    fat = OfflineEngine.from_plan(cfg, params, rt, **kw)
    assert fat.prefill_chunk == 8                   # FLOPs-derived floor
    thin = OfflineEngine.from_plan(
        cfg, params, rt,
        worst_link=LinkSpec(0.01, bandwidth_bps=16_000.0), **kw)
    want = max(1, int(0.016 * 16_000) // (cfg.d_model * 4))
    assert thin.prefill_chunk == want < 8
    assert thin.max_prefill_tokens_per_tick == thin.prefill_chunk
    # int8 wire: same link, bigger chunk (packed tokens are cheaper)
    packed = OfflineEngine.from_plan(
        cfg, params, rt, wire_dtype="int8",
        worst_link=LinkSpec(0.01, bandwidth_bps=16_000.0), **kw)
    assert packed.prefill_chunk > thin.prefill_chunk
    explicit = OfflineEngine.from_plan(
        cfg, params, rt, prefill_chunk=16,
        worst_link=LinkSpec(0.01, bandwidth_bps=16_000.0), **kw)
    assert explicit.prefill_chunk == 16             # user knob wins


def test_plan_threads_links_worst_link_and_wire_dtype():
    from repro.serving.llm import EngineConfig
    plan = DeploymentPlan.uniform(3, 0.02, bandwidth_bps=16_000.0)
    cfg = EngineConfig.plan(deployment=plan, stage_time=0.05,
                            m_kv_bytes=1e6, backend="pipelined",
                            wire_dtype="int8")
    assert cfg.plan_args["link_latencies"] == [0.02] * 3
    assert cfg.plan_args["worst_link"].bandwidth_bps == 16_000.0
    assert cfg.plan_args["latency"] == plan.max_link_latency
    assert cfg.wire_dtype == "int8"


def test_placement_trivial_and_greedy_paths():
    assert DeploymentPlan.uniform(2, 0.05).place_stages().n_stages == 2
    # n > 8 takes the greedy nearest-neighbour path: on a two-cluster
    # geography it still finds the two-crossing ring
    regions = ["us-west", "us-east"] * 5
    placed = DeploymentPlan.from_regions(regions).place_stages()
    assert placed.n_stages == 10
    assert sum(placed.link_latencies) == pytest.approx(
        2 * 0.058 + 8 * 0.002)


def test_engine_config_plan_requires_geometry():
    from repro.serving.llm import EngineConfig
    with pytest.raises(ValueError, match="n_stages"):
        EngineConfig.plan(stage_time=0.05, m_kv_bytes=1e6)


def test_engine_config_rejects_transport_on_local_backend():
    from repro.serving.llm import EngineConfig
    with pytest.raises(ValueError, match="pipelined"):
        EngineConfig(backend="local", transport=0.05)
    with pytest.raises(ValueError, match="pipelined"):
        EngineConfig(backend="local", schedule="round_flush")
    with pytest.raises(ValueError, match="schedule"):
        EngineConfig(backend="pipelined", num_microbatches=2,
                     schedule="eager")


# ------------------------------------------------ simulator cross-check ---


def test_simulator_per_link_uniform_matches_scalar():
    for pol in ("vllm_pp", "deserve_pp", "deserve_opt"):
        a = PipelineSimulator(SimConfig(
            policy=pol, n_stages=4, latency=0.032,
            sim_seconds=120, warmup_seconds=30)).run()
        b = PipelineSimulator(SimConfig(
            policy=pol, n_stages=4, link_latencies=(0.032,) * 4,
            sim_seconds=120, warmup_seconds=30)).run()
        assert a.output_tps == pytest.approx(b.output_tps, abs=1e-9)


def test_simulator_heterogeneous_links():
    het = (0.002, 0.002, 0.002, 0.128)
    circ = simulate_links("deserve_pp", het, sim_seconds=120, warmup=30)
    rf = simulate_links("vllm_pp", het, sim_seconds=120, warmup=30)
    assert circ.output_tps > rf.output_tps
    # one slow link costs the circular ring only its share of the sum;
    # a uniform ring at the same max latency must be strictly worse
    uni = PipelineSimulator(SimConfig(
        policy="deserve_pp", n_stages=4, latency=0.128,
        sim_seconds=120, warmup_seconds=30)).run()
    assert circ.output_tps >= uni.output_tps
    with pytest.raises(ValueError, match="link_latencies"):
        SimConfig(n_stages=4, link_latencies=(0.1, 0.1))


# --------------------------------------- real engine, fast (one device) ---


@pytest.fixture(scope="module")
def tiny_llm_setup():
    import jax
    import jax.numpy as jnp

    from conftest import tiny
    from repro.models import model as M
    from repro.models.common import Runtime
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    return cfg, params, rt


def test_transport_equivalence_and_speedup_one_stage(tiny_llm_setup):
    """Acceptance (1-stage form, in-process): InProcess vs SimulatedLink
    at L = 64 ms produce bit-identical streams — greedy AND sampled,
    chunked prefill exercising the prefill plane — and the planner-N_B
    circular schedule beats round-flush N_B = N_S ≥ 3x on the virtual
    clock.  The 2-stage SPMD form runs in the slow suite."""
    from equivalence import (assert_equivalent, mixed_sps, random_prompts,
                             run_llm)
    from repro.serving.kv_cache import PoolConfig
    cfg, params, rt = tiny_llm_setup
    pool = PoolConfig(page_size=4, n_local_pages=32, n_global_pages=0,
                      max_pages_per_seq=6)
    T, L = 0.016, 0.064
    n_star = optimal_microbatches(1, T, L)      # 5
    prompts = random_prompts(cfg, n_star, seed=3, lo=3, hi=8)
    sps = mixed_sps(n_star, max_new=6)
    common = dict(backend="pipelined", n_stages=1, mb_size=1, pool=pool,
                  offload=False, prefill_chunk=8)
    runs = {}
    runs["inproc"], _ = run_llm(cfg, params, rt, prompts, sps,
                                num_microbatches=n_star, **common)
    runs["simlink"], llm_circ = run_llm(
        cfg, params, rt, prompts, sps, num_microbatches=n_star,
        transport=SimulatedLinkTransport.uniform(1, L, stage_time_s=T),
        **common)
    runs["round_flush"], llm_rf = run_llm(
        cfg, params, rt, prompts, sps, num_microbatches=1,
        schedule="round_flush",
        transport=SimulatedLinkTransport.uniform(1, L, stage_time_s=T),
        **common)
    assert_equivalent(runs, base="inproc")

    rep_c, rep_rf = llm_circ.stats(), llm_rf.stats()
    assert rep_c["transport"]["virtual_time_s"] > 0
    assert rep_c["transport"]["max_link_latency_s"] == L
    ratio = rep_c["virtual_decode_tok_per_s"] / \
        rep_rf["virtual_decode_tok_per_s"]
    assert ratio >= 3.0, f"circular/round_flush = {ratio:.2f} < 3x"
    # and the InProcess run keeps no books
    out, llm_ip = run_llm(cfg, params, rt, prompts[:1], sps[:1],
                          num_microbatches=1, **common)
    assert "transport" not in llm_ip.stats()


def test_transport_survives_reshard(tiny_llm_setup):
    """for_stages carries the link policy through Engine.reshard: a
    1 → 1 stage rebuild keeps the simulated link and its clock."""
    from equivalence import random_prompts
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.llm import LLM, EngineConfig
    from repro.serving.request import SamplingParams
    cfg, params, rt = tiny_llm_setup
    pool = PoolConfig(page_size=4, n_local_pages=32, n_global_pages=0,
                      max_pages_per_seq=6)
    llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
        backend="pipelined", n_stages=1, mb_size=1, num_microbatches=2,
        pool=pool, offload=False, transport=0.032))
    prompts = random_prompts(cfg, 2, seed=5, lo=3, hi=6)
    sp = SamplingParams(temperature=0.0, max_new_tokens=6)
    step = 0
    for _ in llm.generate_iter(prompts, sp, max_steps=300):
        step += 1
        if step == 6:
            vt_before = llm.engine.backend.transport.clock.now
            assert vt_before > 0
            llm.engine.reshard(n_stages=1)
            tr = llm.engine.backend.transport
            assert isinstance(tr, SimulatedLinkTransport)
            assert tr.clock.now >= vt_before
    assert llm.engine.stats.reshards == 1
    assert llm.stats()["transport"]["virtual_time_s"] >= vt_before


# ------------------------------------------------- SPMD acceptance (2x) ---


ACCEPT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from equivalence import assert_equivalent, mixed_sps, random_prompts, run_llm
from repro.config import get_arch, reduced_config
from repro.core.scheduler import optimal_microbatches
from repro.distributed.transport import SimulatedLinkTransport
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.kv_cache import PoolConfig

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg = reduced_config(get_arch("yi-9b"))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=4, n_local_pages=64, n_global_pages=0,
                  max_pages_per_seq=6)
T, L = 0.016, 0.064
n_star = optimal_microbatches(2, T, L)          # 10
prompts = random_prompts(cfg, n_star, seed=7, lo=3, hi=9)
sps = mixed_sps(n_star, max_new=8)              # greedy AND sampled
common = dict(backend="pipelined", n_stages=2, mb_size=1, pool=pool,
              offload=False, prefill_chunk=8)
runs = {}
runs["inproc"], _ = run_llm(cfg, params, rt, prompts, sps,
                            num_microbatches=n_star, **common)
runs["simlink"], llm_c = run_llm(
    cfg, params, rt, prompts, sps, num_microbatches=n_star,
    transport=SimulatedLinkTransport.uniform(2, L, stage_time_s=T), **common)
runs["round_flush"], llm_rf = run_llm(
    cfg, params, rt, prompts, sps, num_microbatches=2,
    schedule="round_flush",
    transport=SimulatedLinkTransport.uniform(2, L, stage_time_s=T), **common)
assert_equivalent(runs, base="inproc")
ratio = llm_c.stats()["virtual_decode_tok_per_s"] / \
    llm_rf.stats()["virtual_decode_tok_per_s"]
assert ratio >= 3.0, f"circular/round_flush = {ratio:.2f} < 3x at 64ms"
print(f"OK ratio={ratio:.2f}")
"""


@pytest.mark.slow
def test_acceptance_two_stage_spmd():
    """ISSUE 5 acceptance: at L = 64 ms one-way on the 2-stage SPMD pipe,
    the planner-chosen N_B circular schedule ≥ 3x round-flush N_B = N_S
    decode tok/s (virtual clock), with InProcess and SimulatedLink runs
    bit-identical (greedy + sampled, decode and prefill planes)."""
    from equivalence import subprocess_env
    r = subprocess.run([sys.executable, "-c", ACCEPT_SCRIPT],
                       env=subprocess_env(), capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "OK ratio=" in r.stdout


WIRE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from equivalence import assert_equivalent, mixed_sps, random_prompts, run_llm
from repro.config import get_arch, reduced_config
from repro.core import pipeline as PL
from repro.distributed.transport import SimulatedLinkTransport
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.kv_cache import PoolConfig

# --- tick-level bound: the int8 codec inside the SPMD ppermute wire ----
mesh = jax.make_mesh((2,), ("pod",))
y = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 64), jnp.float32) * 3.0
def ship(wire):
    f = PL._shard_map(lambda x: PL._wire_permute(x, 2, wire), mesh=mesh,
                      axis_names={"pod"}, in_specs=(P("pod"),),
                      out_specs=P("pod"))
    with mesh:
        return np.asarray(jax.jit(f)(y))
ref = ship("fp32")
got = ship("int8")
assert (ref == np.roll(np.asarray(y), 1, axis=0)).all()   # identity wire
step = np.max(np.abs(np.asarray(y)), axis=-1) / 127.0     # per-row scale
assert np.max(np.abs(got - ref)) <= 0.5 * np.max(step) * 1.01, "codec bound"

# --- engine-level: fp32 wire bit-identical; int8 within tolerance AND
# --- faster on a bandwidth-capped ring --------------------------------
rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg = reduced_config(get_arch("yi-9b"))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=4, n_local_pages=64, n_global_pages=0,
                  max_pages_per_seq=6)
T, BW = 0.016, 8000.0    # fp32 decode payload 256B -> 32ms/link;
n_b = 4                  # int8 packs it to 68B -> 8.5ms
prompts = random_prompts(cfg, n_b, seed=7, lo=3, hi=9)
sps = mixed_sps(n_b, max_new=8)
common = dict(backend="pipelined", n_stages=2, mb_size=1, pool=pool,
              offload=False, prefill_chunk=8, num_microbatches=n_b)
links = lambda: SimulatedLinkTransport.uniform(2, 0.0, bandwidth_bps=BW,
                                               stage_time_s=T)
runs = {}
runs["inproc"], _ = run_llm(cfg, params, rt, prompts, sps, **common)
runs["fp32"], llm_fp = run_llm(cfg, params, rt, prompts, sps,
                               transport=links(), wire_dtype="fp32",
                               **common)
assert_equivalent(runs, base="inproc")          # fp32 wire: bit-identical
q, llm_q = run_llm(cfg, params, rt, prompts, sps, transport=links(),
                   wire_dtype="int8", **common)
agree = float(np.mean([q[r] == runs["fp32"][r] for r in q]))
assert agree >= 0.5, f"int8 wire: only {agree:.0%} of streams match fp32"
s_fp, s_q = llm_fp.stats(), llm_q.stats()
assert s_q["transport"]["compression_ratio"] > 2.0   # returns stay raw
ratio = s_q["virtual_decode_tok_per_s"] / s_fp["virtual_decode_tok_per_s"]
assert ratio > 1.2, f"compressed circular only {ratio:.2f}x under the cap"
print(f"OK agree={agree:.2f} speedup={ratio:.2f}")
"""


@pytest.mark.slow
def test_wire_codec_two_stage_spmd():
    """ISSUE 6 acceptance on the real 2-stage SPMD pipe, both planes:

    * wire_dtype="fp32" over simulated links is bit-identical to the
      in-process run (the codec off-path changes nothing);
    * the int8 wire's activation error obeys the stated bound — half a
      quantization step, scale = max|row|/127 — measured through the
      actual shard_map ppermute;
    * under a bandwidth cap the compressed circular pipe strictly beats
      the uncompressed one in virtual decode tok/s (the wire-speed
      claim: fewer bytes on the thin link, not just cheaper books)."""
    from equivalence import subprocess_env
    r = subprocess.run([sys.executable, "-c", WIRE_SCRIPT],
                       env=subprocess_env(), capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "OK agree=" in r.stdout
