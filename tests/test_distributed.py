"""Control-plane logic: sharding rules, elastic planner (property-style
over every legal device count), failure detector (flap accounting, timeout
boundary), straggler mitigation — pure CPU, no devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from conftest import tiny
from repro.distributed.elastic import (ElasticPlanner, FailureDetector,
                                       StragglerMitigator)
from repro.models import model as M
from repro.models.common import Runtime


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class FakePodMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


# ---------------------------------------------------------------- specs ---

def test_param_specs_rules(rt, key):
    from repro.distributed.sharding import param_specs
    cfg = tiny("yi-9b", d_model=128)
    params = jax.eval_shape(lambda: M.init_params(cfg, key, rt))
    specs = param_specs(params, cfg, FakeMesh(), fsdp=True)
    wq = specs["scan"][0]["wq"]
    assert wq == P(None, "data", "model")        # (period, D, H*Dh)
    assert all(a is None for a in specs["scan"][0]["ln1"])
    assert specs["embed"]["tok"][0] == "model"   # vocab-TP
    # serving: no fsdp
    specs2 = param_specs(params, cfg, FakeMesh(), fsdp=False)
    assert specs2["scan"][0]["wq"] == P(None, None, "model")


def test_param_specs_moe_expert_parallel(rt, key):
    from repro.distributed.sharding import param_specs
    cfg = tiny("qwen3-moe-235b-a22b")
    params = jax.eval_shape(lambda: M.init_params(cfg, key, rt))
    specs = param_specs(params, cfg, FakeMesh(), fsdp=True)
    moe = specs["scan"][0]["moe"]
    assert moe["wg"][1] is None or moe["wg"][1] == "data"
    # expert dim not divisible by 16 in the tiny config -> replicated;
    # check the real config instead
    from repro.config import get_arch
    real = get_arch("qwen3-moe-235b-a22b")
    rt16 = Runtime()
    params_r = jax.eval_shape(lambda: M.init_params(real, key, rt16))
    specs_r = param_specs(params_r, real, FakeMesh(), fsdp=True)
    assert specs_r["scan"][0]["moe"]["wg"][1] == "model"  # E over model


def test_cache_specs_kv_head_vs_sequence_sharding(key):
    from repro.distributed.sharding import cache_specs
    from repro.config import get_arch
    rt16 = Runtime()
    # yi-9b: kv=4 not divisible by 16 -> sequence-parallel KV
    cfg = get_arch("yi-9b")
    caches = jax.eval_shape(lambda: M.init_caches(cfg, 128, 1024, rt16))
    specs = cache_specs(caches, cfg, FakeMesh())
    k = specs["scan"][0]["k"]                    # (P, B, C, Hk, Dh)
    assert k[1] == "data" and k[2] == "model"
    # minitron: kv=8... also not divisible; gemma3-12b kv=8; llama3-70b kv=8
    # musicgen kv=32 -> heads sharded
    cfg2 = get_arch("musicgen-large")
    caches2 = jax.eval_shape(lambda: M.init_caches(cfg2, 128, 1024, rt16))
    specs2 = cache_specs(caches2, cfg2, FakeMesh())
    k2 = specs2["scan"][0]["k"]
    assert k2[3] == "model" and k2[1] == "data"


def test_batch_specs_pod_folding():
    from repro.distributed.sharding import batch_specs
    shapes = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    single = batch_specs(shapes, FakeMesh())
    assert single["tokens"][0] == "data"
    multi = batch_specs(shapes, FakePodMesh())
    assert multi["tokens"][0] == ("pod", "data")
    # non-divisible batch stays replicated
    odd = batch_specs({"x": jax.ShapeDtypeStruct((3, 4), jnp.int32)},
                      FakeMesh())
    assert odd["x"] == P(None, None)


# ---------------------------------------------------------------- elastic --

def test_planner_full_and_degraded():
    pl = ElasticPlanner(model_parallel=16, pod_size=256)
    full = pl.plan(512)
    assert full.shape == (2, 16, 16) and full.devices_spare == 0
    # lose 3 nodes: drop to single pod + largest pow2 data dim
    degraded = pl.plan(509)
    assert degraded.axes == ("data", "model")
    assert degraded.shape == (16, 16)
    assert degraded.devices_spare == 509 - 256
    small = pl.plan(40)
    assert small.shape == (2, 16)
    with pytest.raises(RuntimeError):
        pl.plan(8)


MP, POD = 16, 256
_PLANNER = ElasticPlanner(model_parallel=MP, pod_size=POD)


def _check_plan_invariants(live: int) -> None:
    plan = _PLANNER.plan(live)
    # accounting: used + spare == live, shape product == used <= live
    assert plan.devices_used + plan.devices_spare == live
    assert plan.devices_used <= live
    prod = 1
    for d in plan.shape:
        prod *= d
    assert prod == plan.devices_used
    # data axis: largest power of two that fits
    assert plan.data & (plan.data - 1) == 0 and plan.data >= 1
    # model axis preserved (TP sharding stays valid on every resize)
    assert plan.model == MP
    # pod axis only with >= 2 full pods live
    if live // POD >= 2:
        assert plan.axes == ("pod", "data", "model")
        assert plan.shape[plan.axes.index("pod")] == live // POD
    else:
        assert plan.axes == ("data", "model")


@settings(max_examples=60, deadline=None)
@given(live=st.integers(min_value=MP, max_value=4 * POD))
def test_planner_properties_sampled(live):
    """Property-style: the planner's invariants hold for sampled device
    counts across [model_parallel, 4*pod_size] (hypothesis, or the
    deterministic shim when the real package is absent)."""
    _check_plan_invariants(live)


def test_planner_properties_exhaustive():
    """The full sweep is cheap (pure python): every legal device count in
    [model_parallel, 4*pod_size], plus the reject below it."""
    for live in range(MP, 4 * POD + 1):
        _check_plan_invariants(live)
    with pytest.raises(RuntimeError):
        _PLANNER.plan(MP - 1)


def test_resharding_plan_cheap_vs_heavy():
    pl = ElasticPlanner(model_parallel=16, pod_size=256)
    a, b = pl.plan(512), pl.plan(400)
    plan = pl.resharding_plan(a, b)
    assert plan["batch_reshard"]
    assert not plan["params_move"]              # model axis preserved
    pl2 = ElasticPlanner(model_parallel=8)
    c = pl2.plan(64)
    plan2 = pl.resharding_plan(a, c)
    assert plan2["params_move"] and plan2["restore_from_checkpoint"]


def test_failure_detector():
    fd = FailureDetector(timeout=10.0)
    for d in range(4):
        fd.beat(d, now=0.0)
    fd.beat(0, now=9.0)
    assert fd.dead(now=12.0) == [1, 2, 3]
    assert fd.live(now=12.0) == [0]
    assert fd.should_restart(now=12.0, required=2)
    assert not fd.should_restart(now=5.0, required=4)


def test_failure_detector_flap_accounting():
    """A device that misses the timeout and then beats again is a
    dead->live flap: recorded per device, never silently resurrected."""
    fd = FailureDetector(timeout=10.0)
    fd.beat(0, now=0.0)
    fd.beat(1, now=0.0)
    assert fd.flap_count() == 0
    fd.beat(0, now=11.0)                # was dead (11 > 10): flap
    assert fd.flap_count(0) == 1
    assert fd.flap_count(1) == 0
    assert fd.flap_count() == 1
    assert fd.live(now=11.0) == [0]     # back, but on the record
    fd.beat(0, now=30.0)                # dead again (30-11 > 10): flap 2
    assert fd.flap_count(0) == 2
    assert fd.flap_count(99) == 0       # unseen device
    # a healthy cadence never counts
    for t in (5.0, 12.0, 20.0):
        fd.beat(1, now=t)
    assert fd.flap_count(1) == 0


def test_failure_detector_timeout_boundary():
    """``now - last_seen == timeout`` is still live: a boundary probe must
    not flag the device dead, and a boundary beat must not count a flap
    (no double-counting at the edge)."""
    fd = FailureDetector(timeout=10.0)
    fd.beat(0, now=0.0)
    assert fd.dead(now=10.0) == []      # exactly at timeout: alive
    assert fd.live(now=10.0) == [0]
    fd.beat(0, now=10.0)                # boundary beat: not a flap
    assert fd.flap_count(0) == 0
    assert fd.dead(now=20.0 + 1e-9) == [0]    # strictly past: dead


def test_straggler_mitigation():
    sm = StragglerMitigator(n_stages=4, slow_factor=1.5, demote_factor=3.0)
    for _ in range(10):
        for s, t in enumerate([0.1, 0.1, 0.1, 0.22]):
            sm.observe(s, t)
    assert sm.stragglers() == [3]
    assert sm.demotions() == []
    w = sm.microbatch_weights()
    assert w[3] < w[0]                          # slow stage gets less work
    assert np.isclose(np.mean(w), 1.0)
    for _ in range(20):
        sm.observe(3, 0.5)
    assert 3 in sm.demotions()


def test_microbatch_weights_properties():
    """Satellite coverage: observed weights normalise to mean 1.0, a cold
    (ewma == 0) stage gets exactly weight 1.0 without skewing the others,
    and demotions() ⊆ stragglers() whenever demote_factor > slow_factor."""
    # all observed -> mean exactly 1.0
    sm = StragglerMitigator(n_stages=4)
    for s, t in enumerate([0.1, 0.2, 0.1, 0.4]):
        sm.observe(s, t)
    w = sm.microbatch_weights()
    assert np.isclose(np.mean(w), 1.0)
    assert w[3] < w[1] < w[0]

    # one cold stage: pinned at 1.0, the observed ones still mean-1
    sm = StragglerMitigator(n_stages=4)
    for s, t in ((0, 0.1), (1, 0.3), (3, 0.2)):
        sm.observe(s, t)
    w = sm.microbatch_weights()
    assert w[2] == 1.0
    assert np.isclose(np.mean([w[0], w[1], w[3]]), 1.0)

    # nothing observed at all: everyone 1.0
    assert StragglerMitigator(n_stages=3).microbatch_weights() == [1.0] * 3

    # demotions ⊆ stragglers for any demote_factor > slow_factor
    sm = StragglerMitigator(n_stages=5, slow_factor=1.5, demote_factor=3.0)
    for _ in range(10):
        for s, t in enumerate([0.1, 0.1, 0.16, 0.4, 0.1]):
            sm.observe(s, t)
    assert set(sm.demotions()) <= set(sm.stragglers())
    assert 3 in sm.stragglers()
