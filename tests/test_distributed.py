"""Control-plane logic: sharding rules, elastic planner, failure detector,
straggler mitigation — pure CPU, no devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import tiny
from repro.distributed.elastic import (ElasticPlanner, FailureDetector,
                                       StragglerMitigator)
from repro.models import model as M
from repro.models.common import Runtime


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class FakePodMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


# ---------------------------------------------------------------- specs ---

def test_param_specs_rules(rt, key):
    from repro.distributed.sharding import param_specs
    cfg = tiny("yi-9b", d_model=128)
    params = jax.eval_shape(lambda: M.init_params(cfg, key, rt))
    specs = param_specs(params, cfg, FakeMesh(), fsdp=True)
    wq = specs["scan"][0]["wq"]
    assert wq == P(None, "data", "model")        # (period, D, H*Dh)
    assert all(a is None for a in specs["scan"][0]["ln1"])
    assert specs["embed"]["tok"][0] == "model"   # vocab-TP
    # serving: no fsdp
    specs2 = param_specs(params, cfg, FakeMesh(), fsdp=False)
    assert specs2["scan"][0]["wq"] == P(None, None, "model")


def test_param_specs_moe_expert_parallel(rt, key):
    from repro.distributed.sharding import param_specs
    cfg = tiny("qwen3-moe-235b-a22b")
    params = jax.eval_shape(lambda: M.init_params(cfg, key, rt))
    specs = param_specs(params, cfg, FakeMesh(), fsdp=True)
    moe = specs["scan"][0]["moe"]
    assert moe["wg"][1] is None or moe["wg"][1] == "data"
    # expert dim not divisible by 16 in the tiny config -> replicated;
    # check the real config instead
    from repro.config import get_arch
    real = get_arch("qwen3-moe-235b-a22b")
    rt16 = Runtime()
    params_r = jax.eval_shape(lambda: M.init_params(real, key, rt16))
    specs_r = param_specs(params_r, real, FakeMesh(), fsdp=True)
    assert specs_r["scan"][0]["moe"]["wg"][1] == "model"  # E over model


def test_cache_specs_kv_head_vs_sequence_sharding(key):
    from repro.distributed.sharding import cache_specs
    from repro.config import get_arch
    rt16 = Runtime()
    # yi-9b: kv=4 not divisible by 16 -> sequence-parallel KV
    cfg = get_arch("yi-9b")
    caches = jax.eval_shape(lambda: M.init_caches(cfg, 128, 1024, rt16))
    specs = cache_specs(caches, cfg, FakeMesh())
    k = specs["scan"][0]["k"]                    # (P, B, C, Hk, Dh)
    assert k[1] == "data" and k[2] == "model"
    # minitron: kv=8... also not divisible; gemma3-12b kv=8; llama3-70b kv=8
    # musicgen kv=32 -> heads sharded
    cfg2 = get_arch("musicgen-large")
    caches2 = jax.eval_shape(lambda: M.init_caches(cfg2, 128, 1024, rt16))
    specs2 = cache_specs(caches2, cfg2, FakeMesh())
    k2 = specs2["scan"][0]["k"]
    assert k2[3] == "model" and k2[1] == "data"


def test_batch_specs_pod_folding():
    from repro.distributed.sharding import batch_specs
    shapes = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    single = batch_specs(shapes, FakeMesh())
    assert single["tokens"][0] == "data"
    multi = batch_specs(shapes, FakePodMesh())
    assert multi["tokens"][0] == ("pod", "data")
    # non-divisible batch stays replicated
    odd = batch_specs({"x": jax.ShapeDtypeStruct((3, 4), jnp.int32)},
                      FakeMesh())
    assert odd["x"] == P(None, None)


# ---------------------------------------------------------------- elastic --

def test_planner_full_and_degraded():
    pl = ElasticPlanner(model_parallel=16, pod_size=256)
    full = pl.plan(512)
    assert full.shape == (2, 16, 16) and full.devices_spare == 0
    # lose 3 nodes: drop to single pod + largest pow2 data dim
    degraded = pl.plan(509)
    assert degraded.axes == ("data", "model")
    assert degraded.shape == (16, 16)
    assert degraded.devices_spare == 509 - 256
    small = pl.plan(40)
    assert small.shape == (2, 16)
    with pytest.raises(RuntimeError):
        pl.plan(8)


def test_resharding_plan_cheap_vs_heavy():
    pl = ElasticPlanner(model_parallel=16, pod_size=256)
    a, b = pl.plan(512), pl.plan(400)
    plan = pl.resharding_plan(a, b)
    assert plan["batch_reshard"]
    assert not plan["params_move"]              # model axis preserved
    pl2 = ElasticPlanner(model_parallel=8)
    c = pl2.plan(64)
    plan2 = pl.resharding_plan(a, c)
    assert plan2["params_move"] and plan2["restore_from_checkpoint"]


def test_failure_detector():
    fd = FailureDetector(timeout=10.0)
    for d in range(4):
        fd.beat(d, now=0.0)
    fd.beat(0, now=9.0)
    assert fd.dead(now=12.0) == [1, 2, 3]
    assert fd.live(now=12.0) == [0]
    assert fd.should_restart(now=12.0, required=2)
    assert not fd.should_restart(now=5.0, required=4)


def test_straggler_mitigation():
    sm = StragglerMitigator(n_stages=4, slow_factor=1.5, demote_factor=3.0)
    for _ in range(10):
        for s, t in enumerate([0.1, 0.1, 0.1, 0.22]):
            sm.observe(s, t)
    assert sm.stragglers() == [3]
    assert sm.demotions() == []
    w = sm.microbatch_weights()
    assert w[3] < w[0]                          # slow stage gets less work
    assert np.isclose(np.mean(w), 1.0)
    for _ in range(20):
        sm.observe(3, 0.5)
    assert 3 in sm.demotions()
