"""Exhaustive attention-kernel matrix vs the pure-jnp oracles.

Sweeps ``page_size x Dh x G x window x dtype x seq-len-edge`` (edges: 1,
page_size-1, page_size, max) for the paged decode kernel and the
analogous block-relative edges for the flash kernel, all in interpret
mode on CPU.  The full cross product runs under ``-m slow``; tier-1 runs
a seeded subsample so every axis stays exercised per-commit without the
interpret-mode bill.

Also pins two properties the sweeps alone can't see:

* ``pages_per_block`` is a pure schedule knob — every ppb choice must
  match the oracle on the same inputs;
* unowned pool pages are never read: NaN-poisoning every page outside
  the rows' own page-table ranges must leave the output *bitwise*
  unchanged (the index-map clamp of ISSUE 8 satellite b).
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (paged_decode_attention,
                                           tuned_pages_per_block)

# ---------------------------------------------------------------- axes --

PAGED_AXES = list(itertools.product(
    (8, 16, 32),                    # page_size
    (64, 128),                      # Dh
    (1, 2, 4),                      # G = h // hk
    (0, 1),                         # window: off / ~1.5 pages (resolved below)
    (jnp.float32, jnp.bfloat16),
    ("one", "page-1", "page", "max"),
))

FLASH_AXES = list(itertools.product(
    (64, 128, 256),                 # Dh
    (1, 2, 4),                      # G
    (0, 17),                        # window
    (jnp.float32, jnp.bfloat16),
    (1, 31, 32, 96),                # seq edges around the 32-wide blocks
))


def _subsample(axes, n, seed):
    rng = np.random.RandomState(seed)
    idx = rng.choice(len(axes), size=min(n, len(axes)), replace=False)
    return [axes[i] for i in sorted(idx)]


_CASES_RUN = itertools.count(1)


@pytest.fixture(autouse=True)
def _bound_compiled_maps():
    """Every sweep case compiles distinct-shape jits; across the full
    matrix the mmapped executables alone would eat a large bite of
    ``vm.max_map_count`` (the suite-wide budget — see conftest).  Drop
    them every few dozen cases; each case compiles its own shapes, so
    cross-case cache hits are rare anyway."""
    yield
    if next(_CASES_RUN) % 32 == 0:
        jax.clear_caches()


def _tol(dtype):
    return 3e-5 if dtype == jnp.float32 else 3e-2


# ---------------------------------------------------------------- paged --


def _run_paged(case, pages_per_block=0):
    page, dh, g, win_sel, dtype, edge = case
    maxp, hk = 4, 2
    h = hk * g
    smax = page * maxp
    lens_by_edge = {"one": 1, "page-1": page - 1, "page": page, "max": smax}
    window = 0 if win_sel == 0 else page + page // 2
    b = 2
    npool = 1 + b * maxp            # page 0 reserved scratch, no sharing
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % (2 ** 31)), 4)
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    kp = jax.random.normal(ks[1], (npool, page, hk, dh), dtype)
    vp = jax.random.normal(ks[2], (npool, page, hk, dh), dtype)
    # disjoint per-row page ranges so poisoning "unowned" is well-defined
    pt = jnp.arange(1, 1 + b * maxp, dtype=jnp.int32).reshape(b, maxp)
    # row 0 sits at the edge; row 1 at an unrelated interior length
    lens = jnp.asarray([lens_by_edge[edge],
                        min(smax, page + 3)], jnp.int32)
    out = paged_decode_attention(q, kp, vp, pt, lens, window=window,
                                 pages_per_block=pages_per_block,
                                 interpret=True)
    oracle = ref.paged_decode_attention_ref(
        q.astype(jnp.float32), kp.astype(jnp.float32),
        vp.astype(jnp.float32), pt, lens, window=window)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle), rtol=tol, atol=tol)
    return out, (q, kp, vp, pt, lens, window)


@pytest.mark.slow
@pytest.mark.parametrize("case", PAGED_AXES)
def test_paged_matrix_full(case):
    _run_paged(case)


@pytest.mark.parametrize("case", _subsample(PAGED_AXES, 14, seed=0x5EED))
def test_paged_matrix_sample(case):
    _run_paged(case)


@pytest.mark.parametrize("ppb", [1, 2, 3, 4, 8])
def test_paged_ppb_is_pure_schedule(ppb):
    """Every pages-per-block choice computes the same attention (each
    checked against the oracle on identical inputs)."""
    _run_paged((8, 64, 2, 1, jnp.float32, "max"), pages_per_block=ppb)


def test_paged_tuned_ppb_table_sane():
    for page, dh, g in itertools.product((8, 16, 32, 64), (64, 128, 256),
                                         (1, 2, 4, 8)):
        ppb = tuned_pages_per_block(page, dh, g)
        assert ppb >= 1, (page, dh, g)
        # fused scratch + ppb pages of K and V must respect the VMEM cap
        assert ppb * page * dh * 2 * 4 <= 512 * 1024, (page, dh, g, ppb)


def test_paged_ignores_unowned_pool_pages_bitwise():
    """NaN-poison every pool page outside the rows' own table ranges
    (incl. beyond each row's last *valid* page): output must be bitwise
    identical — the index-map clamp never touches foreign pages."""
    case = (8, 64, 2, 0, jnp.float32, "page-1")
    out_clean, (q, kp, vp, pt, lens, window) = _run_paged(case)
    page = kp.shape[1]
    owned = set()
    for r in range(pt.shape[0]):
        n_pages = -(-int(lens[r]) // page)
        owned |= {int(p) for p in np.asarray(pt[r, :n_pages])}
    poison = np.asarray(kp).copy()
    poison_v = np.asarray(vp).copy()
    for p in range(kp.shape[0]):
        if p not in owned:
            poison[p] = np.nan
            poison_v[p] = np.nan
    out_poison = paged_decode_attention(q, jnp.asarray(poison),
                                        jnp.asarray(poison_v), pt, lens,
                                        window=window, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_clean),
                                  np.asarray(out_poison))


# ---------------------------------------------------------------- flash --


def _run_flash(case):
    dh, g, window, dtype, seq = case
    hk = 2
    h = hk * g
    b = 2
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % (2 ** 31)), 3)
    q = jax.random.normal(ks[0], (b, seq, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, seq, hk, dh), dtype)
    v = jax.random.normal(ks[2], (b, seq, hk, dh), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 q_blk=32, kv_blk=32, interpret=True)
    oracle = ref.flash_attention_ref(q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32),
                                     causal=True, window=window,
                                     q_chunk=32, kv_chunk=32)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle), rtol=tol, atol=tol)


@pytest.mark.slow
@pytest.mark.parametrize("case", FLASH_AXES)
def test_flash_matrix_full(case):
    _run_flash(case)


@pytest.mark.parametrize("case", _subsample(FLASH_AXES, 10, seed=0xF1A5))
def test_flash_matrix_sample(case):
    _run_flash(case)
