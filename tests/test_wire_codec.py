"""In-jit int8 wire codec (ISSUE 6 tentpole): round-trip properties of
the per-row quantizer — all-zero rows, extreme scales, NaN/inf guards —
and the parity between ``CompressedTransport``'s wire-byte books and the
packed payload the tick jits actually ship through ``ppermute``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed.compression import (int8_compress_rows,
                                           int8_decompress_rows,
                                           int8_wire_bytes)
from repro.distributed.transport import (CompressedTransport,
                                         SimulatedLinkTransport)


# ------------------------------------------------- round-trip properties ---


@settings(max_examples=30, deadline=None)
@given(rows=st.integers(min_value=1, max_value=6),
       cols=st.integers(min_value=1, max_value=96),
       logmag=st.floats(min_value=-30.0, max_value=30.0),
       seed=st.integers(min_value=0, max_value=2**16))
def test_int8_roundtrip_error_bound(rows, cols, logmag, seed):
    """Per-row symmetric quantization round-trips within half a step:
    |x - deq(q)| <= scale/2 with scale = max(|row|)/127, across 60
    decades of magnitude (the 'extreme scales' guard)."""
    rng = np.random.RandomState(seed)
    x = (rng.uniform(-1.0, 1.0, (rows, cols)) * 10.0 ** logmag
         ).astype(np.float32)
    q, scale = jax.jit(int8_compress_rows)(jnp.asarray(x))
    assert q.dtype == jnp.int8
    assert scale.shape == (rows, 1)
    y = np.asarray(int8_decompress_rows(q, scale))
    amax = np.max(np.abs(x), axis=1, keepdims=True)
    step = np.maximum(amax, 1e-12) / 127.0
    assert np.all(np.abs(y - x) <= 0.5 * step * 1.01 + 1e-30)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(min_value=1, max_value=8),
       cols=st.integers(min_value=1, max_value=64))
def test_int8_all_zero_rows_roundtrip_exact(rows, cols):
    """All-zero rows survive exactly: the 1e-12 scale floor avoids 0/0
    and decompresses back to exact zeros."""
    x = jnp.zeros((rows, cols), jnp.float32)
    q, scale = int8_compress_rows(x)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.isfinite(np.asarray(scale)))
    assert np.all(np.asarray(int8_decompress_rows(q, scale)) == 0.0)


@settings(max_examples=20, deadline=None)
@given(bad=st.sampled_from([np.nan, np.inf, -np.inf]),
       col=st.integers(min_value=0, max_value=7))
def test_int8_nonfinite_inputs_stay_finite(bad, col):
    """NaN/inf never reach the wire: nan_to_num inside the codec maps
    them to 0 / float32 max, so q, scale, and the round-trip are all
    finite (a single poisoned activation cannot NaN the whole ring)."""
    v = np.linspace(-1.0, 1.0, 8).astype(np.float32)[None, :].repeat(2, 0)
    v[0, col] = bad
    q, scale = int8_compress_rows(jnp.asarray(v))
    y = np.asarray(int8_decompress_rows(q, scale))
    assert np.all(np.isfinite(np.asarray(scale)))
    assert np.all(np.isfinite(y))
    # the clean row is untouched by its neighbour's poison
    assert np.max(np.abs(y[1] - v[1])) <= 0.5 / 127.0 * 1.01


def test_int8_per_row_scales_are_independent():
    """One huge row must not crush a small row's resolution — the whole
    point of per-row (not per-tensor) scales on the wire."""
    small = np.linspace(-1.0, 1.0, 16).astype(np.float32)
    x = np.vstack([np.full((16,), 1e6, np.float32), small])
    q, scale = int8_compress_rows(jnp.asarray(x))
    y = np.asarray(int8_decompress_rows(q, scale))
    assert np.max(np.abs(y[1] - small)) <= 0.5 / 127.0 * 1.01


def test_int8_preserves_dtype_and_extremes():
    x = jnp.asarray([[-3.0, 0.0, 3.0]], jnp.bfloat16)
    q, scale = int8_compress_rows(x)
    y = int8_decompress_rows(q, scale, x.dtype)
    assert y.dtype == jnp.bfloat16
    qn = np.asarray(q)
    assert qn[0, 0] == -127 and qn[0, 2] == 127    # amax maps to ±127
    assert qn[0, 1] == 0


# ----------------------------------------------------- wire-byte parity ---


@pytest.mark.parametrize("rows,d_model", [(1, 32), (5, 48), (8, 128)])
def test_wire_accounting_matches_packed_payload(rows, d_model):
    """The books ARE the wire: with the backend's tuning (elem_bytes =
    compute-dtype bytes, row_elems = d_model), ``_wire(raw_nbytes)``
    equals the packed in-jit payload q.nbytes + scale.nbytes for the
    decode-plane activation shape (mb, d_model)."""
    x = jnp.asarray(np.random.RandomState(0).randn(rows, d_model),
                    jnp.float32)
    q, scale = int8_compress_rows(x)
    packed = q.nbytes + scale.nbytes
    tr = CompressedTransport(
        SimulatedLinkTransport.uniform(2, 0.0, stage_time_s=0.01),
        method="int8", elem_bytes=4, row_elems=d_model).bind(2)
    assert tr._wire(x.nbytes) == packed
    assert packed == int8_wire_bytes(rows * d_model, rows)


def test_wire_accounting_matches_prefill_payload():
    """Prefill-plane shape (rows, chunk, d_model): the codec quantizes
    the last axis, so n_rows = rows * chunk — the accounting must price
    one scale per (row, position), matching the packed payload."""
    rows, chunk, d_model = 2, 8, 48
    x = jnp.asarray(np.random.RandomState(1).randn(rows, chunk, d_model),
                    jnp.float32)
    q, scale = int8_compress_rows(x)
    assert scale.shape == (rows, chunk, 1)
    tr = CompressedTransport(
        SimulatedLinkTransport.uniform(2, 0.0, stage_time_s=0.01),
        method="int8", elem_bytes=4, row_elems=d_model).bind(2)
    assert tr._wire(x.nbytes) == q.nbytes + scale.nbytes


def test_wire_default_row_elems_is_one_scale_per_payload():
    """Back-compat: without row_elems (the what-if accounting mode) a
    payload is priced as one scale total — the historical 1 byte/elem
    + 4 behaviour the seed tests pin down."""
    tr = CompressedTransport(
        SimulatedLinkTransport.uniform(2, 0.0, stage_time_s=0.01),
        method="int8").bind(2)
    assert tr._wire(4096) == 1024 + 4
