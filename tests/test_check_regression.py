"""Exit-code contract of ``benchmarks/check_regression.py``: 0 clean,
1 regression, 2 a gated workload stopped being measured (downgradable
with ``--allow-missing``), 0 when the baseline *file* is absent."""

import json
import subprocess
import sys
from pathlib import Path

SCRIPT = Path(__file__).resolve().parents[1] / "benchmarks" / \
    "check_regression.py"


def _rows(decode=100.0, prefill=200.0, vtps=50.0):
    """One comparable row per gated workload."""
    return {"rows": [
        {"bench": "engine_backend", "policy": "local",
         "decode_tps": decode},
        {"bench": "engine_prefill", "policy": "local",
         "prefill_tps": prefill},
        {"bench": "latency_curve", "policy": "circular", "latency": 0.05,
         "bandwidth": 0.0, "vtps": vtps},
    ]}


def _drop_bench(data, bench):
    data["rows"] = [r for r in data["rows"] if r["bench"] != bench]
    return data


def _run(tmp_path, base, new, *extra):
    b, n = tmp_path / "base.json", tmp_path / "new.json"
    b.write_text(json.dumps(base))
    n.write_text(json.dumps(new))
    return subprocess.run(
        [sys.executable, str(SCRIPT), "--baseline", str(b),
         "--new", str(n), *extra],
        capture_output=True, text=True, timeout=60)


def test_clean_run_exits_zero(tmp_path):
    r = _run(tmp_path, _rows(), _rows())
    assert r.returncode == 0, r.stdout
    assert "REGRESSION" not in r.stdout


def test_regression_exits_one(tmp_path):
    r = _run(tmp_path, _rows(), _rows(decode=50.0))   # -50% > 30% gate
    assert r.returncode == 1, r.stdout
    assert "REGRESSION" in r.stdout


def test_within_threshold_is_ok(tmp_path):
    r = _run(tmp_path, _rows(), _rows(decode=80.0))   # -20% < 30% gate
    assert r.returncode == 0, r.stdout


def test_missing_workload_exits_two(tmp_path):
    new = _drop_bench(_rows(), "latency_curve")
    r = _run(tmp_path, _rows(), new)
    assert r.returncode == 2, r.stdout
    assert "stopped measuring" in r.stdout


def test_allow_missing_downgrades_two_to_zero(tmp_path):
    new = _drop_bench(_rows(), "latency_curve")
    r = _run(tmp_path, _rows(), new, "--allow-missing")
    assert r.returncode == 0, r.stdout
    assert "--allow-missing" in r.stdout


def test_regression_outranks_missing(tmp_path):
    # both a regression and a dropped workload: 1 wins (CI must fail red,
    # not "needs attention")
    new = _drop_bench(_rows(decode=50.0), "latency_curve")
    r = _run(tmp_path, _rows(), new)
    assert r.returncode == 1, r.stdout


def test_absent_baseline_file_exits_zero(tmp_path):
    n = tmp_path / "new.json"
    n.write_text(json.dumps(_rows()))
    r = subprocess.run(
        [sys.executable, str(SCRIPT), "--baseline",
         str(tmp_path / "nope.json"), "--new", str(n)],
        capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, r.stdout
    assert "no usable baseline" in r.stdout
