"""Minimal stand-in for the `hypothesis` API surface this repo uses.

Loaded only when the real package is unavailable (see ``conftest.py``):
``@given`` then runs the test body over a deterministic pseudo-random
sample of the strategy space instead of hypothesis' adaptive search —
the properties are still exercised, just without shrinking.
"""

from __future__ import annotations

import functools
import inspect
import random
import types

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, sampled_from=_sampled_from)


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = getattr(run, "_shim_max_examples", _DEFAULT_EXAMPLES)
            rnd = random.Random(0)
            for _ in range(n):
                drawn = {k: s._draw(rnd) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # hide the strategy-bound parameters from pytest's fixture
        # resolution (the real hypothesis does the same)
        sig = inspect.signature(fn)
        run.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        if hasattr(run, "__wrapped__"):
            del run.__wrapped__
        return run
    return deco
