"""Per-request generation API: sample_batched properties, mixed-sampling
microbatches (greedy rows bit-identical to an all-greedy engine; local ==
pipelined per request), LLM / EngineConfig / RequestOutput lifecycle,
status/stats accounting, and run() drain surfacing."""

import logging
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from equivalence import assert_equivalent, golden_runs, run_llm, \
    subprocess_env
from repro.models import model as M
from repro.serving.kv_cache import PoolConfig
from repro.serving.llm import LLM, EngineConfig, RequestOutput
from repro.serving.request import (FinishReason, Request, SamplingParams,
                                   Status)
from repro.serving.sampler import (RowSampling, fold_in_steps, sample,
                                   sample_batched, token_logprobs)

# ---------------------------------------------------------- sample_batched

V = 32


def _rand_logits(n, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, V)) * 3.0


def _keys(n, seed=7):
    return jnp.stack([jax.random.fold_in(jax.random.PRNGKey(seed), i)
                      for i in range(n)])


def test_sample_batched_greedy_rows_match_argmax():
    logits = _rand_logits(8)
    toks = sample_batched(logits, _keys(8), jnp.zeros(8),
                          jnp.zeros(8, jnp.int32), jnp.ones(8))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_batched_top_p_one_is_noop():
    """top_p=1.0 (and top_k=0) adds no truncation: per-row draws equal the
    static path, which skips the top-p/top-k branches entirely."""
    logits = _rand_logits(6, seed=1)
    keys = _keys(6)
    sp = SamplingParams(temperature=1.3, top_k=0, top_p=1.0)
    batched = sample_batched(logits, keys, jnp.full((6,), 1.3),
                             jnp.zeros((6,), jnp.int32), jnp.ones(6))
    for i in range(6):
        assert int(batched[i]) == int(sample(logits[i:i + 1], keys[i],
                                             sp)[0]), i


def test_sample_batched_top_k_one_is_greedy():
    logits = _rand_logits(8, seed=2)     # continuous → untied a.s.
    toks = sample_batched(logits, _keys(8), jnp.full((8,), 5.0),
                          jnp.ones((8,), jnp.int32), jnp.ones(8))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_batched_tiny_top_p_is_greedy():
    logits = _rand_logits(8, seed=3)
    toks = sample_batched(logits, _keys(8), jnp.full((8,), 2.0),
                          jnp.zeros((8,), jnp.int32), jnp.full((8,), 1e-6))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_batched_tie_handling_keeps_cutoff_ties():
    # two tied maxima: top-k=1 keeps both (mask is `logits < cutoff`) and
    # every draw lands on one of them
    row = jnp.asarray([0.0, 4.0, 4.0, -1.0])
    logits = jnp.tile(row, (20, 1))
    toks = np.asarray(sample_batched(
        logits, _keys(20, seed=11), jnp.ones(20),
        jnp.ones((20,), jnp.int32), jnp.ones(20)))
    assert set(toks.tolist()) <= {1, 2}
    # top-k restriction holds row-wise: never a non-tied token
    assert 0 not in toks and 3 not in toks


def test_sample_batched_respects_top_k_support():
    logits = _rand_logits(64, seed=4)
    k = 3
    toks = np.asarray(sample_batched(
        logits, _keys(64, seed=5), jnp.full((64,), 4.0),
        jnp.full((64,), k, jnp.int32), jnp.ones(64)))
    top3 = np.asarray(jax.lax.top_k(logits, k)[1])
    for i, t in enumerate(toks):
        assert t in top3[i], i


def test_sample_batched_matches_static_sample_per_row():
    """Mask-based per-row path == the static-dispatch reference under the
    same key and params."""
    logits = _rand_logits(6, seed=6)
    keys = _keys(6, seed=9)
    sp = SamplingParams(temperature=1.1, top_k=5, top_p=0.8)
    batched = sample_batched(
        logits, keys, jnp.full((6,), sp.temperature),
        jnp.full((6,), sp.top_k, jnp.int32), jnp.full((6,), sp.top_p))
    for i in range(6):
        ref = sample(logits[i:i + 1], keys[i], sp)
        assert int(batched[i]) == int(ref[0]), i


def test_fold_in_steps_and_logprobs():
    keys = _keys(3)
    folded = fold_in_steps(keys, jnp.asarray([0, 1, 2]))
    assert folded.shape == (3, 2)
    ref = jax.random.fold_in(keys[1], 1)
    np.testing.assert_array_equal(np.asarray(folded[1]), np.asarray(ref))
    logits = _rand_logits(3)
    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    lps = token_logprobs(logits, toks)
    ref_lp = jax.nn.log_softmax(logits, -1)[jnp.arange(3), toks]
    np.testing.assert_allclose(np.asarray(lps), np.asarray(ref_lp),
                               rtol=1e-6)


# ------------------------------------------------------------ mixed batch

POOL = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                  max_pages_per_seq=8)


def _mixed_sps(max_new=5):
    return [SamplingParams(temperature=0.0, max_new_tokens=max_new),
            SamplingParams(temperature=1.0, top_k=8, max_new_tokens=max_new),
            SamplingParams(temperature=0.7, top_p=0.9,
                           max_new_tokens=max_new),
            SamplingParams(temperature=1.5, max_new_tokens=max_new)]


def _prompts(cfg, n, seed=0, length=6):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, cfg.vocab_size, length)) for _ in range(n)]


def test_mixed_batch_greedy_rows_bit_identical_to_all_greedy(rt):
    """One microbatch mixing greedy + temperature + top-k/top-p: the greedy
    request's tokens equal those of an all-greedy engine, bit for bit."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    prompts = _prompts(cfg, 4)

    mixed_out, _ = run_llm(cfg, params, rt, prompts, _mixed_sps(),
                           mb_size=4, num_microbatches=1, pool=POOL)
    greedy_out, _ = run_llm(cfg, params, rt, prompts,
                            SamplingParams(temperature=0.0,
                                           max_new_tokens=5),
                            mb_size=4, num_microbatches=1, pool=POOL)

    assert mixed_out[0] == greedy_out[0]        # the greedy request
    # sampled rows proved they're actually sampling (almost surely differ)
    assert any(mixed_out[i] != greedy_out[i] for i in (1, 2, 3))


def test_mixed_sampling_reproducible_across_layout_and_order(rt):
    """(seed, request_id) keys: same outputs per request across microbatch
    layouts and admission orders."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    prompts = _prompts(cfg, 4, seed=5)
    sps = _mixed_sps(max_new=4)

    runs = golden_runs(cfg, params, rt, prompts, sps, {
        "4x1": dict(mb_size=4, num_microbatches=1, pool=POOL),
        "2x2": dict(mb_size=2, num_microbatches=2, pool=POOL),
    })
    assert_equivalent(runs, base="4x1")
    a = {rid: list(toks) for rid, (toks, _) in runs["4x1"].items()}

    # admission order: same request ids submitted shuffled
    def by_order(order):
        llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
            mb_size=2, num_microbatches=2, pool=POOL))
        llm.engine.submit([Request(i, prompts[i], sps[i]) for i in order])
        llm.engine.run(max_steps=400)
        return {s.request.request_id: s.generated
                for s in llm.engine.finished}

    assert by_order([0, 1, 2, 3]) == by_order([2, 0, 3, 1]) == a


MIXED_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
import jax.numpy as jnp
from equivalence import assert_equivalent, golden_runs, random_prompts
from repro.config import get_arch, reduced_config
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.kv_cache import PoolConfig
from repro.serving.llm import SamplingParams

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg = reduced_config(get_arch("yi-9b"))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                  max_pages_per_seq=8)
prompts = random_prompts(cfg, 6, seed=3, lo=3, hi=16)
sps = [SamplingParams(temperature=0.0, max_new_tokens=4),
       SamplingParams(temperature=1.0, top_k=8, max_new_tokens=4),
       SamplingParams(temperature=0.7, top_p=0.9, max_new_tokens=4),
       SamplingParams(temperature=0.0, max_new_tokens=4),
       SamplingParams(temperature=1.5, max_new_tokens=4),
       SamplingParams(temperature=1.0, top_k=4, top_p=0.8,
                      max_new_tokens=4)]
common = dict(pool=pool, offload=True, mb_size=2, num_microbatches=2,
              n_stages=2, prefill_chunk=4, max_prefill_tokens_per_tick=8)
runs = golden_runs(cfg, params, rt, prompts, sps, {
    f"{backend}/{mode}": dict(backend=backend, prefill_mode=mode, **common)
    for backend in ("local", "pipelined") for mode in ("chunked", "exact")})
assert_equivalent(runs, base="local/exact")
print("MIXED-OK")
"""


@pytest.mark.slow
def test_mixed_sampling_local_pipelined_equivalence():
    """Acceptance: a mixed greedy+sampled workload produces identical
    per-request token streams across LocalBackend vs the 2-stage pipe AND
    chunked (multi-chunk prompts) vs exact-length prefill — all four
    combinations bit-identical per request."""
    r = subprocess.run([sys.executable, "-c", MIXED_EQUIV_SCRIPT],
                       env=subprocess_env(), capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "MIXED-OK" in r.stdout


# ------------------------------------------------------- LLM / lifecycle

def test_engine_config_validation():
    with pytest.raises(ValueError, match="backend"):
        EngineConfig(backend="tpu")
    with pytest.raises(ValueError, match="mb_size"):
        EngineConfig(mb_size=0)
    with pytest.raises(ValueError, match="N_B >= N_S"):
        EngineConfig(backend="pipelined", num_microbatches=1, n_stages=2)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0).validate()
    with pytest.raises(ValueError, match="max_new_tokens"):
        SamplingParams(max_new_tokens=0).validate()


def test_engine_config_plan_builds_planned_engine(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pb = 2 * cfg.num_layers * 8 * cfg.num_kv_heads * cfg.head_dim * 4
    econfig = EngineConfig.plan(
        n_stages=2, stage_time=0.1, latency=0.02, m_kv_bytes=32.0 * pb,
        bandwidth=40.0 * pb, page_size=8, max_pages_per_seq=4,
        mb_size_cap=2, max_microbatches=8)
    llm = LLM(cfg, params=params, rt=rt, config=econfig)
    assert llm.engine.schedule_choice.n_microbatches >= 2
    assert llm.engine.mb_size <= 2
    outs = llm.generate(_prompts(cfg, 3, length=3),
                        SamplingParams(temperature=0.0, max_new_tokens=3))
    assert all(o.finished for o in outs)


def test_request_output_lifecycle_and_finish_reasons(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
        mb_size=1, num_microbatches=1,
        pool=PoolConfig(page_size=4, n_local_pages=16, max_pages_per_seq=2)))

    # length: short max_new inside the page budget
    out = llm.generate([[3, 4, 5]], SamplingParams(temperature=0.0,
                                                   max_new_tokens=2))[0]
    assert out.finished and out.finish_reason == FinishReason.LENGTH.value
    assert len(out.token_ids) == 2
    assert out.latency_steps is not None and out.latency_steps >= 1
    assert out.latency_s is not None and out.latency_s > 0

    # page_budget: max_new larger than the slot's page capacity (8 tokens)
    out = llm.generate([[3, 4, 5]], SamplingParams(temperature=0.0,
                                                   max_new_tokens=50))[0]
    assert out.finish_reason == FinishReason.PAGE_BUDGET.value
    assert len(out.token_ids) == 5               # 8-token capacity - 3 prompt

    # eos: make greedy's first pick the eos token
    logits, _ = M.prefill(params, {"tokens": jnp.asarray([[5, 6, 7]],
                                                         jnp.int32)},
                          cfg, rt, 64)
    eos = int(jnp.argmax(logits, -1)[0])
    out = llm.generate([[5, 6, 7]], SamplingParams(
        temperature=0.0, max_new_tokens=4, eos_token=eos))[0]
    assert out.finish_reason == FinishReason.EOS.value
    assert out.token_ids[-1] == eos


def test_logprobs_recorded_when_requested(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt,
              config=EngineConfig(mb_size=2, num_microbatches=1, pool=POOL))
    sps = [SamplingParams(temperature=0.0, max_new_tokens=4, logprobs=True),
           SamplingParams(temperature=0.0, max_new_tokens=4)]
    outs = llm.generate(_prompts(cfg, 2), sps)
    assert outs[0].logprobs is not None and len(outs[0].logprobs) == 4
    assert all(lp <= 0.0 for lp in outs[0].logprobs)
    assert outs[1].logprobs is None


def test_generate_iter_streams_snapshots(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt,
              config=EngineConfig(mb_size=1, num_microbatches=1, pool=POOL))
    finished_counts = []
    for snap in llm.generate_iter(_prompts(cfg, 3),
                                  SamplingParams(temperature=0.0,
                                                 max_new_tokens=3)):
        assert len(snap) == 3
        finished_counts.append(sum(o.finished for o in snap))
        in_flight = [o for o in snap if not o.finished]
        assert all(o.finish_reason is None for o in in_flight)
    assert finished_counts[-1] == 3
    assert finished_counts == sorted(finished_counts)  # monotone drain


def test_status_lifecycle_and_counts(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt,
              config=EngineConfig(mb_size=1, num_microbatches=1, pool=POOL))
    eng = llm.engine
    sp = SamplingParams(temperature=0.0, max_new_tokens=3)
    seqs = eng.submit([Request(i, [3 + i, 4, 5], sp) for i in range(3)])
    assert all(s.status is Status.QUEUED for s in seqs)
    assert eng.stats.queue_depth == 3

    # PREFILLING is visible while the backend runs the admitted seq's
    # chunk (chunked admission goes through prefill_step, not prefill)
    seen = []
    orig = eng.backend.prefill_step

    def spy(chunk):
        if chunk is not None:
            seen.append([s.status for s in seqs])
        return orig(chunk)

    eng.backend.prefill_step = spy
    assert eng.step()
    assert seen and seen[0][0] is Status.PREFILLING
    assert seqs[0].status is Status.DECODING
    assert seqs[1].status is Status.QUEUED
    counts = eng.status_counts()
    assert counts["decoding"] == 1 and counts["queued"] == 2
    assert eng.stats.queue_depth == 2

    eng.run(max_steps=200)
    assert all(s.status is Status.FINISHED for s in seqs)
    assert eng.status_counts()["finished"] == 3
    assert eng.stats.queue_depth == 0


def test_run_exhausted_budget_surfaces_partial_drain(rt, caplog):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt,
              config=EngineConfig(mb_size=1, num_microbatches=1, pool=POOL))
    eng = llm.engine
    sp = SamplingParams(temperature=0.0, max_new_tokens=6)
    eng.submit([Request(i, [3, 4, 5], sp) for i in range(4)])
    with caplog.at_level(logging.WARNING, logger="repro.serving.engine"):
        done = eng.run(max_steps=2)
    assert eng.stats.aborted
    assert len(done) < 4 and len(eng.pending()) == 4 - len(done)
    assert any("exhausted" in r.message for r in caplog.records)
    assert eng.throughput_report()["aborted"] is True
    # finishing the drain clears the flag
    done = eng.run(max_steps=500)
    assert len(done) == 4 and not eng.stats.aborted

    # generate_iter mirrors run(): exhausted budget with pending work sets
    # aborted, a clean streaming drain clears it
    for _ in llm.generate_iter([[3, 4, 5]], sp, max_steps=1):
        pass
    assert eng.stats.aborted
    for _ in llm.generate_iter([[3, 4, 5]], sp):
        pass
    assert not eng.stats.aborted


def test_wall_clock_and_latency_accounting(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt,
              config=EngineConfig(mb_size=2, num_microbatches=1, pool=POOL))
    outs = llm.generate(_prompts(cfg, 2),
                        SamplingParams(temperature=0.0, max_new_tokens=3))
    rep = llm.stats()
    assert rep["wall_time_s"] > 0
    assert rep["decode_tok_per_s"] > 0
    assert rep["mean_latency_steps"] >= 1
    assert rep["mean_latency_s"] > 0
    for o in outs:
        assert o.latency_steps is not None and o.latency_steps >= 1


def test_generate_per_prompt_params_length_mismatch(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt,
              config=EngineConfig(mb_size=1, num_microbatches=1, pool=POOL))
    with pytest.raises(ValueError, match="sampling_params"):
        llm.generate([[1, 2], [3, 4]], [SamplingParams()])
