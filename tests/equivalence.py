"""Shared cross-backend equivalence fixture.

Every resilience/parity test in this repo asks the same question: does a
set of requests produce *bit-identical* per-request token streams under
two engine configurations (local vs pipelined, chunked vs exact prefill,
faulted vs undisturbed, resharded vs static)?  This module is the one
parametrized answer — build the runs with :func:`run_llm` /
:func:`golden_runs`, compare with :func:`assert_equivalent`.

Importable both from the pytest process (tests dir is on ``sys.path``)
and from the SPMD subprocess scripts (they add the tests dir to
``PYTHONPATH`` — see :func:`subprocess_env`).  No conftest / fixture
dependencies on purpose.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


def subprocess_env(extra: Optional[dict] = None) -> dict:
    """Environment for the SPMD subprocess tests: repo ``src`` plus this
    directory (so scripts can ``import equivalence``)."""
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), here])
    env.update(extra or {})
    return env


def random_prompts(cfg, n: int, seed: int = 0, lo: int = 3,
                   hi: int = 20) -> List[List[int]]:
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, cfg.vocab_size, rng.randint(lo, hi)))
            for _ in range(n)]


def mixed_sps(n: int, max_new: int = 5):
    """Greedy + temperature + top-k + top-p cycled over ``n`` requests —
    one engine run serves all of them through the same pipe."""
    from repro.serving.request import SamplingParams
    pol = [SamplingParams(temperature=0.0, max_new_tokens=max_new),
           SamplingParams(temperature=1.0, top_k=8, max_new_tokens=max_new),
           SamplingParams(temperature=0.7, top_p=0.9,
                          max_new_tokens=max_new),
           SamplingParams(temperature=1.5, max_new_tokens=max_new)]
    return [pol[i % len(pol)] for i in range(n)]


def run_llm(cfg, params, rt, prompts, sps, *, max_steps: int = 2000,
            step_hook: Optional[Callable] = None, **config_kw):
    """One full engine run; returns ``({request_id: (tokens, reason)},
    llm)``.

    ``config_kw`` goes straight into :class:`EngineConfig` — backend,
    n_stages, prefill_mode, fault_plan, pool, ...  ``step_hook(engine,
    step_index)`` (if given) fires after every engine step: the seam the
    fault/reshard tests use to disturb a run mid-flight."""
    from repro.serving.llm import LLM, EngineConfig
    llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(**config_kw))
    if step_hook is None:
        outs = llm.generate(prompts, sps, max_steps=max_steps)
    else:
        seqs = llm._submit(prompts, sps)
        step = 0
        while step < max_steps and llm.engine.step():
            step_hook(llm.engine, step)
            step += 1
        from repro.serving.llm import RequestOutput
        outs = [RequestOutput.from_seq(s) for s in seqs]
    assert all(o.finished for o in outs), \
        f"unfinished requests: {[o.request_id for o in outs if not o.finished]}"
    return {o.request_id: (tuple(o.token_ids), o.finish_reason)
            for o in outs}, llm


def golden_runs(cfg, params, rt, prompts, sps, variants: Dict[str, dict],
                *, max_steps: int = 2000) -> Dict[str, dict]:
    """Run the same request set under every variant's EngineConfig kwargs
    (plus optional ``step_hook``); returns {label: outputs}."""
    runs = {}
    for label, kw in variants.items():
        kw = dict(kw)
        hook = kw.pop("step_hook", None)
        runs[label], _ = run_llm(cfg, params, rt, prompts, sps,
                                 max_steps=max_steps, step_hook=hook, **kw)
    return runs


def assert_equivalent(runs: Dict[str, dict], base: Optional[str] = None):
    """Token-level equality of every run against ``base`` (default: the
    first label).  Failures name the variant, the request, and both
    streams."""
    labels = list(runs)
    base = base or labels[0]
    ref = runs[base]
    for label in labels:
        if label == base:
            continue
        run = runs[label]
        assert set(run) == set(ref), \
            f"{label}: request ids differ from {base}: " \
            f"{sorted(set(run) ^ set(ref))}"
        bad = {rid: (ref[rid], run[rid]) for rid in ref
               if run[rid] != ref[rid]}
        assert not bad, f"{label} != {base}: {bad}"
