"""On-chain framework (§6): registries + matching, escrow payments,
signature-based arbitration honouring the paper's three design principles."""

import itertools
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.framework.arbitration import ArbitrationModule, SignedResult
from repro.framework.payment import PaymentError, PaymentModule
from repro.framework.registry import Registry


def _registry_with_fleet():
    reg = Registry()
    for i in range(4):
        reg.register_machine(f"miner{i}", 24 << 30, "us-west", stake=100)
    for i in range(2):
        reg.register_machine(f"miner{4+i}", 24 << 30, "us-east", stake=100)
    return reg


def test_match_prefers_single_region():
    reg = _registry_with_fleet()
    # fits in 3 us-west machines (3 * 0.8 * 24GB = 57.6GB)
    t = reg.register_task("alice", "llama3-70b", 50 << 30, 100, 1.0)
    m = reg.match(t.task_id)
    assert m is not None
    assert {x.region for x in m.machines} == {"us-west"}
    assert m.max_latency < 0.01
    assert t.status == "matched"
    assert all(x.status == "serving" for x in m.machines)
    reg.release(m)
    assert all(x.status == "idle" for x in m.machines)


def test_match_spans_regions_when_needed():
    reg = _registry_with_fleet()
    t = reg.register_task("bob", "huge", 100 << 30, 10, 1.0)  # needs > 4
    m = reg.match(t.task_id)
    assert m is not None
    assert len(m.machines) >= 6
    assert m.max_latency >= 0.05            # cross-country link in pipeline


def test_match_avoids_memory_greedy_latency_trap():
    """The biggest machine (eu) pulls the memory-greedy prefix across the
    Atlantic; the optimal set spans only us-east + us-west (0.058s).
    Guards the exact region-subset enumeration against regressions back
    to the prefix heuristic."""
    reg = Registry()
    for i in range(2):
        reg.register_machine(f"e{i}", 24 << 30, "us-east", stake=100)
        reg.register_machine(f"w{i}", 24 << 30, "us-west", stake=100)
    reg.register_machine("big", 48 << 30, "eu", stake=100)
    t = reg.register_task("alice", "llama3-70b", 60 << 30, 1, 1.0)
    m = reg.match(t.task_id)
    assert m is not None
    assert {x.region for x in m.machines} == {"us-east", "us-west"}
    assert abs(m.max_latency - 0.058) < 1e-12


_REGIONS = ["us-east", "us-west", "eu"]


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_machines=st.integers(min_value=1, max_value=7),
       model_gb=st.integers(min_value=1, max_value=150))
def test_match_properties(seed, n_machines, model_gb):
    """Property (satellite): any returned match (a) pools enough usable
    memory for the model and (b) attains the minimum max-pairwise latency
    over EVERY feasible machine subset (brute-forced); infeasible fleets
    return None and leave the task open."""
    rnd = random.Random(seed)
    reg = Registry()
    machines = [reg.register_machine(
        f"m{i}", rnd.choice([8, 16, 24, 48]) << 30,
        rnd.choice(_REGIONS), stake=100) for i in range(n_machines)]
    t = reg.register_task("u", "model", model_gb << 30, 1, 1.0)
    m = reg.match(t.task_id)

    feasible_lats = [
        Registry._group_latency(list(combo))
        for r in range(1, n_machines + 1)
        for combo in itertools.combinations(machines, r)
        if sum(x.usable_memory() for x in combo) >= t.model_bytes]
    if not feasible_lats:
        assert m is None
        assert t.status == "open"
        return
    assert m is not None
    assert sum(x.usable_memory() for x in m.machines) >= t.model_bytes
    assert abs(m.max_latency - min(feasible_lats)) < 1e-12
    assert m.max_latency == Registry._group_latency(m.machines)
    assert t.status == "matched"


def test_match_respects_stake_floor():
    reg = Registry()
    reg.register_machine("cheap", 24 << 30, "us-west", stake=1)
    t = reg.register_task("carol", "m", 1 << 30, 1, 1.0)
    assert reg.match(t.task_id, min_stake=50) is None


# ---------------------------------------------------------------- payment --

def test_escrow_lifecycle():
    pay = PaymentModule()
    pay.deposit("user", 100.0)
    e = pay.lock("user", task_id=0, amount=60.0)
    assert pay.balance("user") == 40.0
    pay.release(e.escrow_id, "miner")
    assert pay.balance("miner") == 60.0
    with pytest.raises(PaymentError):
        pay.release(e.escrow_id, "miner")       # double spend blocked
    with pytest.raises(PaymentError):
        pay.lock("user", 1, 1000.0)


def test_escrow_refund():
    pay = PaymentModule()
    pay.deposit("user", 10.0)
    e = pay.lock("user", 0, 10.0)
    pay.refund(e.escrow_id)
    assert pay.balance("user") == 10.0


# -------------------------------------------------------------- arbitration

def _setup_arbitration():
    pay = PaymentModule()
    arb = ArbitrationModule(pay)
    pay.deposit("miner", 100.0)
    key = arb.register_miner("miner", stake=80.0)
    arb.register_task_owner(7, "alice")
    return pay, arb, key


def test_signature_cost_is_the_only_overhead():
    """Principle 1: signing is a pure hash over the output."""
    _, _, key = _setup_arbitration()
    r = SignedResult.sign(7, 0, "miner", [1, 2, 3], key)
    assert r.verify_signature(key)
    assert r.matches_output([1, 2, 3])
    assert not r.matches_output([1, 2, 4])


def test_cheating_miner_slashed():
    pay, arb, key = _setup_arbitration()
    wrong = [9, 9, 9]
    r = SignedResult.sign(7, 0, "miner", wrong, key)
    d = arb.open_dispute("alice", r, claimed_output=wrong,
                         reference_output=[1, 2, 3])
    assert d.outcome == "slashed"
    assert arb.stakes["miner"] == 0.0
    assert pay.balance("alice") == 80.0


def test_honest_miner_not_slashed():
    pay, arb, key = _setup_arbitration()
    good = [1, 2, 3]
    r = SignedResult.sign(7, 0, "miner", good, key)
    d = arb.open_dispute("alice", r, claimed_output=good,
                         reference_output=good)
    assert d.outcome == "dismissed"
    assert arb.stakes["miner"] == 80.0


def test_third_party_cannot_challenge():
    """Principle 3: no DoS via arbitrary verifiers."""
    _, arb, key = _setup_arbitration()
    r = SignedResult.sign(7, 0, "miner", [1], key)
    with pytest.raises(PermissionError):
        arb.open_dispute("mallory", r, [1], [2])


def test_unsigned_results_cannot_be_disputed():
    _, arb, key = _setup_arbitration()
    r = SignedResult.sign(7, 0, "miner", [1], key)
    forged = SignedResult(task_id=7, request_id=0, miner="miner",
                          output_hash=r.output_hash, signature="00" * 32)
    with pytest.raises(PermissionError):
        arb.open_dispute("alice", forged, [1], [2])


def test_forged_output_hash_dismissed():
    """A claimant cannot slash by presenting output the miner never signed."""
    _, arb, key = _setup_arbitration()
    r = SignedResult.sign(7, 0, "miner", [1, 2, 3], key)
    d = arb.open_dispute("alice", r, claimed_output=[5, 5, 5],
                         reference_output=[1, 2, 3])
    assert d.outcome == "dismissed"


def test_full_protocol_flow():
    """User registers task + escrow; miner serves; payment released; the
    signed transcript stays verifiable afterwards."""
    reg = Registry()
    pay = PaymentModule()
    arb = ArbitrationModule(pay)
    pay.deposit("user", 50.0)
    pay.deposit("miner0", 20.0)
    mkey = arb.register_miner("miner0", stake=15.0)
    reg.register_machine("miner0", 24 << 30, "us-west", stake=15.0)
    task = reg.register_task("user", "yi-9b", 10 << 30, 4, 0.9)
    arb.register_task_owner(task.task_id, "user")
    escrow = pay.lock("user", task.task_id, 25.0)
    match = reg.match(task.task_id)
    assert match is not None
    outputs = [11, 22, 33]
    result = SignedResult.sign(task.task_id, 0, "miner0", outputs, mkey)
    assert result.verify_signature(mkey)
    pay.release(escrow.escrow_id, "miner0")
    reg.release(match)
    assert pay.balance("miner0") == 30.0        # 5 left after stake + 25
    d = arb.open_dispute("user", result, outputs, outputs)
    assert d.outcome == "dismissed"
