"""Online serving front end: refcounted page sharing, the prefix-block
index, SLO-shaped admission, and submit-while-running token streams —
with bit-identity against offline ``LLM.generate`` on both backends."""

import jax
import numpy as np
import pytest

from conftest import tiny
from equivalence import assert_equivalent, mixed_sps, run_llm
from repro.models import model as M
from repro.serving.engine import SLOConfig, SLOController
from repro.serving.kv_cache import PageAllocator, PoolConfig, PrefixCache
from repro.serving.llm import LLM, EngineConfig
from repro.serving.online import OnlineLLM
from repro.serving.request import SamplingParams

POOL = PoolConfig(page_size=4, n_local_pages=32, n_global_pages=0,
                  max_pages_per_seq=8)
OFF_POOL = PoolConfig(page_size=4, n_local_pages=24, n_global_pages=8,
                      max_pages_per_seq=8)


# ------------------------------------------------------ allocator refcounts ---

def test_refcounted_sharing_and_release():
    al = PageAllocator(PoolConfig(page_size=4, n_local_pages=8,
                                  max_pages_per_seq=8))
    base = al.allocate(0, 3)
    assert all(al.refcount(p) == 1 for p in base)
    # slot 1 adopts slot 0's pages as a shared prefix, then grows its own
    al.adopt(1, base[:2])
    al.allocate(1, 1)
    assert al.refcount(base[0]) == 2 and al.refcount(base[2]) == 1
    assert al.pages_of(1)[:2] == base[:2]       # shared pages head the row
    free_before = al.free_local()
    al.release(0)                               # shared pages stay live
    assert al.free_local() == free_before + 1   # only the unshared page
    assert al.refcount(base[0]) == 1
    al.release(1)
    assert al.free_local() == 7                 # page 0 stays scratch


def test_double_release_and_double_free_raise():
    al = PageAllocator(PoolConfig(page_size=4, n_local_pages=8,
                                  max_pages_per_seq=8))
    [p] = al.allocate(0, 1)
    al.release(0)
    with pytest.raises(KeyError, match="double release"):
        al.release(0)
    with pytest.raises(KeyError, match="owns no pages"):
        al.release(5)                           # never allocated
    with pytest.raises(ValueError, match="double free"):
        al._decref(p)                           # page already on free list
    with pytest.raises(ValueError, match="twice"):
        al._give_back(p)


def test_adopt_and_retain_validation():
    al = PageAllocator(PoolConfig(page_size=4, n_local_pages=8,
                                  max_pages_per_seq=8))
    pages = al.allocate(0, 2)
    free = next(p for p in range(1, 8) if p not in pages)
    with pytest.raises(ValueError, match="not currently owned"):
        al.adopt(1, [free])                     # free page is unshareable
    with pytest.raises(ValueError, match="not currently owned"):
        al.retain(free)
    al.adopt(1, pages)
    with pytest.raises(ValueError, match="already owns"):
        al.adopt(1, pages)                      # prefix must head the row
    # cache-style retain/drop: page survives both slots releasing
    al.retain(pages[0])
    al.release(0)
    al.release(1)
    assert al.refcount(pages[0]) == 1
    assert al.drop(pages[0]) is True            # last owner -> freed
    assert al.free_local() == 7


# ----------------------------------------------------------- prefix cache ---

def test_prefix_cache_match_insert_evict():
    al = PageAllocator(PoolConfig(page_size=4, n_local_pages=16,
                                  max_pages_per_seq=8))
    pc = PrefixCache(al)
    prompt = list(range(100, 116))              # 16 tokens = 4 pages
    pages = al.allocate(0, 4)
    # only FULL pages below prompt_len-1 are cacheable: 15//4 = 3 entries
    assert pc.insert(prompt, pages) == 3
    assert len(pc) == 3
    assert all(al.refcount(p) == 2 for p in pages[:3])
    assert al.refcount(pages[3]) == 1
    # longest-prefix match, capped the same way; stats update
    assert pc.match(prompt) == pages[:3]
    assert pc.match(prompt[:9]) == pages[:2]    # (9-1)//4 = 2 full pages
    assert pc.match([1, 2, 3, 4, 5]) == []      # different first block
    assert pc.hit_requests == 2 and pc.miss_requests == 1
    assert pc.hit_tokens == 3 * 4 + 2 * 4
    # re-inserting the same prefix keeps the incumbent pages
    other = al.allocate(1, 3)
    assert pc.insert(prompt[:13], other) == 0
    al.release(1)
    # eviction only counts pages actually freed: with slot 0 still owning
    # them, dropping every entry frees nothing
    al.release(0)
    assert pc.evict(1) == 1                     # LRU leaf goes first
    assert len(pc) == 2
    assert pc.clear() == 2
    assert al.free_local() == 15                # everything back


def test_prefix_cache_rejects_global_pages():
    al = PageAllocator(PoolConfig(page_size=4, n_local_pages=4,
                                  n_global_pages=4, max_pages_per_seq=8))
    pc = PrefixCache(al)
    pages = al.allocate(0, 5, global_pool=0)    # 3 local + 2 global
    prompt = list(range(21))                    # 5 full pages worth
    # insert stops at the first global page (parity-swapped content)
    assert pc.insert(prompt, pages) == 3
    assert all(p < 4 for p in (e for e in pc.pages_retained()))


# ------------------------------------------------------------ SLO shaping ---

def test_slo_controller_budget_shaping():
    with pytest.raises(ValueError, match="floor_frac"):
        SLOController(SLOConfig(floor_frac=0.0))
    with pytest.raises(ValueError, match=">= 0"):
        SLOController(SLOConfig(ttft_target_s=-1.0))
    # no targets: never sheds
    c = SLOController(SLOConfig())
    c.observe_tick(10.0)
    assert c.budget_frac(100.0) == 1.0
    # ITL above target: budget shrinks proportionally, floored
    c = SLOController(SLOConfig(itl_target_s=0.1, floor_frac=0.25,
                                ewma_alpha=1.0))
    c.observe_tick(0.05)
    assert c.budget_frac(0.0) == 1.0            # under target: full budget
    c.observe_tick(0.2)
    assert c.budget_frac(0.0) == pytest.approx(0.5)
    c.observe_tick(10.0)
    assert c.budget_frac(0.0) == 0.25           # floored, never starves
    # TTFT override: an old-enough waiter restores the full budget
    c = SLOController(SLOConfig(ttft_target_s=1.0, itl_target_s=0.1,
                                ewma_alpha=1.0))
    c.observe_tick(10.0)
    assert c.budget_frac(0.1) < 1.0
    assert c.budget_frac(0.5) == 1.0


def test_engine_config_gates_prefix_cache():
    with pytest.raises(ValueError, match="chunked"):
        EngineConfig(prefix_cache=True, prefill_mode="exact")


# ----------------------------------------------- engine-level prefix hits ---

def _llm(cfg, params, rt, *, prefix_cache=False, pool=POOL, **kw):
    base = dict(mb_size=2, num_microbatches=2, pool=pool, offload=False,
                prefill_chunk=4, max_prefill_tokens_per_tick=8,
                prefix_cache=prefix_cache)
    base.update(kw)
    return LLM(cfg, params=params, rt=rt, config=EngineConfig(**base))


def test_prefix_hits_share_blocks_and_skip_prefill(rt):
    """The second request sharing a system prompt adopts the first's
    pages: zero shared tokens re-prefilled, identical tokens to a
    cache-less engine, refcounts drop to cache-only after release."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    system = list(range(50, 62))                # 12 tokens = 3 full pages
    p1, p2 = system + [7, 8, 9, 10], system + [11, 12, 13]
    sp = SamplingParams(temperature=0.0, max_new_tokens=4)

    llm = _llm(cfg, params, rt, prefix_cache=True)
    eng = llm.engine
    [o1] = llm.generate([p1], sp)
    assert eng.stats.prefix_hits == 0           # cold cache
    cached = eng.prefix_cache.pages_retained()
    assert len(cached) == 3
    assert all(eng.alloc.refcount(p) == 1 for p in cached)  # cache-only

    [o2] = llm.generate([p2], sp)
    assert eng.stats.prefix_hits == 1
    assert eng.stats.prefix_hit_tokens == 12    # the whole system prompt
    # computed prefill = everything submitted minus the shared blocks
    assert eng.stats.prefill_tokens == len(p1) + len(p2) - 12
    # released: refcounts are back to cache-only, nothing leaked
    assert all(eng.alloc.refcount(p) == 1 for p in cached)
    assert eng.prefix_cache.hit_rate == 0.5

    # bit-identity against a cache-less engine (greedy: id-independent)
    ref = _llm(cfg, params, rt, prefix_cache=False)
    [r1] = ref.generate([p1], sp)
    [r2] = ref.generate([p2], sp)
    assert o1.token_ids == r1.token_ids
    assert o2.token_ids == r2.token_ids

    # clearing the cache returns every page: free list back to full
    eng.prefix_cache.clear()
    assert eng.alloc.free_local() == POOL.n_local_pages - 1


def test_prefix_cache_evicts_under_pool_pressure(rt):
    """When the pool runs dry, admission evicts LRU cached blocks instead
    of failing the allocate."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    small = PoolConfig(page_size=4, n_local_pages=8, max_pages_per_seq=8)
    sp = SamplingParams(temperature=0.0, max_new_tokens=2)
    llm = _llm(cfg, params, rt, prefix_cache=True, pool=small,
               mb_size=1, num_microbatches=1)
    eng = llm.engine
    # two disjoint prompts fill the cache; the third needs eviction
    llm.generate([list(range(100, 112))], sp)
    llm.generate([list(range(200, 212))], sp)
    assert len(eng.prefix_cache) > 0
    llm.generate([list(range(300, 314))], sp)   # forces eviction, succeeds
    assert eng.prefix_cache.evictions > 0


# ------------------------------------------------------- streaming online ---

def test_stream_delivers_before_later_submission_finishes(rt):
    """Submit-while-running: the first request's tokens arrive while a
    second submission is still queued/prefilling, and both finish with
    offline-identical streams."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    p1, p2 = list(range(40, 52)), list(range(60, 70))
    sp = SamplingParams(temperature=0.0, max_new_tokens=5)

    online = OnlineLLM(llm=_llm(cfg, params, rt))
    s1 = online.submit(p1, sp)
    ev = s1.next_event()                        # cooperative: steps inline
    assert ev is not None and ev.index == 0
    s2 = online.submit(p2, sp)                  # joins the LIVE loop
    assert not s2.finished
    ev2 = s1.next_event()                       # first stream keeps flowing
    assert ev2 is not None and ev2.index == 1
    assert s1.tokens() == [ev.token, ev2.token]
    out1, out2 = s1.result(), s2.result()
    assert out1.finished and out2.finished
    assert s1.ttft_s is not None and s1.ttft_s > 0
    assert len(s1.inter_token_s()) == len(out1.token_ids) - 1
    # the last event carries the finish flag + reason
    assert out1.finish_reason == "length"

    # offline reference with the same (request_id, prompt) assignment
    ref = _llm(cfg, params, rt).generate([p1, p2], sp)
    assert out1.token_ids == ref[0].token_ids
    assert out2.token_ids == ref[1].token_ids


def test_threaded_pump_streams_and_closes(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    sp = SamplingParams(temperature=0.0, max_new_tokens=4)
    with OnlineLLM(llm=_llm(cfg, params, rt)).start() as online:
        s = online.submit(list(range(30, 40)), sp)
        out = s.result()                        # blocks on the pump's cv
    assert out.finished and len(out.token_ids) == 4
    ref = _llm(cfg, params, rt).generate([list(range(30, 40))], sp)
    assert out.token_ids == ref[0].token_ids


def _run_online(cfg, params, rt, prompts, sps, **config_kw):
    """Online counterpart of equivalence.run_llm: submit everything into
    the live loop (ids follow submission order), cooperative drain."""
    online = OnlineLLM(llm=LLM(cfg, params=params, rt=rt,
                               config=EngineConfig(**config_kw)))
    streams = [online.submit(p, sp) for p, sp in zip(prompts, sps)]
    outs = [s.result() for s in streams]
    assert all(o.finished for o in outs)
    return {o.request_id: (tuple(o.token_ids), o.finish_reason)
            for o in outs}


@pytest.mark.parametrize("backend", ["local", "pipelined"])
def test_online_bit_identical_to_offline(rt, backend):
    """Acceptance: streamed online outputs == offline LLM.generate for
    the same (seed, request_id) set — mixed sampling policies, with and
    without prefix caching, on both backends (shared 12-token system
    prompt so the cached run actually shares blocks)."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    rng = np.random.RandomState(5)
    system = list(rng.randint(1, cfg.vocab_size, 12))
    prompts = [system + list(rng.randint(1, cfg.vocab_size,
                                         rng.randint(3, 10)))
               for _ in range(5)]
    sps = mixed_sps(5, max_new=4)
    common = dict(mb_size=2, num_microbatches=2, pool=OFF_POOL,
                  offload=True, prefill_chunk=4,
                  max_prefill_tokens_per_tick=8, backend=backend,
                  n_stages=1)
    offline, _ = run_llm(cfg, params, rt, prompts, sps, **common)
    runs = {
        "offline": offline,
        "online": _run_online(cfg, params, rt, prompts, sps, **common),
        "online_prefix": _run_online(cfg, params, rt, prompts, sps,
                                     prefix_cache=True, **common),
        "online_slo": _run_online(
            cfg, params, rt, prompts, sps,
            slo=SLOConfig(ttft_target_s=0.5, itl_target_s=0.005),
            **common),
    }
    assert_equivalent(runs, base="offline")
