"""Smoke the CLI drivers (deliverable b): serve.py and train.py run
end-to-end in fresh interpreters with tiny configs."""

import os
import subprocess
import sys

import pytest

ENV = dict(os.environ,
           PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
def test_serve_driver():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi-9b",
         "--requests", "4", "--max-new", "6", "--microbatches", "2",
         "--mb-size", "1"],
        env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "finished 4/4 requests" in r.stdout
    assert "break-even" in r.stdout


@pytest.mark.slow
def test_serve_driver_networked_with_failure_loop():
    """serve.py satellites: simulated WAN links (virtual clock +
    compressed wire accounting) AND the live FailureDetector loop
    resharding the pipe when a killed device misses its heartbeats —
    no explicit --reshard-at stage target."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi-9b",
         "--requests", "6", "--max-new", "8", "--backend", "pipelined",
         "--stages", "2", "--microbatches", "3", "--mb-size", "1",
         "--detect-failures", "2", "--kill-device", "6:1",
         "--heartbeat-clock", "steps"],
        env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "failure detected at step" in r.stdout
    assert "resharded 2 -> 1 stage(s)" in r.stdout
    assert "finished 6/6 requests" in r.stdout

    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi-9b",
         "--requests", "4", "--max-new", "6", "--backend", "pipelined",
         "--stages", "2", "--microbatches", "2", "--mb-size", "1",
         "--link-latency", "0.064", "--transport-compress", "int8",
         "--schedule", "round_flush"],
        env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "links: uniform 64ms" in r.stdout
    assert "transport: compressed[int8]>simulated" in r.stdout
    assert "finished 4/4 requests" in r.stdout


@pytest.mark.slow
def test_train_driver_with_resume(tmp_path):
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "gemma3-1b", "--steps", "4", "--batch", "2", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
            "--log-every", "2"]
    r = subprocess.run(base, env=ENV, capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: loss" in r.stdout
    r2 = subprocess.run(base + ["--resume"], env=ENV, capture_output=True,
                        text=True, timeout=560)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step" in r2.stdout
