"""Chunked prefill as a first-class scheduler phase: batched/budgeted
admission, bit-identity with the exact-length path, prefill through the
pipe without blocking in-flight decode, page-exhaustion admission, the
submit() no-mutation contract, phase-split stats, and pow2 bucketing of
the recurrent exact-length fallback."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from equivalence import (assert_equivalent, mixed_sps, random_prompts,
                         run_llm, subprocess_env)
from repro.models import model as M
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.llm import LLM, EngineConfig
from repro.serving.request import (FinishReason, Request, SamplingParams,
                                   Status)

POOL = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                  max_pages_per_seq=8)

_prompts = random_prompts               # shared fixture (tests/equivalence)
_mixed_sps = mixed_sps


# ------------------------------------------------------ chunked == exact ---

def test_chunked_prefill_bit_identical_to_exact_local(rt):
    """Acceptance: multi-chunk prefill (chunk=4, prompts up to 19 tokens,
    2 rows per tick) produces bit-identical greedy AND sampled token
    streams to the exact-length path on LocalBackend."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    prompts = _prompts(cfg, 6, seed=3)
    sps = _mixed_sps(6)
    runs = {}
    for mode in ("exact", "chunked"):
        runs[mode], llm = run_llm(
            cfg, params, rt, prompts, sps, mb_size=2, num_microbatches=2,
            pool=POOL, offload=True, prefill_mode=mode, prefill_chunk=4,
            max_prefill_tokens_per_tick=8)
        assert llm.engine.chunked_prefill == (mode == "chunked")
    assert_equivalent(runs, base="exact")


def test_chunked_prefill_single_fixed_shape_jit(rt):
    """The chunk jit compiles at one fixed (rows, chunk) shape: the
    per-length ``_prefill_jits`` dict stays empty on the chunked path
    even with many distinct prompt lengths."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
        mb_size=2, num_microbatches=1, pool=POOL, prefill_chunk=4))
    prompts = [list(range(1, 2 + n)) for n in (1, 3, 5, 7, 9, 11)]
    outs = llm.generate(prompts, SamplingParams(temperature=0.0,
                                                max_new_tokens=2))
    assert all(o.finished for o in outs)
    assert llm.engine.backend._prefill_jits == {}


def test_chunked_rejected_for_recurrent_archs(rt):
    cfg = tiny("recurrentgemma-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    with pytest.raises(ValueError, match="paged"):
        OfflineEngine(cfg, params, rt, pool=POOL, prefill_mode="chunked")
    # auto falls back to exact
    eng = OfflineEngine(cfg, params, rt, pool=POOL)
    assert not eng.chunked_prefill


def test_chunked_prefill_offload_residency_uses_real_microbatch(rt):
    """With N_B >= 3 the offloader keys host copies by *microbatch id*,
    not pool parity: a chunk writing global-pool pages of a microbatch-2
    slot must run with microbatch 2's copy resident, or the prompt KV is
    staged under the wrong host key and zeroed at the next swap."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    # 3 usable local pages force every sequence's pages into the global
    # pools; slots 0/2 share parity 0 with different microbatch ids
    pool = PoolConfig(page_size=8, n_local_pages=4, n_global_pages=16,
                      max_pages_per_seq=8)
    sp = SamplingParams(temperature=0.0, max_new_tokens=6)
    prompts = _prompts(cfg, 6, seed=9, lo=6, hi=16)
    runs = {}
    for mode in ("exact", "chunked"):
        runs[mode], llm = run_llm(
            cfg, params, rt, prompts, sp, max_steps=500, mb_size=1,
            num_microbatches=3, pool=pool, offload=True, prefill_mode=mode,
            prefill_chunk=4, max_prefill_tokens_per_tick=8)
        assert llm.engine.backend.swap_count > 0   # offloading engaged
    assert_equivalent(runs, base="exact")


# ------------------------------------------------- page exhaustion path ---

TINY_POOL = PoolConfig(page_size=4, n_local_pages=4, max_pages_per_seq=4)


def _exhaustion_engine(rt, cfg, params, backend, prefill_mode):
    return OfflineEngine(
        cfg, params, rt, mb_size=2, num_microbatches=1, pool=TINY_POOL,
        backend=backend, n_stages=1, prefill_mode=prefill_mode,
        sampling=SamplingParams(temperature=0.0, max_new_tokens=8))


@pytest.mark.parametrize("backend,prefill_mode", [
    ("local", "chunked"), ("local", "exact"), ("pipelined", "chunked")])
def test_memory_error_requeues_head_of_line(rt, backend, prefill_mode):
    """Page exhaustion at admission: the head-of-line request stays QUEUED
    (never half-admitted) and retries after the running request frees its
    pages — on both backends and both admission paths."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    eng = _exhaustion_engine(rt, cfg, params, backend, prefill_mode)
    sp = SamplingParams(temperature=0.0, max_new_tokens=8)
    # each request needs 3 pages (3 prompt + 8 new = 11 tokens); the pool
    # has 3 usable pages (page 0 is scratch) — only one fits at a time
    seqs = eng.submit([Request(i, [3 + i, 4, 5], sp) for i in range(2)])
    assert eng.step()
    assert seqs[0].status in (Status.PREFILLING, Status.DECODING)
    assert seqs[1].status is Status.QUEUED          # requeued, not dropped
    assert eng.queue and eng.queue[0] is seqs[1]    # head of line
    done = eng.run(max_steps=300)
    assert len(done) == 2
    assert [s.request.request_id for s in done] == [0, 1]
    for s in done:
        assert len(s.generated) == 8
        assert s.finish_reason() is FinishReason.LENGTH


@pytest.mark.parametrize("backend", ["local", "pipelined"])
def test_page_budget_finish_reason(rt, backend):
    """A request whose max_new_tokens exceeds the slot's page capacity is
    capped by the engine-side budget and finishes with page_budget."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    eng = OfflineEngine(
        cfg, params, rt, mb_size=1, num_microbatches=1,
        pool=PoolConfig(page_size=4, n_local_pages=16, max_pages_per_seq=4),
        backend=backend, n_stages=1)
    sp = SamplingParams(temperature=0.0, max_new_tokens=50)
    eng.submit([Request(0, [3, 4, 5], sp)])
    done = eng.run(max_steps=300)
    assert len(done) == 1
    assert done[0].finish_reason() is FinishReason.PAGE_BUDGET
    assert len(done[0].generated) == 13             # 16-token cap - 3 prompt


# ------------------------------------------------------------ satellites ---

def test_submit_never_mutates_caller_request(rt):
    """A Request submitted with sampling=None keeps sampling=None: the
    engine default is resolved onto the SequenceState's private copy, so a
    caller-shared Request object is never written back."""
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=1,
                        pool=POOL,
                        sampling=SamplingParams(temperature=0.0,
                                                max_new_tokens=3))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([Request(9, [], None)])
    shared = Request(0, [3, 4, 5], None)
    explicit = Request(1, [5, 6, 7], SamplingParams(temperature=0.0,
                                                    max_new_tokens=2))
    seqs = eng.submit([shared, explicit])
    assert shared.sampling is None                  # caller object untouched
    assert seqs[0].sampling.max_new_tokens == 3     # default resolved
    assert seqs[1].sampling is not explicit.sampling  # private copy
    eng.run(max_steps=100)
    assert shared.sampling is None
    assert len(seqs[0].generated) == 3 and len(seqs[1].generated) == 2
    # mutating the engine's copy never leaks back to the caller's params
    assert explicit.sampling.max_new_tokens == 2


def test_stats_split_prefill_decode(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    llm = LLM(cfg, params=params, rt=rt, config=EngineConfig(
        mb_size=2, num_microbatches=1, pool=POOL, prefill_chunk=4))
    llm.generate(_prompts(cfg, 3, seed=1),
                 SamplingParams(temperature=0.0, max_new_tokens=3))
    rep = llm.stats()
    assert rep["prefill_time_s"] > 0 and rep["decode_time_s"] > 0
    assert rep["prefill_tok_per_s"] > 0 and rep["decode_tok_per_s"] > 0
    # the phase clocks partition the wall clock
    assert rep["prefill_time_s"] + rep["decode_time_s"] <= \
        rep["wall_time_s"] + 1e-6


def test_recurrent_prefill_len_bucketed_pow2(rt):
    """The exact-length fallback pads recurrent archs to the next power of
    two (bounded jit cache) — and the padded prefill still matches the
    exact-length reference bit for bit (state masking)."""
    cfg = tiny("recurrentgemma-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=24, max_pages_per_seq=8)
    sp = SamplingParams(temperature=0.0, max_new_tokens=4)
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=1,
                        pool=pool, sampling=sp)
    assert eng._prefill_len(9) == 16
    assert eng._prefill_len(17) == 32
    assert eng._prefill_len(16) == 16
    prompt = list(np.random.RandomState(2).randint(1, cfg.vocab_size, 9))
    eng.submit([Request(0, prompt, sp)])
    done = eng.run(max_steps=100)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = M.prefill(params, {"tokens": toks}, cfg, rt, 64)
    ref = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(4):
        ref.append(int(tok[0]))
        logits, caches = M.decode_step(
            params, tok, caches, jnp.asarray([len(prompt) + i], jnp.int32),
            cfg, rt)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert done[0].generated == ref


def test_masked_pad_prefill_matches_exact_logits(rt):
    """Model-level: prefill with right-padding + last_index returns the
    exact-length call's last-position logits (pad positions are masked
    end-to-end, including through the recurrent state).  Tolerance is
    XLA's length-dependent reduction order, not the masking — the
    engine-level pow2 test above checks the decoded tokens bit for bit."""
    for arch in ("recurrentgemma-9b", "xlstm-1.3b"):
        cfg = tiny(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
        rng = np.random.RandomState(0)
        prompt = rng.randint(1, cfg.vocab_size, 11)
        exact = jnp.asarray(prompt, jnp.int32)[None]
        padded = jnp.zeros((1, 16), jnp.int32).at[0, :11].set(exact[0])
        le, _ = M.prefill(params, {"tokens": exact}, cfg, rt, 32)
        lp, _ = M.prefill(params, {"tokens": padded}, cfg, rt, 32,
                          last_index=jnp.asarray([10]))
        np.testing.assert_allclose(np.asarray(le), np.asarray(lp),
                                   rtol=1e-4, atol=1e-4)


# ----------------------------------------------- prefill through the pipe ---

INTERLEAVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
from repro.config import get_arch, reduced_config
from repro.models import model as M
from repro.models.common import Runtime
import jax, jax.numpy as jnp
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams, Status

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg = reduced_config(get_arch("yi-9b"))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                  max_pages_per_seq=8)
# two long-lived decoders in microbatches 0/1; microbatch 2's slot stays
# free for the long prompt, so its chunked prefill runs while both
# decoders keep ticking through the pipe
sp_short = SamplingParams(temperature=0.0, max_new_tokens=60)
sp_long = SamplingParams(temperature=0.0, max_new_tokens=4)
rng = np.random.RandomState(5)
short_prompts = [list(rng.randint(1, cfg.vocab_size, 4)) for _ in range(2)]
long_prompt = list(rng.randint(1, cfg.vocab_size, 20))

def run(prefill_mode):
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=3,
                        pool=pool, backend="pipelined", n_stages=2,
                        prefill_mode=prefill_mode, prefill_chunk=4)
    eng.submit([Request(i, p, sp_short) for i, p in enumerate(short_prompts)])
    for _ in range(8):                     # get decode pipelining going
        assert eng.step()
    long_seq = eng.submit([Request(2, long_prompt, sp_long)])[0]
    overlap_steps = 0          # steps where a chunk sits in the prefill
                               # pipe AND a decode tick is in flight
    decode_during = 0
    prefilling_steps = 0
    while long_seq.status in (Status.QUEUED, Status.PREFILLING):
        chunk_in_pipe = eng.backend.prefill_pending()
        busy = bool(eng.backend.busy_microbatches())
        d0 = eng.stats.decode_tokens
        assert eng.step()
        if long_seq.status is Status.PREFILLING:
            prefilling_steps += 1
            decode_during += eng.stats.decode_tokens - d0
            if chunk_in_pipe and busy:
                overlap_steps += 1
    done = {s.request.request_id: s.generated
            for s in eng.run(max_steps=800)}
    assert len(done) == 3, done
    return done, overlap_steps, decode_during, prefilling_steps

chunked, overlap, dec_during, pf_steps = run("chunked")
exact, _, _, pf_steps_exact = run("exact")
# 20-token prompt / 4-token chunks: PREFILLING spans real engine time, the
# chunks share engine ticks with in-flight decode microbatches, and decode
# keeps producing tokens on those very ticks
assert pf_steps >= 5, pf_steps
assert overlap >= 2, (overlap, pf_steps)
assert dec_during >= 3, "decode stalled while the chunk was in the pipe"
# exact-length prefill is atomic: PREFILLING never spans a step boundary
assert pf_steps_exact == 0, pf_steps_exact
# and the interleaving changed no output bits
assert chunked == exact, (chunked, exact)
print("INTERLEAVE-OK", overlap, dec_during)
"""


@pytest.mark.slow
def test_pipelined_chunk_prefill_does_not_block_decode():
    """Acceptance: a PipelinedBackend prefill chunk flows through its own
    persistent pipe stage-to-stage — decode microbatches stay in flight
    (busy_microbatches non-empty) and keep producing tokens on the same
    engine ticks, and the interleaving is bit-transparent to outputs."""
    r = subprocess.run([sys.executable, "-c", INTERLEAVE_SCRIPT],
                       env=subprocess_env(), capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "INTERLEAVE-OK" in r.stdout
