"""Flight recorder (repro.obs): ring/event mechanics, bitwise transport
ledger reconciliation, Chrome-trace export and its schema check, the
metrics registry, and per-request traces equal — float for float — to
the engine's and the online stream's own latency numbers."""

import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.distributed.transport import SimulatedLinkTransport
from repro.models import model as M
from repro.obs import (Metrics, TraceRecorder, chrome_trace_events,
                       validate_chrome_trace, write_chrome_trace)
from repro.obs.metrics import update_from_engine
from repro.serving.engine import OfflineEngine, _resolve_trace
from repro.serving.kv_cache import PoolConfig
from repro.serving.llm import LLM, EngineConfig
from repro.serving.online import OnlineLLM
from repro.serving.request import SamplingParams


# ------------------------------------------------------ recorder core ---


def test_ring_bounds_and_dropped_counter():
    rec = TraceRecorder(capacity=8)
    for i in range(20):
        rec.instant("e", "t", float(i))
    assert len(rec.events) == 8
    assert rec.dropped == 12
    assert rec.summary()["dropped"] == 12
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)


def test_request_table_bounded_evicts_finished_first():
    rec = TraceRecorder(max_requests=2)
    rec.request_submit(1, 0.0, 4)
    rec.request_finish(1, 1.0, "eos")
    rec.request_submit(2, 0.5, 4)
    rec.request_submit(3, 0.6, 4)           # full → evicts finished #1
    assert 1 not in rec.requests and {2, 3} <= set(rec.requests)
    rec.request_submit(4, 0.7, 4)           # full of LIVE requests: drop
    assert 4 not in rec.requests
    assert rec.request_trace(4) is None


def test_recorder_event_shapes_export_and_ledger():
    rec = TraceRecorder()
    rec.step_phase("decode", 1.0, 2.0, step=3)
    rec.pipe_tick("decode", 0.0, 1.0, (0, -1))
    rec.link_send("decode", 0, 1024, 0.0, 0.5)
    rec.link_send("decode", 1, 64, 0.5, 0.6, return_trip=True)
    rec.tick_stall("decode", 0.25, 1.0)
    rec.stage_busy("decode", 1, 0.0, 0.5)
    rec.offload_swap_out(2, 1.0, True)
    rec.offload_swap_in(2, 1.0, 1.5)
    rec.prefix_event("hit", 7, 32, 1.0)
    rec.slo_budget(0.5, 16, 1.0)
    rec.fault("drop", 1.0, (("plane", "decode"), ("mb", 1)))
    rec.reshard_span("drain", 0.0, 1.0, (("old_stages", 2),))
    assert rec.link_ledger() == \
        {"wire_bytes": 1088, "sends": 2, "stall_s": 0.25}
    trace = chrome_trace_events(rec)
    assert validate_chrome_trace(trace) == []
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"decode", "tick", "send", "return", "stall", "busy",
            "swap_out", "swap_in", "prefix_hit", "slo_budget",
            "fault_drop", "reshard_drain"} <= names


def test_engine_config_trace_resolution():
    assert _resolve_trace(None) is None
    assert _resolve_trace(False) is None
    r = _resolve_trace(True)
    assert isinstance(r, TraceRecorder) and r.capacity == 65536
    assert _resolve_trace(128).capacity == 128     # int = ring capacity
    rec = TraceRecorder(capacity=4)
    assert _resolve_trace(rec) is rec              # instance passthrough
    with pytest.raises(ValueError):
        _resolve_trace("yes")


# ------------------------------- transport ledger (bitwise contract) ---


def _drive_transport(rec, n_ticks=40, seed=7):
    """2-stage simulated WAN with bandwidth + jitter, mixed planes and
    occupancy — every book-keeping branch of tick() gets exercised."""
    tr = SimulatedLinkTransport.uniform(2, 0.004, bandwidth_bps=2e6,
                                        jitter_s=0.0005)
    tr.set_recorder(rec)
    rng = np.random.RandomState(seed)
    for i in range(n_ticks):
        occ = [bool(rng.randint(0, 2)), bool(rng.randint(0, 2))]
        if not any(occ):
            occ[int(rng.randint(0, 2))] = True
        tr.tick(occ, int(rng.randint(256, 4096)), [0.002, 0.003],
                inject_t=float(tr.clock.now),
                plane="decode" if i % 3 else "prefill")
    return tr


def test_link_ledger_reconciles_bitwise_with_transport_books():
    rec = TraceRecorder()
    tr = _drive_transport(rec)
    assert rec.dropped == 0
    led = rec.link_ledger()
    assert led["wire_bytes"] == tr.wire_bytes     # exact int sum
    assert led["sends"] == tr.sends
    assert led["stall_s"] == tr.stall_s           # bitwise float equality


def test_exported_timeline_reconciles_through_json(tmp_path):
    rec = TraceRecorder()
    tr = _drive_transport(rec)
    out = tmp_path / "timeline.json"
    write_chrome_trace(rec, str(out))
    trace = json.loads(out.read_text())
    assert validate_chrome_trace(trace) == []
    sends = [e for e in trace["traceEvents"] if e.get("ph") == "b"]
    assert sum(e["args"]["nbytes"] for e in sends) == tr.wire_bytes
    assert len(sends) == tr.sends
    stall = 0.0                 # same floats, same left-to-right order
    for e in trace["traceEvents"]:
        if e.get("ph") == "C" and e["name"] == "stall":
            stall += e["args"]["stall_s"]
    assert stall == tr.stall_s


def test_span_timestamps_monotone_per_track():
    rec = TraceRecorder()
    _drive_transport(rec)
    last = {}
    spans = 0
    for e in rec.events:
        if e.kind != "span":
            continue
        spans += 1
        key = (e.clock, e.track)
        assert e.t0 >= last.get(key, float("-inf")), key
        assert e.dur >= 0.0
        last[key] = e.t0
    assert spans > 0


# -------------------------------------------------- timeline schema ---


def test_validator_catches_malformed_traces():
    assert validate_chrome_trace({"traceEvents": 5}) != []
    assert validate_chrome_trace(3) != []
    errs = validate_chrome_trace([{"ph": "X", "ts": -1.0}])
    assert any("name" in e for e in errs)
    assert any("ts=" in e for e in errs)
    evs = [{"name": "a", "ph": "X", "ts": 10.0, "dur": 1.0,
            "pid": 1, "tid": 1},
           {"name": "b", "ph": "X", "ts": 5.0, "dur": 1.0,
            "pid": 1, "tid": 1}]
    assert any("monotone" in e for e in validate_chrome_trace(evs))
    evs = [{"name": "s", "ph": "b", "ts": 0.0, "cat": "l", "id": 1}]
    assert any("never ended" in e for e in validate_chrome_trace(evs))
    evs = [{"name": "s", "ph": "b", "ts": 5.0, "cat": "l", "id": 1},
           {"name": "s", "ph": "e", "ts": 1.0, "cat": "l", "id": 1}]
    assert any("before its begin" in e
               for e in validate_chrome_trace(evs))


def test_timeline_cli(tmp_path):
    from repro.obs.timeline import main as tl_main
    rec = TraceRecorder()
    rec.span("a", "t", 0.0, 1.0)
    ok = tmp_path / "ok.json"
    write_chrome_trace(rec, str(ok))
    assert tl_main(["--check", str(ok)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text('{"traceEvents": [{"ph": "X", "ts": -3}]}')
    assert tl_main(["--check", str(bad)]) == 1
    assert tl_main(["--check", str(tmp_path / "missing.json")]) == 2


# ------------------------------------------------------------ metrics ---


def test_metrics_registry_basics():
    m = Metrics()
    c = m.counter("c_total", help="h")
    c.inc()
    c.inc(2)
    assert m.counter("c_total") is c            # idempotent identity
    with pytest.raises(ValueError):
        c.inc(-1)                               # counters never decrease
    with pytest.raises(ValueError):
        c.set_to(1.0)
    with pytest.raises(ValueError):
        m.gauge("c_total")                      # type mismatch
    m.gauge("g", labels={"k": "v"}).set(2.5)
    h = m.histogram("lat_s", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = m.snapshot()
    assert snap["c_total"] == 3.0
    assert snap['g{k="v"}'] == 2.5
    assert snap["lat_s_count"] == 3.0
    assert Metrics.delta({"c_total": 1.0}, snap)["c_total"] == 2.0
    text = m.prometheus_text()
    assert "# TYPE c_total counter" in text
    assert 'g{k="v"} 2.5' in text
    assert "lat_s_bucket" in text and "+Inf" in text
    line = json.loads(m.jsonl_line())
    assert line["c_total"] == 3.0 and "_ts" in line


# ------------------------------------------------- engine integration ---


def _traced_llm(rt, trace=True, **cfg_kw):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=16, max_pages_per_seq=4)
    econfig = EngineConfig(mb_size=2, num_microbatches=1, pool=pool,
                           trace=trace, **cfg_kw)
    return LLM(cfg, config=econfig, params=params, rt=rt), cfg


def test_trace_off_is_zero_cost(rt):
    llm, cfg = _traced_llm(rt, trace=None)
    assert llm.engine.recorder is None
    outs = llm.generate([[3, 4, 5]],
                        SamplingParams(temperature=0.0, max_new_tokens=3))
    assert outs[0].trace is None


def test_offline_request_traces_match_engine_stamps(rt):
    llm, cfg = _traced_llm(rt)
    eng = llm.engine
    assert eng.recorder is not None
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab_size, 6)) for _ in range(3)]
    sp = SamplingParams(temperature=0.0, max_new_tokens=4)
    outs = llm.generate(prompts, sp)
    assert all(o.finished for o in outs)
    for out in outs:
        tr = out.trace
        assert tr is not None
        assert tr["ttft_s"] == out.ttft_s       # same floats subtracted
        assert len(tr["token_times"]) == len(out.token_ids)
        assert tr["queue_wait_s"] is not None and tr["queue_wait_s"] >= 0
        assert tr["finish_reason"] == out.finish_reason
        assert tr["pages"] >= 1
        assert all(d >= 0 for d in tr["inter_token_s"])
        if eng.chunked_prefill:
            assert tr["chunks"] >= 1
    phases = [e for e in eng.recorder.events
              if e.track == "engine" and e.kind == "span"]
    assert {e.name for e in phases} >= {"reap", "prefill", "decode"}
    wall = chrome_trace_events(eng.recorder)
    assert validate_chrome_trace(wall) == []


def test_online_stream_trace_matches_stream_bitwise(rt):
    """Satellite contract: per-request TTFT / inter-token latencies in
    the trace are the SAME floats RequestStream reports — not close, the
    same subtractions of the same stamps."""
    llm, cfg = _traced_llm(rt)
    online = OnlineLLM(llm=llm)
    rng = np.random.RandomState(1)
    sp = SamplingParams(temperature=0.0, max_new_tokens=5)
    s1 = online.submit(list(rng.randint(1, cfg.vocab_size, 5)), sp)
    s2 = online.submit(list(rng.randint(1, cfg.vocab_size, 7)), sp)
    for s in (s2, s1):                  # drain out of submit order too
        out = s.result()
        tr = out.trace
        assert tr is not None and out.finished
        assert tr["stream_submit_time"] == s.submit_time
        assert tr["delivery_times"] == s._event_times
        assert tr["ttft_s"] == s.ttft_s                  # bitwise
        assert tr["inter_token_s"] == s.inter_token_s()  # bitwise


def test_metrics_snapshot_never_stale(rt):
    """Regression for the status_counts staleness bug: the stats field
    is a mirror that status_counts()/throughput_report() always rewrite,
    so a metrics scrape can never observe a stale copy."""
    llm, cfg = _traced_llm(rt, trace=None)
    eng = llm.engine
    rng = np.random.RandomState(2)
    prompts = [list(rng.randint(1, cfg.vocab_size, 5)) for _ in range(3)]
    sp = SamplingParams(temperature=0.0, max_new_tokens=3)
    llm._submit(prompts, sp)
    m = Metrics()
    snap = update_from_engine(m, eng)
    assert snap['repro_requests{status="queued"}'] == 3.0
    assert eng.stats.status_counts["queued"] == 3       # mirror written
    eng.run(max_steps=200)
    # the mirror was last written pre-run; the report must refresh it
    snap2 = update_from_engine(m, eng)
    assert snap2['repro_requests{status="finished"}'] == 3.0
    assert snap2['repro_requests{status="queued"}'] == 0.0
    assert eng.stats.status_counts["finished"] == 3
    assert snap2["repro_requests_finished_total"] == 3.0
    assert snap2["repro_engine_steps_total"] > 0


def test_stage_report_shape(rt):
    """Satellite contract: StragglerMitigator observations and per-stage
    drain times surface in throughput_report()["stages"]."""
    from repro.distributed.elastic import StragglerMitigator
    llm, _ = _traced_llm(rt, trace=None)
    eng = llm.engine
    assert "stages" not in eng.throughput_report()      # local: no stages
    eng.straggler = StragglerMitigator(2)
    eng._stage_time_total = [0.0, 0.0]
    eng._stage_time_count = [0, 0]
    eng.straggler.observe(0, 0.01)
    eng.straggler.observe(1, 0.05)
    eng._stage_time_total[1] += 0.05
    eng._stage_time_count[1] += 1
    st = eng.throughput_report()["stages"]
    assert set(st) == {"ewma_s", "total_s", "counts",
                       "microbatch_weights", "stragglers"}
    assert len(st["ewma_s"]) == 2 == len(st["microbatch_weights"])
    assert st["counts"] == [0, 1]
    assert st["total_s"][1] == pytest.approx(0.05)
    assert st["ewma_s"] == [0.01, 0.05]         # first observation seeds
    assert isinstance(st["stragglers"], list)
    # ... and the metrics mapping exposes one labelled gauge per stage
    m = Metrics()
    snap = update_from_engine(m, eng)
    assert snap['repro_stage_time_ewma_s{stage="1"}'] == 0.05
    assert 'repro_stage_straggler{stage="0"}' in snap


def test_local_backend_no_retrace_with_tracing_on(rt):
    """Tracing must not add a retrace: with the recorder live, every
    serve jit still holds exactly one compiled trace after mixed
    prefill+decode with slot churn."""
    from repro.analysis.invariants import jit_cache_size
    llm, cfg = _traced_llm(rt)
    rng = np.random.RandomState(11)
    sp = SamplingParams(temperature=0.0, max_new_tokens=4)
    prompts = [list(rng.randint(1, cfg.vocab_size, rng.randint(3, 10)))
               for _ in range(5)]               # 5 > 2 slots → churn
    outs = llm.generate(prompts, sp)
    assert all(o.finished for o in outs)
    sizes = {k: jit_cache_size(f)
             for k, f in llm.engine.backend.jit_entries().items()}
    bad = {k: v for k, v in sizes.items() if v is not None and v > 1}
    assert not bad, f"tracing caused a retrace: {bad} (all: {sizes})"
    assert any(v == 1 for v in sizes.values()), sizes


PIPE_TRACE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
import numpy as np
from repro.analysis.invariants import jit_cache_size
from repro.config import get_arch, reduced_config
from repro.distributed.transport import SimulatedLinkTransport
from repro.models import model as M
from repro.models.common import Runtime
from repro.obs import chrome_trace_events, validate_chrome_trace
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg0 = get_arch("yi-9b")
period = len(cfg0.block_pattern)
cfg = reduced_config(cfg0, num_layers=2 * period + (2 if period > 1 else 1))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=4, n_local_pages=32, max_pages_per_seq=6)
sp = SamplingParams(temperature=0.0, max_new_tokens=5)
transport = SimulatedLinkTransport.uniform(2, 0.008)
eng = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=2,
                    pool=pool, sampling=sp, backend="pipelined",
                    n_stages=2, transport=transport, trace=True,
                    strict=True)
rng = np.random.RandomState(11)
reqs = [Request(i, list(rng.randint(1, cfg.vocab_size,
                                    rng.randint(3, 10))), sp)
        for i in range(6)]
eng.submit(reqs)
done = eng.run(max_steps=600)
assert len(done) == 6, len(done)
rec = eng.recorder
assert rec is not None and rec.dropped == 0
# acceptance: the recorded ledger reconciles BITWISE with the books
led = rec.link_ledger()
assert led["wire_bytes"] == transport.wire_bytes, led
assert led["sends"] == transport.sends, led
assert led["stall_s"] == transport.stall_s, led
# ... including through the exported Chrome-trace JSON
trace = chrome_trace_events(rec)
assert validate_chrome_trace(trace) == []
sends = [e for e in trace["traceEvents"] if e.get("ph") == "b"]
assert sum(e["args"]["nbytes"] for e in sends) == transport.wire_bytes
# tracing must not add a retrace on the pipelined backend either
sizes = {k: jit_cache_size(f)
         for k, f in eng.backend.jit_entries().items()}
bad = {k: v for k, v in sizes.items() if v is not None and v > 1}
assert not bad, sizes
assert any(v == 1 for v in sizes.values()), sizes
print("OK", led)
"""


@pytest.mark.slow
def test_pipelined_tracing_reconciles_bitwise_and_no_retrace():
    """2-stage SimulatedLinkTransport run (fresh interpreter, 2 fake CPU
    devices) with the flight recorder on: the exported timeline's
    per-link transfer slices reconcile bitwise with the transport's
    wire-byte books, and every tick jit still compiles exactly once."""
    from equivalence import subprocess_env
    r = subprocess.run([sys.executable, "-c", PIPE_TRACE_SCRIPT],
                       env=subprocess_env(), capture_output=True,
                       text=True, timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "OK" in r.stdout
