"""repro-audit: static lint passes (against fixture files), suppression
mechanics, the clean-tree gate, the runtime invariant auditor, and the
no-retrace-after-warmup regression on both backends."""

import os
import re
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.analysis.invariants import InvariantViolation, jit_cache_size
from repro.analysis.lint import AuditConfig, run_lint
from repro.models import model as M
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams, Status

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "fixtures" / "analysis"


def _marks(path: Path):
    """{rule: [lineno, ...]} from ``# LINT-EXPECT: <rule>`` markers."""
    out = {}
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        m = re.search(r"LINT-EXPECT:\s*([\w-]+)", line)
        if m:
            out.setdefault(m.group(1), []).append(i)
    return out


# ------------------------------------------------------------- lint ---


def test_fixture_host_sync_fires_once():
    path = FIXTURES / "fixture_host_sync.py"
    cfg = AuditConfig(hot_roots=["fixture_host_sync:tick_loop"],
                      traced_fns=[])
    vs = run_lint([path], config=cfg)
    assert [(v.rule, v.line) for v in vs] == \
        [("host-sync", _marks(path)["host-sync"][0])]
    assert vs[0].path == str(path)
    assert "device_get" in vs[0].msg


def test_fixture_prng_rules_fire_once_each():
    path = FIXTURES / "fixture_prng.py"
    marks = _marks(path)
    vs = run_lint([path], config=AuditConfig(hot_roots=[], traced_fns=[]))
    got = sorted((v.rule, v.line) for v in vs)
    assert got == sorted([
        ("prng-fold-drop", marks["prng-fold-drop"][0]),
        ("prng-reuse", marks["prng-reuse"][0]),
    ])
    drop = next(v for v in vs if v.rule == "prng-fold-drop")
    assert "token_idx" in drop.msg   # says WHAT the short chain dropped


def test_fixture_retrace_rules_fire_once_each():
    path = FIXTURES / "fixture_retrace.py"
    marks = _marks(path)
    cfg = AuditConfig(hot_roots=["fixture_retrace:hot_step"],
                      traced_fns=["fixture_retrace:tick_fn"])
    vs = run_lint([path], config=cfg)
    got = sorted((v.rule, v.line) for v in vs)
    assert got == sorted([
        ("retrace-jit", marks["retrace-jit"][0]),
        ("retrace-nonhashable", marks["retrace-nonhashable"][0]),
        ("retrace-branch", marks["retrace-branch"][0]),
    ])


def test_fixture_offload_sync_fires_once():
    path = FIXTURES / "fixture_offload_sync.py"
    cfg = AuditConfig(
        hot_roots=[], traced_fns=[],
        offload_windows=["fixture_offload_sync:Offloader.ensure_resident"])
    vs = run_lint([path], config=cfg)
    assert [(v.rule, v.line) for v in vs] == \
        [("offload-sync", _marks(path)["offload-sync"][0])]
    # the message tells the reader WHAT to do, not just what fired
    assert "enqueued" in vs[0].msg


def test_fixture_obs_hot_path_fires_twice():
    path = FIXTURES / "fixture_obs.py"
    marks = _marks(path)
    cfg = AuditConfig(hot_roots=["fixture_obs:hot_step"],
                      traced_fns=["fixture_obs:tick_fn"])
    vs = run_lint([path], config=cfg)
    got = sorted((v.rule, v.line) for v in vs)
    assert got == sorted(
        ("obs-hot-path", ln) for ln in marks["obs-hot-path"])
    in_jit = next(v for v in vs if "tick-jit" in v.msg)
    assert "host-side" in in_jit.msg     # says WHY the recorder can't run
    dev = next(v for v in vs if "materialises" in v.msg)
    assert "host scalars" in dev.msg     # ... and what to record instead


def test_suppression_with_reason_silences(tmp_path):
    f = tmp_path / "mod_sync.py"
    f.write_text(
        "import jax\n\n\n"
        "def tick_loop(x):\n"
        "    # repro-audit: allow(host-sync) — return link needs host "
        "tokens\n"
        "    return jax.device_get(x)\n")
    cfg = AuditConfig(hot_roots=["mod_sync:tick_loop"], traced_fns=[])
    assert run_lint([f], config=cfg) == []
    # a reasoned, used suppression also survives the strict gate
    assert run_lint([f], config=cfg, strict_suppressions=True) == []


def test_strict_suppressions_flag_unreasoned_stale_and_unknown(tmp_path):
    f = tmp_path / "mod_stale.py"
    f.write_text(
        "def helper():\n"
        "    # repro-audit: allow(host-sync)\n"        # no reason
        "    return 1\n\n\n"
        "def other():\n"
        "    # repro-audit: allow(no-such-rule) — covering nothing\n"
        "    return 2\n\n\n"
        "def third():\n"
        "    # repro-audit: allow(prng-reuse) — stale after a fix\n"
        "    return 3\n")
    cfg = AuditConfig(hot_roots=[], traced_fns=[])
    # default mode tolerates them all
    assert run_lint([f], config=cfg) == []
    vs = run_lint([f], config=cfg, strict_suppressions=True)
    assert sorted(v.rule for v in vs) == \
        ["bad-suppression", "bad-suppression", "unused-suppression"]


def test_docstring_mention_is_not_a_suppression(tmp_path):
    # the allow() syntax quoted in a docstring must not silence anything
    f = tmp_path / "mod_doc.py"
    f.write_text(
        'import jax\n\n\n'
        'def tick_loop(x):\n'
        '    """# repro-audit: allow(host-sync) — quoted, not real"""\n'
        '    return jax.device_get(x)\n')
    cfg = AuditConfig(hot_roots=["mod_doc:tick_loop"], traced_fns=[])
    vs = run_lint([f], config=cfg)
    assert [v.rule for v in vs] == ["host-sync"]


def test_clean_tree_lint_exits_zero():
    """The committed src/ tree passes its own audit, strict suppressions
    included — this is the same command the CI audit job runs."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src",
         "--strict-suppressions"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "repro-audit: clean" in r.stdout


# --------------------------------------------------- runtime auditor ---


def _small_engine(rt, strict=True, mb=1, n_mb=1, max_new=4):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=16, max_pages_per_seq=4)
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new)
    eng = OfflineEngine(cfg, params, rt, mb_size=mb, num_microbatches=n_mb,
                        pool=pool, sampling=sp, strict=strict)
    return eng, cfg, sp


def test_strict_default_follows_env(monkeypatch, rt):
    monkeypatch.setenv("REPRO_STRICT", "0")
    eng, _, _ = _small_engine(rt, strict=None)
    assert eng.auditor is None
    monkeypatch.setenv("REPRO_STRICT", "1")
    eng, _, _ = _small_engine(rt, strict=None)
    assert eng.auditor is not None
    # explicit flag beats the environment
    eng, _, _ = _small_engine(rt, strict=False)
    assert eng.auditor is None


def test_auditor_catches_page_leak(rt):
    eng, cfg, sp = _small_engine(rt)
    eng.submit([Request(0, [3, 4, 5], sp)])
    eng.step()
    eng.auditor.after_step()          # consistent so far
    # leak one free page out of the allocator's books
    page = next(iter(eng.alloc._free_local))
    eng.alloc._free_local.remove(page)
    with pytest.raises(InvariantViolation, match="page"):
        eng.auditor.after_step()


def test_auditor_catches_fsm_backstep(rt):
    eng, cfg, sp = _small_engine(rt)
    eng.submit([Request(0, [3, 4, 5], sp)])
    done = eng.run(max_steps=100)
    assert len(done) == 1
    eng.finished[0].status = Status.DECODING   # illegal rewind
    with pytest.raises(InvariantViolation, match="fsm"):
        eng.auditor.after_step()


def test_auditor_catches_offload_breaches(rt):
    from repro.core.offload import DoubleBufferOffloader
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=16, n_global_pages=4,
                      max_pages_per_seq=4)
    sp = SamplingParams(temperature=0.0, max_new_tokens=4)
    off = DoubleBufferOffloader(pool, 2)
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=2,
                        pool=pool, sampling=sp, offloader=off, strict=True)
    eng.auditor.after_step()                   # consistent so far
    # (a) parity breach: pool 0 must only ever host even microbatches
    off.resident[0] = 1
    with pytest.raises(InvariantViolation, match="parity"):
        eng.auditor.after_step()
    off.resident[0] = None
    # (b) stale host copy kept for a resident microbatch
    off.resident[1] = 1
    off._host[1] = []
    with pytest.raises(InvariantViolation, match="host-store"):
        eng.auditor.after_step()
    del off._host[1]
    # (c) counters must be monotone for the offloader's lifetime
    off.swap_count = 5
    eng.auditor.after_step()
    off.swap_count = 2
    with pytest.raises(InvariantViolation, match="backward"):
        eng.auditor.after_step()


def test_jit_cache_size_probe():
    f = jax.jit(lambda x: x + 1)
    assert jit_cache_size(f) == 0
    f(jax.numpy.ones((3,)))
    assert jit_cache_size(f) == 1
    f(jax.numpy.ones((4,)))           # new shape → second trace
    assert jit_cache_size(f) == 2
    assert jit_cache_size(lambda x: x) is None   # not a jit: cannot check


# ------------------------------------------- retrace regression gate ---


def test_local_backend_no_retrace_after_warmup(rt):
    """Mixed prefill+decode with slot churn: after the run, every serve
    jit the backend exposes holds exactly one compiled trace."""
    eng, cfg, sp = _small_engine(rt, mb=2, n_mb=2, max_new=5)
    rng = np.random.RandomState(11)
    reqs = [Request(i, list(rng.randint(1, cfg.vocab_size,
                                        rng.randint(3, 12))), sp)
            for i in range(7)]        # 7 > 4 slots → replenishment
    eng.submit(reqs)
    done = eng.run(max_steps=400)
    assert len(done) == 7
    sizes = {name: jit_cache_size(fn)
             for name, fn in eng.backend.jit_entries().items()}
    assert sizes, "backend exposes no jit entries"
    bad = {k: v for k, v in sizes.items() if v is not None and v > 1}
    assert not bad, f"retraced mid-serve: {bad} (all: {sizes})"
    assert any(v == 1 for v in sizes.values()), \
        f"nothing compiled — probe is dead: {sizes}"


PIPE_RETRACE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
import numpy as np
from repro.analysis.invariants import jit_cache_size
from repro.config import get_arch, reduced_config
from repro.core.offload import DoubleBufferOffloader
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg0 = get_arch("yi-9b")
period = len(cfg0.block_pattern)
cfg = reduced_config(cfg0, num_layers=2 * period + (2 if period > 1 else 1))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
pool = PoolConfig(page_size=4, n_local_pages=32, n_global_pages=12,
                  max_pages_per_seq=6)
sp = SamplingParams(temperature=0.0, max_new_tokens=5)
eng = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=3,
                    pool=pool, sampling=sp, backend="pipelined",
                    n_stages=2, offloader=DoubleBufferOffloader(pool, 3),
                    strict=True)
rng = np.random.RandomState(11)
reqs = [Request(i, list(rng.randint(1, cfg.vocab_size,
                                    rng.randint(3, 10))), sp)
        for i in range(8)]            # 8 > 6 slots: prefill amid decode
eng.submit(reqs)
done = eng.run(max_steps=600)
assert len(done) == 8, len(done)
sizes = {k: jit_cache_size(f) for k, f in eng.backend.jit_entries().items()}
bad = {k: v for k, v in sizes.items() if v is not None and v > 1}
assert not bad, f"retraced mid-serve: {sizes}"
assert any(v == 1 for v in sizes.values()), sizes
print("OK", sizes)
"""


@pytest.mark.slow
def test_pipelined_backend_no_retrace_after_warmup():
    """Same gate on the 2-stage pipelined backend (fresh interpreter with
    2 fake CPU devices): mixed prefill+decode with offloading, then every
    tick jit — `_tick_jit`, `_pf_tick_jit`, the per-length prefill jits —
    must hold exactly one compiled trace."""
    from equivalence import subprocess_env
    r = subprocess.run([sys.executable, "-c", PIPE_RETRACE_SCRIPT],
                       env=subprocess_env(), capture_output=True,
                       text=True, timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "OK" in r.stdout
