"""Recurrent blocks: RG-LRU, mLSTM, sLSTM — parallel/chunked forms vs exact
sequential recurrences, decode-step consistency, state handover."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import rglru as R
from repro.models import xlstm as X


# ---------------------------------------------------------------- RG-LRU ---

def test_rglru_scan_matches_sequential():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, d = 2, 33, 8
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, d)))
    bb = jax.random.normal(ks[1], (b, s, d))
    h0 = jax.random.normal(ks[2], (b, d))
    hs = R.rglru_scan(a, bb, h0)
    h = h0
    for t in range(s):
        h = R.rglru_step(a[:, t], bb[:, t], h)
        np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(h),
                                   rtol=1e-5, atol=1e-5)


def test_rglru_block_prefill_then_decode_equals_full():
    cfg_heads, d, dr = 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 12)
    w = {
        "wg": jax.random.normal(ks[0], (d, dr)) * 0.3,
        "wx": jax.random.normal(ks[1], (d, dr)) * 0.3,
        "conv_w": jax.random.normal(ks[2], (4, dr)) * 0.3,
        "conv_b": jnp.zeros((dr,)),
        "gate_a_w": jax.random.normal(ks[3], (cfg_heads, dr // 2, dr // 2)) * 0.3,
        "gate_a_b": jnp.zeros((dr,)),
        "gate_x_w": jax.random.normal(ks[4], (cfg_heads, dr // 2, dr // 2)) * 0.3,
        "gate_x_b": jnp.zeros((dr,)),
        "lam": jnp.ones((dr,)),
        "wo": jax.random.normal(ks[5], (dr, d)) * 0.3,
    }
    b, s1, s2 = 1, 7, 3
    x = jax.random.normal(ks[6], (b, s1 + s2, d))
    y_full, _ = R.rglru_block(x, w, cfg_heads, mode="train", state=None)

    state = {"h": jnp.zeros((b, dr), jnp.float32),
             "conv": jnp.zeros((b, 3, dr))}
    y1, state = R.rglru_block(x[:, :s1], w, cfg_heads, mode="prefill",
                              state=state)
    ys = [y1]
    for t in range(s2):
        yt, state = R.rglru_block(x[:, s1 + t: s1 + t + 1], w, cfg_heads,
                                  mode="decode", state=state)
        ys.append(yt)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_cat),
                               rtol=1e-4, atol=1e-4)


def test_causal_conv_state_handover():
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    b, s, d, cw = 2, 10, 4, 4
    x = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (cw, d))
    bias = jnp.zeros((d,))
    y_full, _ = R.causal_conv1d(x, w, bias)
    y1, st = R.causal_conv1d(x[:, :6], w, bias)
    y2, _ = R.causal_conv1d(x[:, 6:], w, bias, state=st)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- mLSTM ---

@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**16))
def test_mlstm_chunkwise_equals_sequential(s, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, h, dh = 2, 2, 8
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, dh))
    i = jax.random.normal(ks[3], (b, s, h))
    f = jax.random.normal(ks[4], (b, s, h)) + 2.0
    h_seq, _ = X.mlstm_sequential(q, k, v, i, f)
    h_chk, _ = X.mlstm_chunkwise(q, k, v, i, f, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_seq), np.asarray(h_chk),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_state_continuation():
    """chunkwise(state) must continue exactly where sequential left off."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    b, s, h, dh = 1, 24, 2, 4
    q, k, v = (jax.random.normal(ks[j], (b, s, h, dh)) for j in range(3))
    i = jax.random.normal(ks[3], (b, s, h))
    f = jax.random.normal(ks[4], (b, s, h)) + 2.0
    h_full, st_full = X.mlstm_sequential(q, k, v, i, f)
    h1, st1 = X.mlstm_chunkwise(q[:, :10], k[:, :10], v[:, :10],
                                i[:, :10], f[:, :10], chunk=4)
    h2, st2 = X.mlstm_chunkwise(q[:, 10:], k[:, 10:], v[:, 10:],
                                i[:, 10:], f[:, 10:], st1, chunk=4)
    np.testing.assert_allclose(np.asarray(h_full),
                               np.asarray(jnp.concatenate([h1, h2], 1)),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_decode_step_matches_sequential_tail():
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    b, s, h, dh = 1, 9, 2, 4
    q, k, v = (jax.random.normal(ks[j], (b, s, h, dh)) for j in range(3))
    i = jax.random.normal(ks[3], (b, s, h))
    f = jax.random.normal(ks[4], (b, s, h)) + 2.0
    h_all, _ = X.mlstm_sequential(q, k, v, i, f)
    _, st = X.mlstm_sequential(q[:, :-1], k[:, :-1], v[:, :-1],
                               i[:, :-1], f[:, :-1])
    h_last, _ = X.mlstm_step(q[:, -1], k[:, -1], v[:, -1], i[:, -1],
                             f[:, -1], st)
    np.testing.assert_allclose(np.asarray(h_all[:, -1]), np.asarray(h_last),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- sLSTM ---

def test_slstm_block_decode_consistency():
    d, dr, hn = 6, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    w = {
        "w_in": jax.random.normal(ks[0], (4, d, dr)) * 0.4,
        "b_in": jnp.zeros((4, dr)),
        "r": jax.random.normal(ks[1], (4, hn, dr // hn, dr // hn)) * 0.4,
        "wo": jax.random.normal(ks[2], (dr, d)) * 0.4,
    }
    b, s = 2, 11
    x = jax.random.normal(ks[3], (b, s, d))
    y_full, _ = X.slstm_block(x, w, hn, mode="train", state=None)
    st = X.slstm_zero_state(b, dr)
    ys = []
    for t in range(s):
        yt, st = X.slstm_block(x[:, t:t + 1], w, hn, mode="decode", state=st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-4)


def test_slstm_forget_gate_stability():
    """Long constant input must not overflow the stabilised gates."""
    d, dr, hn = 4, 4, 1
    w = {
        "w_in": jnp.ones((4, d, dr)) * 0.1,
        "b_in": jnp.zeros((4, dr)).at[1].set(5.0),
        "r": jnp.ones((4, hn, dr, dr)) * 0.1,
        "wo": jnp.ones((dr, d)) * 0.1,
    }
    x = jnp.ones((1, 500, d))
    y, st = X.slstm_block(x, w, hn, mode="prefill",
                          state=X.slstm_zero_state(1, dr))
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(st["m"])))
