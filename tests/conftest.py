"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit/smoke tests must see the
real single CPU device; only the SPMD subprocess tests use 8/512 fake
devices (they spawn fresh interpreters)."""

import os
import sys

# strict mode on by default under test: the runtime invariant auditor
# (repro.analysis.invariants) audits page accounting, the Status FSM,
# transport books, and jit cache sizes after every engine step.  Set
# before any repro import so subprocess tests inherit it too; export
# REPRO_STRICT=0 to profile without the audit overhead.
os.environ.setdefault("REPRO_STRICT", "1")

try:                                    # gate, don't require: the container
    import hypothesis  # noqa: F401     # may not ship hypothesis
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_shims"))

import jax
import jax.numpy as jnp
import pytest

from repro.config import get_arch, list_archs, reduced_config
from repro.models.common import Runtime

ASSIGNED_ARCHS = [
    "musicgen-large", "recurrentgemma-9b", "yi-9b", "gemma3-1b",
    "minitron-4b", "gemma3-12b", "qwen2-vl-2b", "qwen3-moe-235b-a22b",
    "phi3.5-moe-42b-a6.6b", "xlstm-1.3b",
]


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_executables():
    """Drop jax's compiled-program caches after every test module.

    Each XLA compile mmaps JIT code into the process; across the full
    suite (~thousands of distinct jits, incl. the kernel matrix sweeps)
    the accumulated maps exhaust ``vm.max_map_count`` (65530 default)
    and the *next* compile segfaults inside XLA — in whatever test
    happens to run near the end.  Per-module clearing bounds the
    growth; modules recompile their own jits anyway, so the only cost
    is re-warming the handful of shared helpers."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rt():
    return Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny(arch_name: str, **kw):
    return reduced_config(get_arch(arch_name), **kw)
