"""SPMD pipeline equivalence, run in a fresh interpreter with 8 fake devices
(so the rest of the suite keeps the real single-device backend).

The subprocess asserts, per arch family: pipelined prefill+decode over a
(pod=2, data=2, model=2) mesh == the plain single-program path.
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.config import get_arch, reduced_config
from repro.models import model as M
from repro.models.common import Runtime
from repro.core import pipeline as PL

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
arch = os.environ["PIPE_ARCH"]
cfg0 = get_arch(arch)
period = len(cfg0.block_pattern)
cfg = reduced_config(cfg0, num_layers=2 * period + (2 if period > 1 else 1))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
n_mb, mb, S, cap = 3, 4, 10, 32
B = n_mb * mb
pcfg = PL.PipelineConfig(n_stages=2, n_microbatches=n_mb, mb_size=mb)
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
logits_ref, caches_ref = M.prefill(params, {"tokens": toks}, cfg, rt, cap)
tok = jnp.argmax(logits_ref, -1).astype(jnp.int32)
cur = jnp.full((B,), S, jnp.int32)
dec_ref, _ = M.decode_step(params, tok, caches_ref, cur, cfg, rt)
with mesh:
    pcaches = PL.init_pipeline_caches(cfg, pcfg, cap, rt)
    pl_logits, pcaches = jax.jit(
        lambda p, t, c: PL.pipeline_prefill(p, t, c, cfg, rt, pcfg))(
        params, toks.reshape(n_mb, mb, S), pcaches)
    err_pf = float(jnp.max(jnp.abs(pl_logits.reshape(B, -1) - logits_ref)))
    tok2 = jnp.argmax(pl_logits.reshape(B, -1), -1).astype(jnp.int32)
    dec_pl, pcaches = jax.jit(
        lambda p, t, c, cp: PL.pipeline_decode_step(p, t, c, cp, cfg, rt,
                                                    pcfg))(
        params, tok2.reshape(n_mb, mb), pcaches, cur.reshape(n_mb, mb))
    err_dec = float(jnp.max(jnp.abs(dec_pl.reshape(B, -1) - dec_ref)))
print(f"errs {err_pf:.3e} {err_dec:.3e}")
assert err_pf < 2e-3 and err_dec < 2e-3, (err_pf, err_dec)
print("OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi-9b", "gemma3-12b", "xlstm-1.3b",
                                  "recurrentgemma-9b"])
def test_pipeline_equals_plain(arch):
    env = dict(os.environ)
    env["PIPE_ARCH"] = arch
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-2000:]}"
    assert "OK" in r.stdout


@pytest.mark.slow
def test_dryrun_one_cell_compiles():
    """End-to-end 256-device lower+compile of one real cell (the smallest)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gemma3-1b",
         "--shape", "decode_32k", "--mesh", "single"],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert '"ok": true' in r.stdout


ROUNDS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.config import get_arch, reduced_config
from repro.models import model as M
from repro.models.common import Runtime
from repro.core import pipeline as PL

rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg0 = get_arch(os.environ["PIPE_ARCH"])
period = len(cfg0.block_pattern)
cfg = reduced_config(cfg0, num_layers=2 * period + (2 if period > 1 else 1))
params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
n_mb, mb, S, cap, R = 3, 4, 6, 48, 4
B = n_mb * mb
pcfg = PL.PipelineConfig(n_stages=2, n_microbatches=n_mb, mb_size=mb)
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
logits, caches = M.prefill(params, {"tokens": toks}, cfg, rt, cap)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
ref = []
for r in range(R):
    ref.append(np.asarray(tok))
    logits, caches = M.decode_step(params, tok, caches,
                                   jnp.full((B,), S + r, jnp.int32), cfg, rt)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
ref = np.stack(ref)
with mesh:
    pcaches = PL.init_pipeline_caches(cfg, pcfg, cap, rt)
    pl_logits, pcaches = jax.jit(
        lambda p, t, c: PL.pipeline_prefill(p, t, c, cfg, rt, pcfg))(
        params, toks.reshape(n_mb, mb, S), pcaches)
    tok0 = jnp.argmax(pl_logits.reshape(B, -1), -1).astype(jnp.int32)
    outs, _ = jax.jit(lambda p, t, c, cp: PL.pipeline_decode_rounds(
        p, t, c, cp, cfg, rt, pcfg, rounds=R))(
        params, tok0.reshape(n_mb, mb), pcaches,
        jnp.full((n_mb, mb), S, jnp.int32))
got = np.asarray(outs).reshape(R, B)
assert (got[:R - 1] == ref[1:]).all(), (got[:2], ref[1:3])
print("OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi-9b", "gemma3-12b"])
def test_multiround_circular_decode(arch):
    """R tokens in one circular pass == R sequential pipelined decodes —
    the paper's steady-state schedule, with sampling on the return link."""
    env = dict(os.environ)
    env["PIPE_ARCH"] = arch
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", ROUNDS_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
