"""Optimizer, schedules, grad accumulation, end-to-end loss descent,
gradient compression, checkpoint/restore, data pipeline."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import tiny
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticTokens, batches
from repro.distributed.compression import (Compressor, int8_compress,
                                           int8_decompress, topk_compress,
                                           topk_decompress)
from repro.models import model as M
from repro.training import optimizer as O
from repro.training import train_loop as TL


# ---------------------------------------------------------------- adamw ---

def test_adamw_single_step_matches_numpy():
    cfg = O.AdamWConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8,
                        weight_decay=0.0, grad_clip=0.0,
                        schedule="constant", warmup_steps=0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st_ = O.init(cfg, p)
    newp, st2, _ = O.apply(cfg, p, g, st_)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(newp["w"][0]), want, rtol=1e-6)
    assert int(st2.step) == 1


def test_weight_decay_pulls_to_zero():
    cfg = O.AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0,
                        schedule="constant", warmup_steps=0)
    p = {"w": jnp.asarray([4.0])}
    g = {"w": jnp.asarray([0.0])}
    newp, _, _ = O.apply(cfg, p, g, O.init(cfg, p))
    assert float(newp["w"][0]) < 4.0


def test_schedule_shapes():
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                        min_lr_ratio=0.1, schedule="cosine")
    lr0 = float(O.schedule_lr(cfg, jnp.asarray(0)))
    lr_w = float(O.schedule_lr(cfg, jnp.asarray(10)))
    lr_end = float(O.schedule_lr(cfg, jnp.asarray(110)))
    assert lr0 == pytest.approx(0.0)
    assert lr_w == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, rel=1e-3)


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, norm = O.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    got = float(jnp.linalg.norm(clipped["a"]))
    assert got == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------- train loop ---

def test_loss_descends_and_accum_equivalence(rt, key):
    cfg = tiny("minitron-4b")
    ocfg = O.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=40,
                         grad_clip=1.0)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=24, batch_size=4)
    params, opt_state, res = TL.train(cfg, rt, ocfg, batches(dcfg), steps=20)
    assert res.losses[-1] < res.losses[0] - 0.3

    # accumulation: accum=2 over half batches == one full batch, same grads
    params = M.init_params(cfg, key, rt)
    batch = next(batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    batch_size=4, seed=7)))
    # equal per-microbatch token counts (mean-of-means == global mean)
    batch["loss_mask"] = np.ones_like(batch["loss_mask"])
    split = {k: np.stack([v[:2], v[2:]]) for k, v in batch.items()}
    step1 = TL.make_train_step(cfg, rt, ocfg, accum_steps=1)
    step2 = TL.make_train_step(cfg, rt, ocfg, accum_steps=2)
    o1 = O.init(ocfg, params)
    p1, _, m1 = step1(params, o1, batch)
    p2, _, m2 = step2(params, O.init(ocfg, params), split)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


# ---------------------------------------------------------- compression ---

def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = int8_compress(x)
    y = int8_decompress(q, s)
    assert float(jnp.max(jnp.abs(x - y))) <= float(s) * 0.5 + 1e-7


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    vals, idx, shape = topk_compress(x, 0.4)
    y = topk_decompress(vals, idx, shape)
    np.testing.assert_allclose(np.asarray(y),
                               [0.0, -5.0, 0.0, 3.0, 0.0], atol=1e-6)


def test_error_feedback_accumulates():
    """With EF, the *sum* of compressor outputs over steps converges to the
    sum of inputs (no systematic bias)."""
    comp = Compressor(method="topk", topk_frac=0.25)
    g = {"w": jnp.asarray([1.0, 0.1, 0.01, 0.001])}
    total = np.zeros(4)
    for _ in range(50):
        out = comp.roundtrip(g)
        total += np.asarray(out["w"])
    np.testing.assert_allclose(total / 50, np.asarray(g["w"]), rtol=0.15,
                               atol=0.02)


def test_compression_ratio():
    comp = Compressor(method="int8")
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    assert comp.compression_ratio(g) > 3.5
    comp2 = Compressor(method="topk", topk_frac=0.01)
    assert comp2.compression_ratio(g) > 40


def test_training_converges_with_compression(rt):
    cfg = tiny("minitron-4b")
    ocfg = O.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=40)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=24, batch_size=4)
    comp = Compressor(method="int8")
    _, _, res = TL.train(cfg, rt, ocfg, batches(dcfg), steps=15,
                         compressor=comp)
    assert res.losses[-1] < res.losses[0] - 0.2


# ----------------------------------------------------------- checkpoint ---

def test_checkpoint_roundtrip_and_retention(rt, key):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, key, rt)
    ocfg = O.AdamWConfig()
    opt = O.init(ocfg, params)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for step in (10, 20, 30):
            mgr.save(step, {"params": params, "opt_state": opt},
                     {"step": step})
        assert mgr.steps() == [20, 30]          # retention
        restored, meta = mgr.restore({"params": params, "opt_state": opt})
        assert meta["step"] == 30
        for a, b in zip(jax.tree.leaves(restored["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(rt, key):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, key, rt)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.zeros((5,))})


def test_checkpoint_no_tmp_left_behind():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, {"x": jnp.ones((2,))})
        assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_resume_continues_step_count(rt):
    cfg = tiny("minitron-4b")
    ocfg = O.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        p, o, _ = TL.train(cfg, rt, ocfg, batches(dcfg), steps=3,
                           checkpoint_mgr=mgr, checkpoint_every=3)
        assert mgr.latest_step() == 3
        (restored, _) = mgr.restore({"params": p, "opt_state": o})
        p2, o2, _ = TL.train(cfg, rt, ocfg, batches(dcfg), steps=2,
                             params=restored["params"],
                             opt_state=restored["opt_state"])
        assert int(o2.step) == 5


# ------------------------------------------------------------------ data ---

def test_data_deterministic_and_sharded():
    dcfg = DataConfig(vocab_size=64, seq_len=16, batch_size=4, seed=3)
    a = next(batches(dcfg))
    b = next(batches(dcfg))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert a["labels"].shape == (4, 16)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # host sharding: different hosts see disjoint rows
    h0 = next(batches(dcfg, host_index=0, host_count=2))
    h1 = next(batches(dcfg, host_index=1, host_count=2))
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_has_learnable_structure():
    dcfg = DataConfig(vocab_size=128, seq_len=64, batch_size=8, seed=0)
    gen = SyntheticTokens(dcfg)
    doc = gen.document()
    assert doc.min() >= 1 and doc.max() < 128
    # bigram table makes transitions predictable more often than chance
    b = next(batches(dcfg))
    toks = b["tokens"]
    nxt = gen._next[toks[:, :-1]]
    hit = (nxt == toks[:, 1:]).mean()
    assert hit > 0.3
