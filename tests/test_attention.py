"""Flash attention vs naive attention: forward, gradients, schemes, decode.
Includes hypothesis property tests on the attention invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (decode_attention, flash_attention)


def naive(q, k, v, causal=True, window=0, q_offset=0):
    b, sq, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, sq, hk, g, dh)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k) / jnp.sqrt(dh)
    qp = jnp.arange(sq)[:, None] + q_offset
    kp = jnp.arange(k.shape[1])[None]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v)
    return o.reshape(b, sq, h, dh)


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


CASES = [
    # (sq, skv, h, hk, dh, causal, window, offset, cq, ck)
    (33, 33, 4, 2, 16, True, 0, 0, 16, 16),
    (64, 64, 4, 1, 32, True, 0, 0, 16, 32),
    (40, 40, 2, 2, 8, True, 12, 0, 8, 8),
    (24, 24, 8, 4, 16, False, 0, 0, 8, 8),
    (16, 48, 4, 2, 16, True, 0, 32, 16, 16),   # continuation (offset)
    (7, 7, 2, 1, 8, True, 0, 0, 16, 16),       # seq smaller than chunk
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("scheme", ["masked", "blockpair"])
def test_flash_matches_naive(case, scheme):
    sq, skv, h, hk, dh, causal, window, off, cq, ck = case
    if scheme == "blockpair" and (not causal or window):
        pytest.skip("blockpair is the causal-only scheme")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], 2, sq, h, dh)
    k = _rand(ks[1], 2, skv, hk, dh)
    v = _rand(ks[2], 2, skv, hk, dh)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=off, q_chunk=cq, kv_chunk=ck,
                          scheme=scheme)
    ref = naive(q, k, v, causal=causal, window=window, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CASES[:4])
def test_flash_gradients_match_naive(case):
    sq, skv, h, hk, dh, causal, window, off, cq, ck = case
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = _rand(ks[0], 1, sq, h, dh)
    k = _rand(ks[1], 1, skv, hk, dh)
    v = _rand(ks[2], 1, skv, hk, dh)
    co = _rand(ks[3], 1, sq, h, dh)

    f1 = lambda q, k, v: jnp.sum(flash_attention(
        q, k, v, causal=causal, window=window, q_offset=off, q_chunk=cq,
        kv_chunk=ck) * co)
    f2 = lambda q, k, v: jnp.sum(naive(q, k, v, causal=causal, window=window,
                                       q_offset=off) * co)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_blockpair_equals_masked():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], 2, 48, 4, 16)
    k = _rand(ks[1], 2, 48, 2, 16)
    v = _rand(ks[2], 2, 48, 2, 16)
    a = flash_attention(q, k, v, q_chunk=16, kv_chunk=16, scheme="masked")
    b = flash_attention(q, k, v, q_chunk=16, kv_chunk=16, scheme="blockpair")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


def test_decode_matches_last_row_of_prefill():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    S, h, hk, dh = 12, 4, 2, 16
    q = _rand(ks[0], 2, S, h, dh)
    k = _rand(ks[1], 2, S, hk, dh)
    v = _rand(ks[2], 2, S, hk, dh)
    full = naive(q, k, v, causal=True)
    slot_pos = jnp.broadcast_to(jnp.arange(S)[None], (2, S)).astype(jnp.int32)
    cur = jnp.full((2,), S - 1, jnp.int32)
    dec = decode_attention(q[:, -1], k, v, slot_pos, cur)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_ignores_empty_and_future_slots():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    C, h, hk, dh = 16, 2, 1, 8
    q = _rand(ks[0], 1, h, dh)
    k = _rand(ks[1], 1, C, hk, dh)
    v = _rand(ks[2], 1, C, hk, dh)
    # only slots 0..3 valid
    slot_pos = jnp.full((1, C), -1, jnp.int32).at[0, :4].set(
        jnp.arange(4, dtype=jnp.int32))
    cur = jnp.asarray([3], jnp.int32)
    out = decode_attention(q, k, v, slot_pos, cur)
    ref = decode_attention(q, k[:, :4], v[:, :4],
                           slot_pos[:, :4], cur)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)
    # corrupting an invalid slot's kv must not change the output
    k2 = k.at[0, 10].set(99.0)
    out2 = decode_attention(q, k2, v, slot_pos, cur)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2))


@settings(max_examples=25, deadline=None)
@given(
    sq=st.integers(2, 24), hk=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]), dh=st.sampled_from([4, 8]),
    window=st.integers(0, 8), seed=st.integers(0, 2**16),
)
def test_property_output_in_value_hull(sq, hk, g, dh, window, seed):
    """Attention output of each position is a convex combination of values:
    per-dim it lies within [min v, max v]."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    h = hk * g
    q = _rand(ks[0], 1, sq, h, dh)
    k = _rand(ks[1], 1, sq, hk, dh)
    v = _rand(ks[2], 1, sq, hk, dh)
    out = flash_attention(q, k, v, causal=True, window=window, q_chunk=8,
                          kv_chunk=8)
    vmin = jnp.min(v, axis=1).min()
    vmax = jnp.max(v, axis=1).max()
    assert bool(jnp.all(out >= vmin - 1e-4))
    assert bool(jnp.all(out <= vmax + 1e-4))


@settings(max_examples=15, deadline=None)
@given(sq=st.integers(3, 20), dh=st.sampled_from([4, 8]),
       seed=st.integers(0, 2**16))
def test_property_causality(sq, dh, seed):
    """Perturbing future keys/values never changes earlier outputs."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], 1, sq, 2, dh)
    k = _rand(ks[1], 1, sq, 2, dh)
    v = _rand(ks[2], 1, sq, 2, dh)
    cut = sq // 2
    out1 = flash_attention(q, k, v, causal=True, q_chunk=4, kv_chunk=4)
    k2 = k.at[:, cut:].add(3.0)
    v2 = v.at[:, cut:].add(-2.0)
    out2 = flash_attention(q, k2, v2, causal=True, q_chunk=4, kv_chunk=4)
    np.testing.assert_allclose(np.asarray(out1[:, :cut]),
                               np.asarray(out2[:, :cut]), rtol=1e-5,
                               atol=1e-6)
