"""Dry-run harness unit tests: HLO collective parsing, wire-byte
conventions, roofline term math, pipeline-config selection.  (The heavy
512-device compiles are exercised by the SPMD subprocess test and the sweep
artifacts; here we pin the pure logic.)"""

import jax

# lock the backend to the real single CPU device BEFORE importing the dryrun
# module (which sets XLA_FLAGS=...device_count=512 for its own __main__ use)
jax.devices()

import pytest  # noqa: E402

from repro.launch import dryrun as DR  # noqa: E402


HLO = """
  %all-gather = f32[256,8192]{0,1} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={1}
  %all-reduce.1 = bf16[128,4096]{1,0} all-reduce(%y), replica_groups=[32,8]<=[256], to_apply=%add
  %reduce-scatter.2 = f32[64]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %collective-permute.3 = bf16[32,1,4096]{2,1,0} collective-permute(%w), source_target_pairs={{0,256},{256,0}}
  %cp2 = f32[8,8]{1,0} collective-permute(%v), source_target_pairs={{0,1},{1,2}}
  %all-to-all.9 = f32[16,64]{1,0} all-to-all(%u), replica_groups=[4,4]<=[16], dimensions={0}
"""


def test_collective_bytes_parsing():
    out = DR.collective_bytes(HLO)
    # all-gather: 256*8192*4 bytes, group 16 -> (15/16)x
    ag = 256 * 8192 * 4 * 15 / 16
    assert out["all-gather"] == pytest.approx(ag)
    # all-reduce: 2*(g-1)/g * size, group 8
    ar = 2 * 7 / 8 * 128 * 4096 * 2
    assert out["all-reduce"] == pytest.approx(ar)
    # reduce-scatter with explicit groups of 4
    rs = 3 / 4 * 64 * 4
    assert out["reduce-scatter"] == pytest.approx(rs)
    # permutes count full size
    cp = 32 * 4096 * 2 + 8 * 8 * 4
    assert out["collective-permute"] == pytest.approx(cp)
    a2a = 3 / 4 * 16 * 64 * 4
    assert out["all-to-all"] == pytest.approx(a2a)
    assert out["total"] == pytest.approx(ag + ar + rs + cp + a2a)
    assert out["counts"]["collective-permute"] == 2


def test_pod_boundary_bytes():
    # only the {0,256} permute crosses the 512/2 boundary
    got = DR.pod_boundary_bytes(HLO, n_devices=512)
    assert got == pytest.approx(32 * 4096 * 2)


def test_group_size_fallbacks():
    assert DR._group_size("replica_groups=[16,16]<=[256]") == 16
    assert DR._group_size("replica_groups={{0,1,2}}") == 3
    assert DR._group_size("source_target_pairs={{0,1}}") == 2
    assert DR._group_size("no groups here") == 1


def test_roofline_terms():
    rec = {
        "flops_per_device": 197e12,          # exactly 1 second of compute
        "bytes_per_device": 819e9 / 2,       # 0.5 s of HBM
        "collectives": {"total": 50e9 * 2},  # 2 s of ICI
    }
    t = DR.roofline_terms(rec)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(2.0)
    assert t["dominant"] == "collective_s"
    assert t["bound_step_s"] == pytest.approx(2.0)
    assert t["compute_fraction_of_bound"] == pytest.approx(0.5)


def test_serve_pipeline_config():
    from repro.config import SHAPES
    p = DR.serve_pipeline_config(SHAPES["decode_32k"])
    assert p.n_microbatches * p.mb_size == 128
    assert p.n_microbatches >= p.n_stages
    lone = DR.serve_pipeline_config(SHAPES["long_500k"])
    assert lone.global_batch == 1 and lone.n_microbatches == 1
    assert lone.n_ticks == 2                 # fill the 2-stage pipe


def test_batch_inputs_shapes():
    from repro.config import SHAPES, get_arch
    cfg = get_arch("qwen2-vl-2b")
    b = DR.batch_inputs(cfg, SHAPES["train_4k"], include_labels=True)
    assert b["patches"].shape == (256, 256, 1536)
    assert b["tokens"].shape == (256, 4096 - 256)
    assert b["labels"].shape == b["tokens"].shape
    cfg2 = get_arch("musicgen-large")
    b2 = DR.batch_inputs(cfg2, SHAPES["prefill_32k"], include_labels=False)
    assert b2["frames"].shape == (32, 32768, 2048)


def test_long500k_skip_logic(tmp_path):
    rec = DR.run_cell("yi-9b", "long_500k", "single_pod",
                      out_dir=str(tmp_path))
    assert rec["skipped"] and rec["ok"]
    assert "full-attention" in rec["reason"]
