"""MoE dispatch: capacity behaviour, chunked == unchunked, EP partial-sum
equivalence, router normalisation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig
from repro.models import moe as M


def _weights(key, e, d, de):
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, e)) * 0.5,
        "wg": jax.random.normal(ks[1], (e, d, de)) * 0.2,
        "wu": jax.random.normal(ks[2], (e, d, de)) * 0.2,
        "wd": jax.random.normal(ks[3], (e, de, d)) * 0.2,
    }


MOE = MoEConfig(num_experts=4, experts_per_token=2, d_expert=16,
                capacity_factor=2.0)


def test_chunked_equals_unchunked():
    key = jax.random.PRNGKey(0)
    w = _weights(key, 4, 8, 16)
    x = jax.random.normal(key, (32, 8))
    full = M.moe_ffn(x, w, MOE)
    # chunked capacity is computed per chunk — same tokens, same experts
    chk = M.moe_ffn(x, w, MOE, token_chunk=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk), rtol=2e-5,
                               atol=2e-5)


def test_ep_partial_sums_equal_full():
    """Two half-expert shards must psum to the full-expert output."""
    key = jax.random.PRNGKey(1)
    e, d, de = 4, 8, 16
    w = _weights(key, e, d, de)
    x = jax.random.normal(key, (16, d))
    full = M.moe_ffn(x, w, MOE)
    parts = []
    for (e0, ec) in [(0, 2), (2, 2)]:
        w_shard = {"router": w["router"],
                   "wg": w["wg"][e0:e0 + ec], "wu": w["wu"][e0:e0 + ec],
                   "wd": w["wd"][e0:e0 + ec]}
        parts.append(M.moe_ffn(x, w_shard, MOE, expert_shard=(e0, ec)))
    np.testing.assert_allclose(np.asarray(parts[0] + parts[1]),
                               np.asarray(full), rtol=2e-5, atol=2e-5)


def test_no_drops_at_generous_capacity():
    """With capacity_factor 2 and uniform-ish routing, the combine weights
    must sum to ~1 for every token (nothing dropped)."""
    key = jax.random.PRNGKey(2)
    w = _weights(key, 4, 8, 16)
    x = jax.random.normal(key, (64, 8))
    topv, topi, _ = M.router_probs(x, w["router"], MOE)
    cap = M.expert_capacity(64, MOE)
    flat_e, pos = M._positions_in_expert(topi, MOE.num_experts)
    assert bool(jnp.all(pos < cap)), "unexpected capacity overflow"


def test_capacity_drops_are_zero_weight():
    """Force overflow with capacity_factor ~0: output must be exactly 0
    (all tokens dropped), not garbage."""
    moe = MoEConfig(num_experts=2, experts_per_token=1, d_expert=8,
                    capacity_factor=1e-9)
    key = jax.random.PRNGKey(3)
    w = _weights(key, 2, 4, 8)
    x = jax.random.normal(key, (64, 4))
    out = M.moe_ffn(x, w, moe)
    # capacity floor is 4 slots; tokens beyond it contribute zero
    n_kept = 2 * 4  # experts * floor-capacity
    norms = jnp.linalg.norm(out, axis=-1)
    assert int(jnp.sum(norms > 1e-7)) <= n_kept


def test_router_normalisation():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (8, 4))
    wr = jax.random.normal(key, (4, 4))
    moe_norm = MoEConfig(num_experts=4, experts_per_token=2, d_expert=8,
                         normalize_router_weights=True)
    topv, _, probs = M.router_probs(x, wr, moe_norm)
    np.testing.assert_allclose(np.asarray(jnp.sum(topv, -1)),
                               np.ones(8), rtol=1e-5)
    moe_raw = MoEConfig(num_experts=4, experts_per_token=2, d_expert=8,
                        normalize_router_weights=False)
    topv2, _, _ = M.router_probs(x, wr, moe_raw)
    assert bool(jnp.all(jnp.sum(topv2, -1) <= 1.0 + 1e-6))


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss == num_experts * E[f*P] == 1."""
    n, e = 1024, 8
    probs = jnp.full((n, e), 1.0 / e)
    topi = jnp.stack([jnp.arange(n) % e, (jnp.arange(n) + 1) % e], axis=1)
    moe = MoEConfig(num_experts=e, experts_per_token=2, d_expert=4)
    loss = M.moe_load_balance_loss(probs, topi, moe)
    np.testing.assert_allclose(float(loss), 1.0, rtol=1e-5)
