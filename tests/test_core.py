"""DeServe core math: scheduler (§4.3), offload formulas (§4.2), cost model
(§3), simulator (§5 / Table 4).  Hypothesis property tests on the formulas."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cost_model as CM
from repro.core import offload as OF
from repro.core import scheduler as SC
from repro.core import simulator as SIM


# ---------------------------------------------------------------- formulas

def test_formula2_global_pool():
    # M_G = W * T_S
    assert OF.global_pool_bytes(16e9, 0.08) == pytest.approx(1.28e9)


def test_formula1_capacity():
    # M_B' = (M_KV - 2 M_G)/N_B + M_G
    m_kv, m_g = 8e9, 1e9
    got = OF.per_microbatch_capacity(m_kv, m_g, 8)
    assert got == pytest.approx((8e9 - 2e9) / 8 + 1e9)
    # without offload
    assert OF.per_microbatch_capacity_no_offload(m_kv, 8) == 1e9


@settings(max_examples=50, deadline=None)
@given(m_kv=st.floats(1e8, 1e11), m_g_frac=st.floats(0.01, 0.49),
       n1=st.integers(2, 64), n2=st.integers(2, 64))
def test_property_capacity_floor_independent_of_nb(m_kv, m_g_frac, n1, n2):
    """The paper's central synergy: capacity never drops below M_G no matter
    how many microbatches are in flight (Formula 1's floor)."""
    m_g = m_kv * m_g_frac
    c1 = OF.per_microbatch_capacity(m_kv, m_g, n1)
    c2 = OF.per_microbatch_capacity(m_kv, m_g, n2)
    assert c1 >= m_g and c2 >= m_g
    # and without offload, capacity decays ~1/N_B
    assert OF.per_microbatch_capacity_no_offload(m_kv, 64) == \
        pytest.approx(m_kv / 64)


def test_nb_star_paper_example():
    """Figure 2(c): 4 machines, latency = T_S/2 -> 6 microbatches."""
    assert SC.optimal_microbatches(4, 1.0, 0.5) == 6


@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 16), ts=st.floats(0.01, 1.0),
       lat=st.floats(0.0, 1.0))
def test_property_nb_star_is_bubble_free(n, ts, lat):
    nb = SC.optimal_microbatches(n, ts, lat)
    assert SC.bubble_fraction(n, nb, ts, lat) <= 1e-9
    # one fewer microbatch must leave a bubble when latency > 0
    # (guard against exact-division float edge: (nb-1)*ts == period)
    if nb > n and (nb - 1) * ts < n * (ts + lat) * (1 - 1e-9):
        assert SC.bubble_fraction(n, nb - 1, ts, lat) > 0


def test_bubble_fraction_limits():
    assert SC.bubble_fraction(8, 8, 0.1, 0.0) == pytest.approx(0.0)
    # N_B = N_M with latency L: busy N_B*T_S of N_M*(T_S+L)
    assert SC.bubble_fraction(8, 8, 0.1, 0.1) == pytest.approx(0.5)


def test_schedule_steady_tick_and_assignment():
    ps = SC.PipelineSchedule(n_stages=4, n_microbatches=6, stage_time=1.0,
                             latency=0.5)
    assert ps.round_trip == pytest.approx(6.0)
    assert ps.steady_tick == pytest.approx(1.0)
    # each tick every stage works on a distinct microbatch
    for t in range(12):
        mbs = [ps.microbatch_at(s, t) for s in range(4)]
        assert len(set(mbs)) == 4


def test_plan_schedule_offload_beats_no_offload_at_latency():
    kw = dict(n_stages=8, stage_time=0.08, latency=0.064,
              m_kv_bytes=2e9, kv_bytes_per_seq=15.7e6,
              offload_bandwidth=6e9)
    with_off = SC.plan_schedule(use_offload=True, **kw)
    no_off = SC.plan_schedule(use_offload=False, **kw)
    assert with_off.per_mb_batch > no_off.per_mb_batch
    assert with_off.offload and not no_off.offload


def test_schedule_diagram_figure2c():
    """4 stages, L = T_S/2 -> the 6-microbatch diagram has no bubbles in
    steady state; the 4-microbatch one idles 1/3 of the time."""
    full = SC.schedule_diagram(4, 6, stage_time=1.0, latency=0.5, ticks=24)
    row0 = full.splitlines()[1]
    steady = row0.split("|")[1][8 * 2:]          # past the fill
    assert "." not in steady
    starved = SC.schedule_diagram(4, 4, stage_time=1.0, latency=0.5,
                                  ticks=24)
    assert "." in starved.splitlines()[1].split("|")[1][8 * 2:]


def test_per_link_latencies_generalise_the_scalar():
    """§4.3 per-link form: only Σ L_i enters the steady state, so a
    uniform list reproduces the scalar exactly and any spread of the
    same sum plans identically (one 256ms link == 4 x 64ms links)."""
    assert SC.optimal_microbatches(4, 1.0, link_latencies=[0.5] * 4) == \
        SC.optimal_microbatches(4, 1.0, 0.5) == 6
    assert SC.bubble_fraction(8, 8, 0.1, link_latencies=[0.1] * 8) == \
        pytest.approx(0.5)
    kw = dict(n_stages=4, stage_time=0.08, m_kv_bytes=2e9,
              kv_bytes_per_seq=15.7e6, offload_bandwidth=6e9)
    lop = SC.plan_schedule(link_latencies=[0.016, 0.0, 0.0, 0.24], **kw)
    uni = SC.plan_schedule(latency=0.064, **kw)     # same sum: 0.256
    assert lop.n_microbatches == uni.n_microbatches
    assert lop.utilisation == pytest.approx(uni.utilisation)
    # the list wins over the scalar when both are given
    assert SC.optimal_microbatches(4, 1.0, 9.9,
                                   link_latencies=[0.0] * 4) == 4
    with pytest.raises(ValueError, match="link"):
        SC.optimal_microbatches(4, 1.0, link_latencies=[0.5] * 3)
    with pytest.raises(ValueError, match=">= 0"):
        SC.bubble_fraction(4, 4, 1.0, link_latencies=[0.1, -0.1, 0, 0])


def test_plan_schedule_raises_when_one_seq_too_big():
    with pytest.raises(ValueError):
        SC.plan_schedule(n_stages=4, stage_time=0.1, latency=0.0,
                         m_kv_bytes=1e6, kv_bytes_per_seq=1e9)


# ---------------------------------------------------------------- cost §3

def test_table2_matches_paper():
    t2 = CM.table2()
    for name, want in CM.PAPER_TABLE2.items():
        got = t2[name]["min_throughput_tps"]
        assert abs(got - want) / want < 0.01, (name, got, want)


def test_profitability():
    # mining: 108 tok/s breaks even; 450 tok/s is profitable
    assert not CM.is_profitable(100, "mining")
    assert CM.is_profitable(120, "mining")
    assert CM.profit_per_hour(450, CM.PLATFORMS["mining"].cost_per_hour) > 0
    # the same throughput is deeply unprofitable on cloud
    assert not CM.is_profitable(450, "cloud")


# ---------------------------------------------------------------- sim §5

def test_stage_time_interpolation():
    # table anchor points exact
    assert SIM.stage_time(1) == pytest.approx(0.0666)
    assert SIM.stage_time(128) == pytest.approx(0.0891)
    # monotone between anchors, extrapolates linearly beyond 256
    assert SIM.stage_time(96) > SIM.stage_time(64)
    assert SIM.stage_time(512) > SIM.stage_time(256)


@pytest.fixture(scope="module")
def t4():
    return SIM.table4(sim_seconds=300, warmup=60)


def test_sim_calibration_anchor(t4):
    got = t4["deserve_pp"][0.0].output_tps
    assert abs(got - 194.6) / 194.6 < 0.08


def test_sim_policy_ordering(t4):
    for lat in (0.0, 0.016, 0.032, 0.064):
        v = t4["vllm_pp"][lat].output_tps
        d = t4["deserve_pp"][lat].output_tps
        o = t4["deserve_opt"][lat].output_tps
        assert v < d < o, lat


def test_sim_opt_flat_under_latency(t4):
    """The paper's headline property: DeServe(opt) holds throughput flat
    from <1 ms to 256 ms (paper: 445 -> 443)."""
    vals = [t4["deserve_opt"][l].output_tps
            for l in (0.0, 0.016, 0.032, 0.064, 0.256)]
    # paper holds 445->443 (<4%); our mechanics-only model holds within 20%
    # (the 256 ms point *rises* as the planner adds microbatches against the
    # M_G floor — see EXPERIMENTS.md discussion)
    assert min(vals) > 0.80 * max(vals)


def test_sim_baselines_degrade(t4):
    assert t4["vllm_pp"][0.256].output_tps < \
        0.5 * t4["vllm_pp"][0.0].output_tps
    assert t4["deserve_pp"][0.064].output_tps < \
        t4["deserve_pp"][0.0].output_tps


def test_sim_speedup_band(t4):
    """Paper: 6.7x-12.6x at 16-64 ms.  Our mechanics-only model lands in a
    4.5x-10x band (our vLLM baseline is more charitable; see EXPERIMENTS)."""
    for lat in (0.016, 0.032, 0.064):
        speed = t4["deserve_opt"][lat].output_tps / \
            t4["vllm_pp"][lat].output_tps
        assert speed > 4.0, (lat, speed)


def test_sim_opt_uses_more_microbatches_at_latency(t4):
    assert t4["deserve_opt"][0.256].n_microbatches > \
        t4["deserve_opt"][0.0].n_microbatches
