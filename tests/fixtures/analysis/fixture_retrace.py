"""Lint fixture: retrace hazards.  Never imported — parsed only.

``hot_step`` builds a fresh ``jax.jit`` inside a (configured) hot root
— ``retrace-jit``.  ``build_tick`` jits a ``functools.partial`` with a
mutable-literal kwarg — ``retrace-nonhashable`` (fires everywhere, no
reachability needed).  ``tick_fn`` (configured as a traced tick fn)
branches Python-side on a traced argument — ``retrace-branch``."""

import functools

import jax


def hot_step(params, tokens):
    step = jax.jit(lambda p, t: p)  # LINT-EXPECT: retrace-jit
    return step(params, tokens)


def build_tick(fn):
    return jax.jit(functools.partial(fn, scales=[1.0, 0.5]))  # LINT-EXPECT: retrace-nonhashable


def tick_fn(params, acts, gate):
    if gate:  # LINT-EXPECT: retrace-branch
        acts = acts + 1
    return acts
