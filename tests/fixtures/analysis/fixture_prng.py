"""Lint fixture: PRNG-hygiene breaches.  Never imported — parsed only.

``sample_token`` keys a sampling call with a single-level ``fold_in``
chain (the serving discipline is two folds: request_id AND token_idx)
— exactly one ``prng-fold-drop``.  ``noisy_pair`` feeds one key to two
consumers without re-binding — exactly one ``prng-reuse``."""

import jax


def sample_token(logits_row, seed, request_id):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), request_id)
    return jax.random.categorical(key, logits_row)  # LINT-EXPECT: prng-fold-drop


def noisy_pair(shape):
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, shape)
    b = jax.random.normal(key, shape)  # LINT-EXPECT: prng-reuse
    return a, b
