"""Lint fixture: flight-recorder calls in the wrong places.  Never
imported — the auditor parses it (pure AST).  The test pins
``tick_fn`` as a tick jit and ``hot_step`` as a hot root; exactly two
``obs-hot-path`` violations must fire at the marked lines:

* a recording call inside the tick-jit body (the recorder is host-side
  only — under tracing it fails or bakes one trace's stamps in);
* a recording call in the hot path fed a device-tracked value (it
  materialises the array, adding the sync the recorder must never add).

The host-scalar recording call in ``hot_step`` is the sanctioned shape
and must NOT fire."""

import time

import jax.numpy as jnp


def tick_fn(tokens, caches, recorder):
    logits = jnp.dot(tokens, caches)
    recorder.instant("tick", 0.0)  # LINT-EXPECT: obs-hot-path
    return logits


def hot_step(rec, tokens):
    t0 = time.perf_counter()
    logits = jnp.asarray(tokens)
    rec.span("decode", t0, time.perf_counter())          # host stamps: fine
    rec.instant("logits", logits[0])  # LINT-EXPECT: obs-hot-path
    return logits
