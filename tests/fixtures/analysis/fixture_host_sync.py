"""Lint fixture: a device→host materialisation inside a serve tick
loop.  Never imported — the auditor parses it (pure AST).  The test
configures ``tick_loop`` as a hot root; exactly one ``host-sync``
violation must fire at the marked line."""

import jax
import jax.numpy as jnp


def tick_loop(params, tokens):
    logits = jnp.ones((tokens.shape[0], 8))
    probs = jax.device_get(logits)  # LINT-EXPECT: host-sync
    return probs
