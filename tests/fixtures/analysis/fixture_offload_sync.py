"""Lint fixture: a blocking host copy inside the KV offloader's engaged
window.  Never imported — the auditor parses it (pure AST).  The test
configures ``ensure_resident`` as an offload window; exactly one
``offload-sync`` violation must fire at the marked line (``jnp.zeros``
and the enqueued ``device_put`` are fine — only *blocking*
materialisations stall the double-buffer overlap)."""

import jax
import jax.numpy as jnp
import numpy as np


class Offloader:
    def ensure_resident(self, caches, mb):
        sl = jax.lax.slice_in_dim(caches["k_pages"], 0, 4, axis=0)
        staged = jax.device_put(sl)                  # enqueued: allowed
        host = np.asarray(staged)  # LINT-EXPECT: offload-sync
        pad = jnp.zeros((4,), jnp.float32)
        return host, pad
