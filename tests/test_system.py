"""End-to-end behaviour: the full serving system (engine + pools + offload +
scheduler-chosen microbatches) and the full training system (data →
train loop → checkpoint → restart) — the two paper-level workflows."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny
from repro.core.offload import DoubleBufferOffloader
from repro.core.scheduler import optimal_microbatches
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, batches
from repro.models import model as M
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams
from repro.training import optimizer as O
from repro.training import train_loop as TL


def test_offline_serving_workflow(rt):
    """Paper §5 workload in miniature: submit a request batch, replenish on
    completion, measure throughput accounting."""
    cfg = tiny("recurrentgemma-9b")          # hybrid: recurrent + window
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    n_b = optimal_microbatches(2, 1.0, 0.4)  # pretend 2 stages, L=0.4*T_S
    assert n_b == 3
    pool = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                      max_pages_per_seq=6)
    off = DoubleBufferOffloader(pool, n_b)
    sp = SamplingParams(temperature=0.0, max_new_tokens=6)
    eng = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=n_b,
                        pool=pool, sampling=sp, offloader=off)
    rng = np.random.RandomState(0)
    eng.submit([Request(i, list(rng.randint(1, cfg.vocab_size, 5)), sp)
                for i in range(10)])
    done = eng.run(max_steps=500)
    assert len(done) == 10
    rep = eng.throughput_report()
    assert rep["decode_tokens"] == 60
    assert rep["prefill_tokens"] == 50
    assert rep["finished"] == 10


def test_train_crash_restart_workflow(rt):
    """Fault tolerance: train, 'crash', restore from the atomic checkpoint,
    continue — final state identical to an uninterrupted run."""
    cfg = tiny("gemma3-1b")
    ocfg = O.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)

    def data():
        return batches(dcfg)

    # uninterrupted 6 steps
    p_ref, o_ref, _ = TL.train(cfg, rt, ocfg, data(), steps=6)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        it = data()
        p1, o1, _ = TL.train(cfg, rt, ocfg, it, steps=3,
                             checkpoint_mgr=mgr, checkpoint_every=3)
        del p1, o1                           # "crash"
        template = {"params": M.init_params(cfg, jax.random.PRNGKey(0), rt),
                    "opt_state": O.init(ocfg, M.init_params(
                        cfg, jax.random.PRNGKey(0), rt))}
        restored, _ = mgr.restore(template)
        # data iterator replay: consume the first 3 batches
        it2 = data()
        for _ in range(3):
            next(it2)
        p2, o2, _ = TL.train(cfg, rt, ocfg, it2, steps=3,
                             params=restored["params"],
                             opt_state=restored["opt_state"])
    assert int(o2.step) == int(o_ref.step) == 6
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)
