"""Serving stack: allocator, paged caches, engine continuous batching,
offloader rotation, engine == reference greedy decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.core.offload import DoubleBufferOffloader
from repro.models import model as M
from repro.serving import kv_cache as kvc
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PageAllocator, PoolConfig
from repro.serving.request import Request, SamplingParams


# ---------------------------------------------------------------- alloc ---

def test_allocator_basic_and_rollback():
    pool = PoolConfig(page_size=4, n_local_pages=5, n_global_pages=2,
                      max_pages_per_seq=8)
    al = PageAllocator(pool)
    assert al.free_local() == 4          # page 0 reserved as scratch
    pages = al.allocate(0, 3)
    assert len(pages) == 3 and 0 not in pages
    assert al.free_local() == 1
    # exceeding local+global capacity rolls back cleanly
    with pytest.raises(MemoryError):
        al.allocate(1, 6, global_pool=0)
    assert al.free_local() == 1 and al.free_global(0) == 2
    al.release(0)
    assert al.free_local() == 4


def test_allocator_global_pool_separation():
    pool = PoolConfig(page_size=4, n_local_pages=2, n_global_pages=3,
                      max_pages_per_seq=8)
    al = PageAllocator(pool)
    p0 = al.allocate(0, 3, global_pool=0)   # 1 local + 2 from G0
    g0 = set(pool.global_range(0))
    g1 = set(pool.global_range(1))
    assert len(set(p0) & g1) == 0
    p1 = al.allocate(1, 2, global_pool=1)
    assert set(p1) <= g1
    al.release(0)
    assert al.free_global(0) == 3


def test_table_row_order_preserved():
    pool = PoolConfig(page_size=4, n_local_pages=8, max_pages_per_seq=4)
    al = PageAllocator(pool)
    pages = al.allocate(7, 2)
    pages += [al.extend(7)]
    row = al.table_row(7)
    assert list(row[:3]) == pages


# ---------------------------------------------------------------- caches ---

def test_build_and_reset_paged_caches(rt):
    cfg = tiny("gemma3-12b")       # local + global kinds
    pool = PoolConfig(page_size=4, n_local_pages=8, n_global_pages=2,
                      max_pages_per_seq=4)
    caches = kvc.build_paged_caches(cfg, batch=3, pool=pool, rt=rt)
    kinds = [("k_pages" in c, "pos" in c) for c in caches["scan"]]
    assert (False, True) in kinds        # local ring present
    assert (True, False) in kinds        # paged pool present
    # reset slot 1: ring pos -> -1 there, untouched elsewhere
    for c in caches["scan"]:
        if "pos" in c:
            c["pos"] = c["pos"].at[:, 1].set(5)
            c["pos"] = c["pos"].at[:, 2].set(7)
    caches = kvc.reset_slot(caches, cfg, 1, rt)
    for c in caches["scan"]:
        if "pos" in c:
            assert bool(jnp.all(c["pos"][:, 1] == -1))
            assert bool(jnp.all(c["pos"][:, 2] == 7))


def test_set_page_table_broadcast(rt):
    cfg = tiny("yi-9b")
    pool = PoolConfig(page_size=4, n_local_pages=8, max_pages_per_seq=4)
    caches = kvc.build_paged_caches(cfg, batch=2, pool=pool, rt=rt)
    table = np.arange(8, dtype=np.int32).reshape(2, 4)
    caches = kvc.set_page_table(caches, table)
    for c in caches["scan"]:
        if "page_table" in c:
            assert c["page_table"].shape[0] == 2 or \
                c["page_table"].shape[1] == 2
            got = np.asarray(c["page_table"])
            assert (got[0] == table).all() if got.ndim == 3 else \
                (got == table).all()


# ---------------------------------------------------------------- engine ---

def _engine(rt, arch="yi-9b", n_mb=2, mb=2, offload=True, max_new=10):
    cfg = tiny(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=24,
                      n_global_pages=8 if offload else 0,
                      max_pages_per_seq=8)
    off = DoubleBufferOffloader(pool, n_mb) if offload else None
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new)
    return OfflineEngine(cfg, params, rt, mb_size=mb, num_microbatches=n_mb,
                         pool=pool, sampling=sp, offloader=off), cfg, params


def _requests(cfg, n, seed=0, lo=3, hi=12, max_new=10):
    rng = np.random.RandomState(seed)
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new)
    return [Request(i, list(rng.randint(1, cfg.vocab_size,
                                        rng.randint(lo, hi))), sp)
            for i in range(n)]


def test_engine_finishes_all_requests(rt):
    eng, cfg, _ = _engine(rt)
    reqs = _requests(cfg, 9)
    eng.submit(reqs)
    done = eng.run(max_steps=500)
    assert len(done) == 9
    for s in done:
        assert len(s.generated) == 10


def test_engine_matches_reference_greedy(rt):
    eng, cfg, params = _engine(rt, max_new=8)
    reqs = _requests(cfg, 5, seed=3, max_new=8)
    eng.submit(reqs)
    done = {s.request.request_id: s for s in eng.run(max_steps=400)}

    for rid in (0, 2, 4):
        prompt = reqs[rid].prompt
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, caches = M.prefill(params, {"tokens": toks}, cfg, rt, 128)
        ref = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(8):
            ref.append(int(tok[0]))
            logits, caches = M.decode_step(
                params, tok, caches,
                jnp.asarray([len(prompt) + i], jnp.int32), cfg, rt)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert done[rid].generated == ref, rid


def test_engine_eos_stops_early(rt):
    cfg = tiny("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    pool = PoolConfig(page_size=8, n_local_pages=24, max_pages_per_seq=8)
    # find what greedy emits first, then make that the eos token
    toks = jnp.asarray([[5, 6, 7]], jnp.int32)
    logits, _ = M.prefill(params, {"tokens": toks}, cfg, rt, 64)
    eos = int(jnp.argmax(logits, -1)[0])
    sp = SamplingParams(temperature=0.0, max_new_tokens=50, eos_token=eos)
    eng = OfflineEngine(cfg, params, rt, mb_size=1, num_microbatches=1,
                        pool=pool, sampling=sp)
    eng.submit([Request(0, [5, 6, 7], sp)])
    done = eng.run(max_steps=200)
    assert len(done) == 1
    assert done[0].generated[-1] == eos
    assert len(done[0].generated) < 50


def test_engine_slot_reuse_no_crosstalk(rt):
    """More requests than slots: recycled slots must produce the same
    output as a fresh engine run of the same request."""
    eng1, cfg, params = _engine(rt, n_mb=1, mb=1, offload=False, max_new=6)
    reqs = _requests(cfg, 4, seed=11, max_new=6)
    eng1.submit(reqs)
    serial = {s.request.request_id: s.generated
              for s in eng1.run(max_steps=400)}
    assert len(serial) == 4
    eng2, _, _ = _engine(rt, n_mb=2, mb=2, offload=True, max_new=6)
    eng2.submit(_requests(cfg, 4, seed=11, max_new=6))
    packed = {s.request.request_id: s.generated
              for s in eng2.run(max_steps=400)}
    assert serial == packed


def test_offloader_roundtrip_preserves_content(rt):
    cfg = tiny("yi-9b")
    pool = PoolConfig(page_size=4, n_local_pages=4, n_global_pages=3,
                      max_pages_per_seq=6)
    caches = kvc.build_paged_caches(cfg, batch=2, pool=pool, rt=rt)
    # write a signature into G0's slice for mb 0
    sl = kvc.global_slice(pool, 0)
    sig = 3.25
    caches["scan"] = [
        {**c, "k_pages": c["k_pages"].at[:, sl.start].set(sig)}
        if "k_pages" in c else c for c in caches["scan"]]
    off = DoubleBufferOffloader(pool, num_microbatches=4)
    caches = off.ensure_resident(caches, 0)        # adopt mb0 (no prior)
    caches = off.ensure_resident(caches, 2)        # swap mb0 out, mb2 in
    for c in caches["scan"]:
        if "k_pages" in c:
            assert not bool(jnp.any(c["k_pages"][:, sl.start] == sig))
    caches = off.ensure_resident(caches, 0)        # swap mb0 back in
    found = False
    for c in caches["scan"]:
        if "k_pages" in c:
            found = True
            assert bool(jnp.all(c["k_pages"][:, sl.start] == sig))
    assert found
    assert off.swap_count == 3
    assert off.bytes_swapped > 0


def test_offloader_async_matches_sync(rt):
    """async_swap stores the enqueued jax copy instead of a blocking
    numpy one — the pool contents after any swap sequence must be
    bit-identical between the two modes, and a swap-in must pop the
    host-store key (the strict auditor's staleness invariant)."""
    cfg = tiny("yi-9b")
    pool = PoolConfig(page_size=4, n_local_pages=4, n_global_pages=3,
                      max_pages_per_seq=6)

    def run(async_swap):
        caches = kvc.build_paged_caches(cfg, batch=2, pool=pool, rt=rt)
        sl = kvc.global_slice(pool, 0)
        caches["scan"] = [
            {**c, "k_pages": c["k_pages"].at[:, sl.start].set(1.5)}
            if "k_pages" in c else c for c in caches["scan"]]
        off = DoubleBufferOffloader(pool, 4, async_swap=async_swap)
        for mb in (0, 2, 0, 2, 0):
            caches = off.ensure_resident(caches, mb)
            assert mb not in off._host        # swap-in popped the key
        off.settle()
        return off, [np.asarray(c["k_pages"]) for part in ("scan", "tail")
                     for c in caches[part]
                     if isinstance(c, dict) and "k_pages" in c]

    off_a, pools_a = run(True)
    off_s, pools_s = run(False)
    assert off_a.swap_count == off_s.swap_count == 5
    assert off_a.bytes_swapped == off_s.bytes_swapped
    for pa, ps in zip(pools_a, pools_s):
        np.testing.assert_array_equal(pa, ps)


def test_sampler_modes():
    from repro.serving.sampler import sample
    logits = jnp.asarray([[1.0, 5.0, 2.0, 0.0]])
    key = jax.random.PRNGKey(0)
    assert int(sample(logits, key, SamplingParams(temperature=0.0))[0]) == 1
    # top-k=1 is greedy regardless of temperature
    sp = SamplingParams(temperature=2.0, top_k=1)
    assert int(sample(logits, key, sp)[0]) == 1
    # top-p very small keeps only the argmax
    sp = SamplingParams(temperature=1.0, top_p=1e-6)
    assert int(sample(logits, key, sp)[0]) == 1
    # plain temperature sampling hits every token eventually
    sp = SamplingParams(temperature=5.0)
    seen = {int(sample(logits, jax.random.PRNGKey(i), sp)[0])
            for i in range(60)}
    assert len(seen) >= 3


def test_offload_backend_gating(rt):
    """On CPU the pinned_host path degrades to device memory and the numpy
    store; the schedule/bookkeeping is identical either way (DESIGN §3)."""
    from repro.core import offload as OF
    assert not OF.host_memory_available()        # CPU container
    mesh = jax.make_mesh((1,), ("data",))
    sh = OF.pool_shardings(mesh, jax.sharding.PartitionSpec(), host=True)
    # degrades to the backend's default memory kind, not pinned_host
    assert sh.memory_kind in (None, jax.devices()[0].default_memory().kind)
    off = DoubleBufferOffloader(
        PoolConfig(page_size=4, n_local_pages=4, n_global_pages=2,
                   max_pages_per_seq=4), 2)
    assert OF.place_host_store(off, mesh, jax.sharding.PartitionSpec()) is off
