"""Config registry: every assigned arch present with the exact assigned
dimensions; derived quantities sane."""

import pytest

from conftest import ASSIGNED_ARCHS, tiny
from repro.config import SHAPES, get_arch, list_archs

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment table
ASSIGNMENT = {
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "yi-9b": (48, 4096, 32, 4, 11008, 64000),
    "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
    "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
    "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
    "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
}


def test_all_assigned_archs_registered():
    archs = list_archs()
    for a in ASSIGNED_ARCHS:
        assert a in archs
    assert "llama3-70b" in archs          # the paper's own model


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_assigned_dimensions(name):
    cfg = get_arch(name)
    L, D, H, Hk, F, V = ASSIGNMENT[name]
    assert cfg.num_layers == L
    assert cfg.d_model == D
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == Hk
    assert cfg.d_ff == F
    assert cfg.vocab_size == V


def test_moe_configs():
    q = get_arch("qwen3-moe-235b-a22b")
    assert q.moe.num_experts == 128 and q.moe.experts_per_token == 8
    p = get_arch("phi3.5-moe-42b-a6.6b")
    assert p.moe.num_experts == 16 and p.moe.experts_per_token == 2


def test_param_counts_in_family_range():
    # name encodes scale; param_count should land within ~35 %
    expect = {
        "yi-9b": 8.8e9, "gemma3-12b": 12e9, "minitron-4b": 4.2e9,
        "qwen3-moe-235b-a22b": 235e9, "phi3.5-moe-42b-a6.6b": 42e9,
        "xlstm-1.3b": 1.3e9, "gemma3-1b": 1.0e9, "llama3-70b": 70e9,
    }
    for name, n in expect.items():
        got = get_arch(name).param_count()
        assert 0.6 * n < got < 1.5 * n, (name, got, n)


def test_active_params_moe():
    q = get_arch("qwen3-moe-235b-a22b")
    assert q.active_param_count() < 0.15 * q.param_count()
    d = get_arch("yi-9b")
    assert d.active_param_count() == d.param_count()


def test_layer_kinds_pattern():
    g = get_arch("gemma3-12b")
    kinds = g.layer_kinds()
    assert len(kinds) == 48
    assert kinds[:6] == ("local",) * 5 + ("global",)
    r = get_arch("recurrentgemma-9b")
    assert r.layer_kinds()[:3] == ("rglru", "rglru", "local")
    assert r.recurrent_layer_count() == 26  # 38 layers, 2/3 recurrent + tail


def test_subquadratic_flags():
    # long_500k runs only for these
    assert get_arch("recurrentgemma-9b").is_subquadratic()
    assert get_arch("xlstm-1.3b").is_subquadratic()
    assert get_arch("gemma3-1b").is_subquadratic()
    assert get_arch("gemma3-12b").is_subquadratic()
    for name in ("yi-9b", "musicgen-large", "minitron-4b", "qwen2-vl-2b",
                 "qwen3-moe-235b-a22b", "phi3.5-moe-42b-a6.6b"):
        assert not get_arch(name).is_subquadratic(), name


def test_shapes_table():
    assert SHAPES["train_4k"].tokens_per_step == 4096 * 256
    assert SHAPES["decode_32k"].tokens_per_step == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["prefill_32k"].kind == "prefill"


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_reduced_config_keeps_family(name):
    cfg = get_arch(name)
    red = tiny(name)
    assert red.family == cfg.family
    assert red.block_pattern == cfg.block_pattern
    assert (red.moe is None) == (cfg.moe is None)
    assert red.frontend == cfg.frontend
    assert red.param_count() < 30e6


def test_paper_kv_cache_size_claim():
    """Paper §2.1: 'in the Llama 3 70B model, the KV cache for a sequence of
    length 4096 can occupy 1.25 GB' — our config computes 1.34 GB at bf16
    (the paper presumably rounds / excludes a couple of layers): within 10%."""
    cfg = get_arch("llama3-70b")
    gb = cfg.kv_bytes_per_token(2) * 4096 / (1 << 30)
    assert abs(gb - 1.25) / 1.25 < 0.10, gb
