"""End-to-end training driver (deliverable b): train a ~100M-class reduced
model for a few hundred steps on the synthetic pipeline with checkpointing.

    PYTHONPATH=src python examples/train_small.py [--steps 300]
"""

import argparse
import tempfile

import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.config import get_arch, reduced_config
from repro.data.pipeline import DataConfig, batches
from repro.models.common import Runtime
from repro.training import optimizer as O
from repro.training import train_loop as TL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced_config(get_arch(args.arch), num_layers=4,
                         d_model=args.d_model, vocab=512)
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    ocfg = O.AdamWConfig(lr=3e-3, warmup_steps=args.steps // 20,
                         total_steps=args.steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_size=args.batch)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) for "
          f"{args.steps} steps on the synthetic pipeline")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        params, opt_state, res = TL.train(
            cfg, rt, ocfg, batches(dcfg), steps=args.steps,
            checkpoint_mgr=mgr, checkpoint_every=100,
            log_every=max(10, args.steps // 10))
        print(f"\nloss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
              f"({res.tokens_per_second:.0f} tok/s)")
        print(f"checkpoints kept: {mgr.steps()}")
    assert res.losses[-1] < res.losses[0], "training failed to reduce loss"


if __name__ == "__main__":
    main()
