"""The full DeServe framework flow (paper Figure 1): task + GPU registries,
escrow payment, pipelined serving over simulated high-latency links, signed
results, and an arbitration round against a cheating miner.

    PYTHONPATH=src python examples/decentralized_market.py
"""

import numpy as np

from repro.core.scheduler import optimal_microbatches, plan_schedule
from repro.core.simulator import PipelineSimulator, SimConfig, calibrate
from repro.framework.arbitration import ArbitrationModule, SignedResult
from repro.framework.payment import PaymentModule
from repro.framework.registry import Registry


def main():
    reg, pay = Registry(), PaymentModule()
    arb = ArbitrationModule(pay)

    # --- miners register GPUs + stake; user registers a task + escrow ----
    keys = {}
    for i in range(8):
        miner = f"miner{i}"
        region = "us-west" if i < 5 else "us-east"
        pay.deposit(miner, 50.0)
        keys[miner] = arb.register_miner(miner, stake=30.0)
        reg.register_machine(miner, 24 << 30, region, stake=30.0)
    pay.deposit("alice", 200.0)
    task = reg.register_task("alice", "llama3-70b", 140 << 30,
                             n_requests=1000, max_price=0.9)
    arb.register_task_owner(task.task_id, "alice")
    escrow = pay.lock("alice", task.task_id, 120.0)

    # --- matching: pooled memory + minimal intra-pipeline latency --------
    match = reg.match(task.task_id)
    print(f"matched {match.n_stages} machines "
          f"({[m.miner for m in match.machines]}), "
          f"max link latency {match.max_latency*1000:.0f} ms")

    # --- schedule + simulate the serving run over those links ------------
    n_b = optimal_microbatches(match.n_stages, 0.08, match.max_latency)
    print(f"microbatch schedule: N_B* = {n_b}")
    scale = calibrate()
    res = PipelineSimulator(SimConfig(
        policy="deserve_opt", n_stages=match.n_stages,
        latency=match.max_latency, time_scale=scale,
        sim_seconds=200, warmup_seconds=50)).run()
    print(f"simulated throughput: {res.output_tps:.0f} tok/s "
          f"(N_B={res.n_microbatches}, {res.per_mb_batch} seqs/microbatch)")

    # --- delivery: signed results, payment released ----------------------
    outputs = list(np.random.RandomState(0).randint(0, 1000, 16))
    lead = match.machines[0].miner
    result = SignedResult.sign(task.task_id, 0, lead, outputs, keys[lead])
    assert result.verify_signature(keys[lead])
    pay.release(escrow.escrow_id, lead)
    reg.release(match)
    print(f"payment released: {lead} balance ${pay.balance(lead):.2f}")

    # --- a cheater gets slashed ------------------------------------------
    cheat = "miner7"
    wrong = [0] * 16
    bad = SignedResult.sign(task.task_id, 1, cheat, wrong, keys[cheat])
    d = arb.open_dispute("alice", bad, claimed_output=wrong,
                         reference_output=outputs)
    print(f"dispute against {cheat}: {d.outcome} "
          f"(alice recovered ${pay.balance('alice'):.2f})")


if __name__ == "__main__":
    main()
