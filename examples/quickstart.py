"""Quickstart: load an assigned architecture at CPU scale, serve a few
requests offline, inspect the DeServe schedule math.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, list_archs, reduced_config
from repro.core.cost_model import min_throughput
from repro.core.offload import DoubleBufferOffloader
from repro.core.scheduler import (optimal_microbatches, plan_schedule,
                                  schedule_diagram)
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams


def main():
    print("registered architectures:", ", ".join(list_archs()))

    # 1. a reduced-config model of an assigned arch (CPU-sized, same family)
    cfg = reduced_config(get_arch("yi-9b"))
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)
    print(f"\nmodel: {cfg.name}, {cfg.param_count()/1e6:.1f}M params")

    # 2. the DeServe serving engine: paged KV + double-buffer offload
    pool = PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                      max_pages_per_seq=8)
    sp = SamplingParams(temperature=0.0, max_new_tokens=12)
    engine = OfflineEngine(
        cfg, params, rt, mb_size=2, num_microbatches=2, pool=pool,
        sampling=sp, offloader=DoubleBufferOffloader(pool, 2))
    rng = np.random.RandomState(0)
    engine.submit([Request(i, list(rng.randint(1, cfg.vocab_size, 6)), sp)
                   for i in range(5)])
    done = engine.run()
    for s in done:
        print(f"  req {s.request.request_id}: prompt={s.request.prompt} "
              f"-> {s.generated}")
    print("engine report:", engine.throughput_report())

    # 3. the paper's schedule math for a real deployment
    n_b = optimal_microbatches(n_stages=8, stage_time=0.08, latency=0.064)
    choice = plan_schedule(n_stages=8, stage_time=0.08, latency=0.064,
                           m_kv_bytes=2e9, kv_bytes_per_seq=15.7e6,
                           offload_bandwidth=6e9)
    print(f"\n8 stages @ 80ms, 64ms links: N_B* = {n_b}; planner chose "
          f"{choice.n_microbatches} microbatches x {choice.per_mb_batch} "
          f"seqs (util {choice.utilisation:.0%})")
    print(f"mining-platform break-even: "
          f"{min_throughput(0.35):.0f} tok/s")

    # 4. paper Figure 2(c): the bubble-free circular schedule
    print("\npaper Figure 2(c) (4 stages, L = T_S/2):")
    print(schedule_diagram(4, 6, stage_time=1.0, latency=0.5, ticks=16))
    print("vs. the naive N_B = N_M schedule:")
    print(schedule_diagram(4, 4, stage_time=1.0, latency=0.5, ticks=16))


if __name__ == "__main__":
    main()
