"""Quickstart: load an assigned architecture at CPU scale, serve a few
requests offline through the ``LLM`` API, inspect the DeServe schedule math.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.config import list_archs
from repro.core.cost_model import min_throughput
from repro.core.scheduler import (optimal_microbatches, plan_schedule,
                                  schedule_diagram)
from repro.serving.kv_cache import PoolConfig
from repro.serving.llm import LLM, EngineConfig, SamplingParams


def main():
    print("registered architectures:", ", ".join(list_archs()))

    # 1. the LLM front end: a reduced-config model of an assigned arch
    #    (CPU-sized, same family) behind the DeServe serving engine —
    #    paged KV + double-buffer offload
    llm = LLM("yi-9b", config=EngineConfig(
        mb_size=2, num_microbatches=2,
        pool=PoolConfig(page_size=8, n_local_pages=32, n_global_pages=8,
                        max_pages_per_seq=8)))
    print(f"\nmodel: {llm.cfg.name}, {llm.cfg.param_count()/1e6:.1f}M params")

    # 2. generate: one greedy batch, then a sampled request on the side
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, llm.cfg.vocab_size, 6)) for _ in range(5)]
    outs = llm.generate(prompts, SamplingParams(temperature=0.0,
                                                max_new_tokens=12))
    for o in outs:
        print(f"  req {o.request_id}: prompt={o.prompt} -> {o.token_ids} "
              f"({o.finish_reason})")
    sampled = llm.generate([prompts[0]],
                           SamplingParams(temperature=0.9, top_p=0.95,
                                          max_new_tokens=12, logprobs=True))
    print(f"  sampled req {sampled[0].request_id}: {sampled[0].token_ids} "
          f"logprobs[0]={sampled[0].logprobs[0]:.2f}")
    print("engine report:", llm.stats())

    # 3. the paper's schedule math for a real deployment
    n_b = optimal_microbatches(n_stages=8, stage_time=0.08, latency=0.064)
    choice = plan_schedule(n_stages=8, stage_time=0.08, latency=0.064,
                           m_kv_bytes=2e9, kv_bytes_per_seq=15.7e6,
                           offload_bandwidth=6e9)
    print(f"\n8 stages @ 80ms, 64ms links: N_B* = {n_b}; planner chose "
          f"{choice.n_microbatches} microbatches x {choice.per_mb_batch} "
          f"seqs (util {choice.utilisation:.0%})")
    print(f"mining-platform break-even: "
          f"{min_throughput(0.35):.0f} tok/s")

    # 4. paper Figure 2(c): the bubble-free circular schedule
    print("\npaper Figure 2(c) (4 stages, L = T_S/2):")
    print(schedule_diagram(4, 6, stage_time=1.0, latency=0.5, ticks=16))
    print("vs. the naive N_B = N_M schedule:")
    print(schedule_diagram(4, 4, stage_time=1.0, latency=0.5, ticks=16))


if __name__ == "__main__":
    main()
