"""Networked serving end-to-end: registry match -> DeploymentPlan ->
per-link simulated WAN transport -> the real engine on a virtual clock.

The run demonstrates the paper's headline mechanics without any real
network: the same mixed greedy+sampled workload is served (1) over
zero-cost in-process links, (2) over the deployment's simulated WAN
links with the planner-chosen N_B circular schedule, and (3) over the
same links with the round-flush (vLLM-PP) baseline schedule — outputs
are bit-identical in all three, while the virtual clock shows the
circular schedule hiding the link latency and round-flush paying it
every token round.

The circular WAN run records a flight-recorder trace and exports it as
``networked_serving_trace.json`` — drop the file into
https://ui.perfetto.dev to see the schedule on both clocks (engine
phases on the wall clock, stage busy windows + link transfers + stalls
on the transport's virtual clock).

    PYTHONPATH=src python examples/networked_serving.py
"""

import numpy as np

from repro.config import get_arch, reduced_config
from repro.core.scheduler import optimal_microbatches
from repro.distributed.transport import (DeploymentPlan,
                                         SimulatedLinkTransport)
from repro.framework.registry import Registry
from repro.obs.timeline import write_chrome_trace
from repro.serving.kv_cache import PoolConfig
from repro.serving.llm import LLM, EngineConfig, SamplingParams


def main():
    # --- a fleet registers; the registry builds the latency-minimising
    # pipeline; its match output IS the deployment plan -----------------
    reg = Registry()
    for i in range(2):
        reg.register_machine(f"west{i}", 24 << 30, "us-west", stake=30.0)
    reg.register_machine("east0", 24 << 30, "us-east", stake=30.0)
    task = reg.register_task("alice", "yi-9b", 55 << 30,
                             n_requests=64, max_price=0.9)
    match = reg.match(task.task_id)
    plan = DeploymentPlan.from_match(match)
    print(plan.describe())

    # --- the engine: reduced config, single host — the deployment's
    # links are simulated on a virtual clock, so this runs anywhere -----
    cfg = reduced_config(get_arch("yi-9b"))
    pool = PoolConfig(page_size=8, n_local_pages=64, n_global_pages=0,
                      max_pages_per_seq=4)
    T = 0.016                                   # virtual stage seconds
    L = plan.max_link_latency
    n_star = optimal_microbatches(1, T, L)      # 1-stage pipe on this host
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab_size, 6))
               for _ in range(n_star)]
    sps = [SamplingParams(temperature=0.0 if i % 2 == 0 else 0.8,
                          max_new_tokens=12) for i in range(n_star)]

    def serve(label, n_b, schedule, transport, wire_dtype="fp32",
              trace=False):
        llm = LLM(cfg, config=EngineConfig(
            backend="pipelined", n_stages=1, mb_size=1,
            num_microbatches=n_b, pool=pool, offload=False,
            transport=transport, schedule=schedule, prefill_chunk=8,
            wire_dtype=wire_dtype, trace=trace))
        outs = llm.generate(prompts, sps)
        rep = llm.stats()
        vtps = rep.get("virtual_decode_tok_per_s")
        print(f"  {label:22s} N_B={n_b:2d} "
              + (f"{vtps:7.1f} tok/s on the virtual clock"
                 if vtps else "   (no clock: in-process links)"))
        if trace:
            t = write_chrome_trace(llm.engine.recorder,
                                   "networked_serving_trace.json")
            print(f"  ^ timeline: {len(t['traceEvents'])} events -> "
                  "networked_serving_trace.json "
                  "(open in https://ui.perfetto.dev)")
        return [tuple(o.token_ids) for o in outs], vtps

    print(f"\nserving over max link {L * 1000:.0f}ms "
          f"(virtual T_S={T * 1000:.0f}ms):")
    base, _ = serve("in-process", n_star, "circular", None)
    links = lambda: SimulatedLinkTransport.uniform(1, L, stage_time_s=T)
    circ, v_c = serve("simulated circular", n_star, "circular", links(),
                      trace=True)
    rf, v_rf = serve("simulated round-flush", 1, "round_flush", links())

    assert circ == base and rf == base, "transports must not change tokens"
    print(f"\noutputs bit-identical across all three runs; "
          f"circular hides the WAN: {v_c / v_rf:.1f}x round-flush")

    # --- the int8 wire codec: same circular schedule, but every ppermute
    # payload crosses the links packed (1 byte/element + a per-row scale).
    # Quantization perturbs logits, so tokens may drift off the fp32 run —
    # report agreement instead of asserting equality; the wire-byte win
    # shows up on multi-stage pipes (latency_curve benchmark with a
    # bandwidth cap, and the 2-stage SPMD tests).
    q, _ = serve("simulated circular int8", n_star, "circular", links(),
                 wire_dtype="int8")
    agree = np.mean([a == b for a, b in zip(q, base)])
    print(f"int8 wire codec: {agree * 100:.0f}% of streams identical to "
          f"fp32 on this reduced model (4x fewer payload bytes per link)")
    reg.release(match)


if __name__ == "__main__":
    main()
