"""End-to-end offline serving driver (deliverable b): serve a batched
request workload through the full DeServe stack and account profitability.

This is the paper's §5 workload shrunk to CPU: random prompt/generation
lengths, replenish-on-finish, stats over the run.  Swap --arch for any of
the 11 registered architectures.

    PYTHONPATH=src python examples/offline_serving.py [--arch gemma3-1b]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced_config
from repro.core.cost_model import PLATFORMS, profit_per_hour
from repro.core.offload import DoubleBufferOffloader
from repro.models import model as M
from repro.models.common import Runtime
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduced_config(get_arch(args.arch))
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)

    pool = PoolConfig(page_size=8, n_local_pages=48, n_global_pages=12,
                      max_pages_per_seq=8)
    sp = SamplingParams(temperature=args.temperature, top_p=0.95,
                        max_new_tokens=args.max_new)
    engine = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=3,
                           pool=pool, sampling=sp,
                           offloader=DoubleBufferOffloader(pool, 3))

    rng = np.random.RandomState(1)
    reqs = [Request(i, list(rng.randint(1, cfg.vocab_size,
                                        rng.randint(4, 20))), sp)
            for i in range(args.requests)]
    engine.submit(reqs)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0

    rep = engine.throughput_report()
    tps = rep["total_tokens"] / dt
    print(f"{cfg.name}: served {rep['finished']} requests, "
          f"{rep['total_tokens']} tokens in {dt:.1f}s ({tps:.1f} tok/s on "
          f"this CPU host)")
    print(f"offload swaps: {rep['swaps']}")
    print("\nif this were an 8x4090 mining-rate pipeline at 450 tok/s:")
    for name in ("mining", "ionet", "cloud"):
        print(f"  {name:8s} profit/hour "
              f"${profit_per_hour(450, PLATFORMS[name].cost_per_hour):+7.2f}")


if __name__ == "__main__":
    main()
