"""End-to-end offline serving driver (deliverable b): serve a batched
request workload through the full DeServe stack and account profitability.

This is the paper's §5 workload shrunk to CPU: random prompt/generation
lengths, replenish-on-finish, stats over the run.  Swap --arch for any of
the 11 registered architectures; swap --backend to run the same engine
through the SPMD pipeline (the pod axis is emulated with host devices).

    PYTHONPATH=src python examples/offline_serving.py [--arch gemma3-1b]
        [--backend pipelined --stages 2]
"""

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--backend", default="local",
                    choices=["local", "pipelined"])
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stages for --backend pipelined (the "
                         "reduced archs fit 1-2 stages)")
    args = ap.parse_args()

    if args.backend == "pipelined":
        from repro.launch.serve import _ensure_host_devices
        _ensure_host_devices(args.stages)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_arch, reduced_config
    from repro.core.cost_model import PLATFORMS, profit_per_hour
    from repro.core.offload import DoubleBufferOffloader
    from repro.models import model as M
    from repro.models.common import Runtime
    from repro.serving.engine import OfflineEngine
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.request import Request, SamplingParams

    cfg = reduced_config(get_arch(args.arch))
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0), rt)

    pool = PoolConfig(page_size=8, n_local_pages=48, n_global_pages=12,
                      max_pages_per_seq=8)
    sp = SamplingParams(temperature=args.temperature, top_p=0.95,
                        max_new_tokens=args.max_new)
    engine = OfflineEngine(cfg, params, rt, mb_size=2, num_microbatches=3,
                           pool=pool, sampling=sp,
                           offloader=DoubleBufferOffloader(pool, 3),
                           backend=args.backend, n_stages=args.stages)

    rng = np.random.RandomState(1)
    reqs = [Request(i, list(rng.randint(1, cfg.vocab_size,
                                        rng.randint(4, 20))), sp)
            for i in range(args.requests)]
    engine.submit(reqs)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0

    rep = engine.throughput_report()
    tps = rep["total_tokens"] / dt
    print(f"{cfg.name} [{rep['backend']}]: served {rep['finished']} "
          f"requests, {rep['total_tokens']} tokens in {dt:.1f}s "
          f"({tps:.1f} tok/s on this CPU host)")
    print(f"offload swaps: {rep['swaps']}")
    print("\nif this were an 8x4090 mining-rate pipeline at 450 tok/s:")
    for name in ("mining", "ionet", "cloud"):
        print(f"  {name:8s} profit/hour "
              f"${profit_per_hour(450, PLATFORMS[name].cost_per_hour):+7.2f}")


if __name__ == "__main__":
    main()
