"""End-to-end offline serving driver (deliverable b): serve a batched
request workload through the full DeServe stack via the ``LLM`` API and
account profitability.

This is the paper's §5 workload shrunk to CPU: random prompt/generation
lengths, replenish-on-finish, stats over the run — with a *mixed* sampling
workload: greedy, temperature, top-k, and top-p requests all ride the same
continuously-batched pipe, each honoring its own ``SamplingParams``.  Swap
--arch for any of the 11 registered architectures; swap --backend to run
the same engine through the SPMD pipeline (the pod axis is emulated with
host devices).

    PYTHONPATH=src python examples/offline_serving.py [--arch gemma3-1b]
        [--backend pipelined --stages 2]
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--backend", default="local",
                    choices=["local", "pipelined"])
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stages for --backend pipelined (the "
                         "reduced archs fit 1-2 stages)")
    args = ap.parse_args()

    if args.backend == "pipelined":
        from repro.launch.serve import _ensure_host_devices
        _ensure_host_devices(args.stages)

    import numpy as np

    from repro.core.cost_model import PLATFORMS, profit_per_hour
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.llm import LLM, EngineConfig, SamplingParams

    llm = LLM(args.arch, config=EngineConfig(
        mb_size=2,
        num_microbatches=max(3, args.stages),
        pool=PoolConfig(page_size=8, n_local_pages=48, n_global_pages=12,
                        max_pages_per_seq=8),
        offload=True, backend=args.backend, n_stages=args.stages))

    rng = np.random.RandomState(1)
    prompts = [list(rng.randint(1, llm.cfg.vocab_size, rng.randint(4, 20)))
               for _ in range(args.requests)]
    # one engine, four sampling policies — each request keeps its own
    policies = [
        SamplingParams(temperature=0.0, max_new_tokens=args.max_new),
        SamplingParams(temperature=0.8, top_p=0.95,
                       max_new_tokens=args.max_new, logprobs=True),
        SamplingParams(temperature=1.0, top_k=16,
                       max_new_tokens=args.max_new),
        SamplingParams(temperature=0.9, top_p=0.9, top_k=32,
                       max_new_tokens=args.max_new),
    ]
    sps = [policies[i % len(policies)] for i in range(args.requests)]

    outs = llm.generate(prompts, sps)
    for o in outs[:4]:
        lp = (f" mean_lp={np.mean(o.logprobs):.2f}"
              if o.logprobs else "")
        print(f"  req {o.request_id}: {len(o.token_ids)} toks, "
              f"finish={o.finish_reason}{lp}")

    rep = llm.stats()
    print(f"{llm.cfg.name} [{rep['backend']}]: served {rep['finished']} "
          f"requests, {rep['total_tokens']} tokens in "
          f"{rep['wall_time_s']:.1f}s ({rep['decode_tok_per_s']:.1f} decode "
          f"tok/s on this CPU host; mean latency "
          f"{rep['mean_latency_steps']:.0f} steps)")
    print(f"offload swaps: {rep['swaps']}")
    print("\nif this were an 8x4090 mining-rate pipeline at 450 tok/s:")
    for name in ("mining", "ionet", "cloud"):
        print(f"  {name:8s} profit/hour "
              f"${profit_per_hour(450, PLATFORMS[name].cost_per_hour):+7.2f}")


if __name__ == "__main__":
    main()
