"""Qwen3-MoE-235B-A22B — 128-expert top-8 MoE decoder with GQA + qk-norm.

[hf:Qwen/Qwen3-30B-A3B; hf]  d_ff=1536 is the per-expert FFN width.
"""
from repro.config import ModelConfig, MoEConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                 # per-expert
    vocab_size=151936,
    block_pattern=("attn",),
    moe=MoEConfig(num_experts=128, experts_per_token=8, d_expert=1536,
                  capacity_factor=1.25, normalize_router_weights=True),
    rope_theta=1000000.0,
    use_qk_norm=True,
    max_position_embeddings=40960,
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
))
