"""Llama-3-70B — the paper's own serving target (DeServe §2, Table 4).

[arXiv:2407.21783; hf]  Used by the paper-reproduction benchmarks (cost
model, batch-size curve, throughput-vs-latency) and as an 11th selectable
arch.
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="llama3-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn",),
    rope_theta=500000.0,
    max_position_embeddings=8192,
    source="[arXiv:2407.21783; hf]",
))
