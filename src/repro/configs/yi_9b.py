"""Yi-9B — llama-architecture dense decoder with GQA.

[arXiv:2403.04652; hf]
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    block_pattern=("attn",),
    rope_theta=5000000.0,
    max_position_embeddings=4096,
    source="[arXiv:2403.04652; hf]",
))
