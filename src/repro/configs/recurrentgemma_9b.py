"""RecurrentGemma-9B — Griffin architecture: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; unverified]  Pattern period is (rglru, rglru, local): two
gated linear-recurrence blocks followed by one sliding-window attention block.
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,           # MQA on the local-attention layers
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    rope_theta=10000.0,
    norm_eps=1e-6,
    tie_embeddings=True,      # gemma family ties embeddings
    scale_embeddings=True,
    logit_softcap=30.0,
    d_rnn=4096,
    conv_width=4,
    max_position_embeddings=8192,
    source="[arXiv:2402.19427; unverified]",
))
