"""Gemma3-12B — dense decoder, 5:1 local:global attention, 128k-class context.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    block_pattern=("local",) * 5 + ("global",),
    window_size=1024,
    rope_theta=1000000.0,
    tie_embeddings=True,
    scale_embeddings=True,
    use_qk_norm=True,
    max_position_embeddings=131072,
    source="[hf:google/gemma-3-1b-pt; unverified]",
))
