"""MusicGen-Large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  The audio frontend (EnCodec codebook interleaving)
is a stub: ``input_specs()`` supplies precomputed frame embeddings.
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,          # MHA (GQA kv=32)
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,          # EnCodec codebook size
    block_pattern=("attn",),
    rope_theta=10000.0,
    frontend="audio_frames",
    tie_embeddings=False,
    max_position_embeddings=32768,
    source="[arXiv:2306.05284; hf]",
))
