"""Qwen2-VL-2B — VLM backbone with M-RoPE; dynamic-resolution vision stubbed.

[arXiv:2409.12191; hf]  ``input_specs()`` provides precomputed patch
embeddings; the backbone prepends them to the text token stream and applies
M-RoPE (temporal/height/width position components).
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    block_pattern=("attn",),
    rope_theta=1000000.0,
    frontend="vision_patches",
    num_patch_tokens=256,      # one 16x16 grid of merged patches per request
    tie_embeddings=True,
    max_position_embeddings=32768,
    source="[arXiv:2409.12191; hf]",
))
