"""Gemma3-1B — dense decoder, 5:1 local:global attention, 128k-class context.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    block_pattern=("local",) * 5 + ("global",),
    window_size=512,
    rope_theta=1000000.0,      # global layers; local layers use 10k (handled in model)
    tie_embeddings=True,
    scale_embeddings=True,
    logit_softcap=0.0,
    use_qk_norm=True,
    max_position_embeddings=131072,
    source="[hf:google/gemma-3-1b-pt; unverified]",
))
