"""Minitron-4B — width/depth-pruned Nemotron dense decoder.

[arXiv:2407.14679; hf]
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    block_pattern=("attn",),
    rope_theta=10000.0,
    max_position_embeddings=4096,
    source="[arXiv:2407.14679; hf]",
))
