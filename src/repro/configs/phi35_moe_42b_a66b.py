"""Phi-3.5-MoE-42B-A6.6B — 16-expert top-2 MoE decoder.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.config import ModelConfig, MoEConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,                 # per-expert
    vocab_size=32064,
    block_pattern=("attn",),
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_expert=6400,
                  capacity_factor=1.25, normalize_router_weights=False),
    rope_theta=10000.0,
    max_position_embeddings=131072,
    source="[hf:microsoft/Phi-3.5-MoE-instruct; hf]",
))
