"""xLSTM-1.3B — sLSTM + mLSTM recurrent blocks (7:1 mLSTM:sLSTM).

[arXiv:2405.04517; unverified]  d_ff=0: xLSTM blocks embed their own
projections; there is no separate FFN sub-block.
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    d_rnn=2560,                # 1.25x in-block expansion: lands the
                               # total at the 1.3B name scale
    tie_embeddings=False,
    max_position_embeddings=1 << 20,
    source="[arXiv:2405.04517; unverified]",
))
