"""Atomic, resumable checkpointing for arbitrary pytrees.

Layout:  <root>/step_<N>/  with one ``.npy`` per leaf (path-mangled names)
plus ``manifest.json`` (treedef + shapes/dtypes + user metadata).  Writes go
to ``step_<N>.tmp`` and are renamed only after fsync — a crash mid-save
never corrupts the latest checkpoint, which is the restart contract the
fault-tolerance layer (``repro.distributed.elastic``) relies on.

Multi-host note: each process saves only its addressable shards and the
manifest records the (process, shard) mapping; on this single-process
container that degenerates to full arrays, but the API (``save``/``restore``
/ ``latest_step`` / retention) is the production one.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    if hasattr(p, "name"):
        return f"n:{p.name}"
    return str(p)


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # -- save -----------------------------------------------------------

    def save(self, step: int, tree, metadata: Optional[dict] = None) -> str:
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "metadata": metadata or {},
        }
        for k, v in flat.items():
            np.save(os.path.join(tmp, k + ".npy"), v)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._retain()
        return final

    # -- restore --------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.root, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: Optional[int] = None):
        """Restore into the structure of ``template`` (shapes validated)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in paths:
            key = _SEP.join(_path_str(p) for p in path)
            arr = np.load(os.path.join(d, key + ".npy"))
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {key}: shape {arr.shape} != {want}")
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves), \
            manifest["metadata"]

    # -- retention ------------------------------------------------------

    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)
