"""Training step and loop: grad accumulation, remat, optional gradient
compression for high-latency data parallelism (beyond-paper: DeServe is an
inference paper, but its decentralized substrate wants cheap DP training —
see ``repro.distributed.compression``)."""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import model as model_lib
from repro.models.common import Runtime
from repro.training import optimizer as opt_lib


def make_train_step(cfg: ModelConfig, rt: Runtime, ocfg: opt_lib.AdamWConfig,
                    *, accum_steps: int = 1, compressor=None) -> Callable:
    """Build the jit-able train step.

    batch leaves carry a leading accumulation axis when accum_steps > 1:
    tokens (A, B, S) etc.  ``compressor`` (optional) is applied to the
    gradients before the optimizer — its decompressed output is what the
    optimizer consumes (error feedback lives inside the compressor).
    """

    def loss_fn(params, microbatch):
        return model_lib.train_loss(params, microbatch, cfg, rt)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def acc_body(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0), zeros), batch)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        if compressor is not None:
            grads = compressor.roundtrip(grads)
        params, opt_state, metrics = opt_lib.apply(ocfg, params, grads,
                                                   opt_state)
        metrics = {"loss": loss, **metrics}
        return params, opt_state, metrics

    return train_step


@dataclass
class TrainResult:
    losses: list
    steps: int
    tokens: int
    seconds: float

    @property
    def tokens_per_second(self) -> float:
        return self.tokens / max(self.seconds, 1e-9)


def train(cfg: ModelConfig, rt: Runtime, ocfg: opt_lib.AdamWConfig,
          data_iter, *, steps: int, params=None, opt_state=None,
          accum_steps: int = 1, compressor=None, donate: bool = True,
          checkpoint_mgr=None, checkpoint_every: int = 0,
          log_every: int = 0) -> tuple:
    """Run the training loop on the current default device/mesh.

    Returns (params, opt_state, TrainResult)."""
    if params is None:
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0), rt)
    if opt_state is None:
        opt_state = opt_lib.init(ocfg, params)
    step_fn = make_train_step(cfg, rt, ocfg, accum_steps=accum_steps,
                              compressor=compressor)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())

    losses = []
    tokens = 0
    t0 = time.perf_counter()
    start = int(opt_state.step)
    for i in range(start, start + steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        tokens += int(batch["tokens"].size) if "tokens" in batch else 0
        if log_every and (i + 1) % log_every == 0:
            print(f"step {i+1}: loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
        if checkpoint_mgr is not None and checkpoint_every and \
                (i + 1) % checkpoint_every == 0:
            checkpoint_mgr.save(i + 1, {"params": params,
                                        "opt_state": opt_state})
    dt = time.perf_counter() - t0
    return params, opt_state, TrainResult(losses=losses, steps=steps,
                                          tokens=tokens, seconds=dt)
