"""AdamW + schedules, from scratch (no optax), pytree-native.

Optimizer state dtype is configurable: fp32 moments by default, bf16 moments
for memory-tight dry-runs of the largest archs (recorded per-experiment).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0            # global-norm clip; 0 disables
    moment_dtype: Any = jnp.float32
    schedule: str = "cosine"          # cosine | constant | linear
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array                   # int32 scalar
    m: Any                            # pytree like params
    v: Any


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step_f / jnp.maximum(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((step_f - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0., 1.)
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    else:                             # cosine
        frac = jnp.clip((step_f - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0., 1.)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def init(cfg: AdamWConfig, params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply(cfg: AdamWConfig, params, grads,
          state: AdamWState) -> tuple:
    """One AdamW update.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return (pf.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                v_new.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_m, new_v), {
        "lr": lr, "grad_norm": gnorm}
