"""Request/sequence bookkeeping for the offline serving engine."""

from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import List, Optional


class Status(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


@dataclass
class SamplingParams:
    temperature: float = 0.0          # 0 = greedy
    top_k: int = 0                    # 0 = no top-k
    top_p: float = 1.0
    max_new_tokens: int = 64
    eos_token: int = -1               # -1 = never terminate early


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # modality payloads for stub frontends (precomputed embeddings)
    frames: Optional[object] = None
    patches: Optional[object] = None


@dataclass
class SequenceState:
    request: Request
    status: Status = Status.QUEUED
    slot: int = -1                    # decode-batch slot, -1 = unassigned
    generated: List[int] = field(default_factory=list)
    budget: Optional[int] = None      # engine-side cap (page capacity)

    @property
    def prompt_len(self) -> int:
        return len(self.request.prompt)

    @property
    def total_len(self) -> int:
        return self.prompt_len + len(self.generated)

    def is_done(self) -> bool:
        sp = self.request.sampling
        cap = sp.max_new_tokens if self.budget is None else \
            min(sp.max_new_tokens, self.budget)
        if len(self.generated) >= cap:
            return True
        return bool(self.generated) and self.generated[-1] == sp.eos_token


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    finished_requests: int = 0
    steps: int = 0
    swaps: int = 0                    # page-pool swap events (offload manager)

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens
