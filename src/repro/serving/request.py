"""Request/sequence bookkeeping for the offline serving engine."""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class Status(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


class FinishReason(enum.Enum):
    EOS = "eos"                       # emitted the request's eos token
    LENGTH = "length"                 # hit sampling.max_new_tokens
    PAGE_BUDGET = "page_budget"       # hit the per-sequence page capacity


@dataclass
class SamplingParams:
    temperature: float = 0.0          # 0 = greedy
    top_k: int = 0                    # 0 = no top-k
    top_p: float = 1.0
    max_new_tokens: int = 64
    eos_token: int = -1               # -1 = never terminate early
    logprobs: bool = False            # record per-token logprobs

    def validate(self) -> "SamplingParams":
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {self.max_new_tokens}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        return self


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    # None = use the engine's default_sampling (resolved at submit());
    # explicit params are honored exactly, per request
    sampling: Optional[SamplingParams] = None
    # modality payloads for stub frontends (precomputed embeddings)
    frames: Optional[object] = None
    patches: Optional[object] = None


@dataclass
class SequenceState:
    request: Request
    # the request's effective SamplingParams, resolved at submit() onto a
    # private copy — the caller's Request object is never written back
    # (``request.sampling`` may legitimately stay None)
    sampling: Optional[SamplingParams] = None
    status: Status = Status.QUEUED
    slot: int = -1                    # decode-batch slot, -1 = unassigned
    generated: List[int] = field(default_factory=list)
    budget: Optional[int] = None      # engine-side cap (page capacity)
    logprobs: Optional[List[float]] = None    # per generated token, if asked
    # chunked prefill: prompt tokens already written into the KV cache and
    # whether a chunk for this sequence is currently in the prefill pipe
    prefill_pos: int = 0
    chunk_inflight: bool = False
    global_parity: Optional[int] = None       # global-pool parity of the
                                              # slot's pages (None=all-local)
    # lifecycle accounting (engine steps + wall clock at submit/finish;
    # first_token_time stamps the engine-side TTFT mark — the moment the
    # first token was sampled, not when a consumer observed it)
    submit_step: int = -1
    finish_step: int = -1
    submit_time: float = 0.0
    finish_time: float = 0.0
    first_token_time: float = 0.0

    def __post_init__(self) -> None:
        if self.sampling is None:
            self.sampling = self.request.sampling

    @property
    def prompt_len(self) -> int:
        return len(self.request.prompt)

    @property
    def total_len(self) -> int:
        return self.prompt_len + len(self.generated)

    def _cap(self) -> int:
        sp = self.sampling
        return sp.max_new_tokens if self.budget is None else \
            min(sp.max_new_tokens, self.budget)

    def is_done(self) -> bool:
        if len(self.generated) >= self._cap():
            return True
        return bool(self.generated) and \
            self.generated[-1] == self.sampling.eos_token

    def finish_reason(self) -> Optional[FinishReason]:
        """Why the sequence stopped (None while still in flight)."""
        if not self.is_done():
            return None
        sp = self.sampling
        if self.generated and self.generated[-1] == sp.eos_token:
            return FinishReason.EOS
        if self.budget is not None and self.budget < sp.max_new_tokens \
                and len(self.generated) >= self.budget:
            return FinishReason.PAGE_BUDGET
        return FinishReason.LENGTH

    @property
    def latency_steps(self) -> Optional[int]:
        if self.finish_step < 0 or self.submit_step < 0:
            return None
        return self.finish_step - self.submit_step

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_step < 0 or self.submit_step < 0:
            return None
        return self.finish_time - self.submit_time

    @property
    def ttft_s(self) -> Optional[float]:
        """Engine-side time-to-first-token (None until sampled)."""
        if self.first_token_time <= 0.0 or self.submit_time <= 0.0:
            return None
        return self.first_token_time - self.submit_time


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    finished_requests: int = 0
    steps: int = 0
    swaps: int = 0                    # page-pool swap events (offload manager)
    wall_time_s: float = 0.0          # accumulated inside step()
    # wall_time_s split by phase so prefill changes are measurable without
    # confounding decode throughput: prefill covers admission + chunk/exact
    # prefill work, decode covers the microbatch tick (+ reap)
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    queue_depth: int = 0              # requests waiting (refreshed per step)
    status_counts: Dict[str, int] = field(default_factory=dict)
                                      # a MIRROR of engine.status_counts(),
                                      # which always writes it back —
                                      # throughput_report() and metrics
                                      # snapshots therefore can never read
                                      # a stale copy (it is not updated
                                      # per tick; read via the engine)
    aborted: bool = False             # run() exhausted max_steps with
                                      # work still pending
    # fault tolerance / elasticity (see engine._apply_result /
    # _apply_prefill_result / reshard)
    decode_ticks_lost: int = 0        # dropped decode ticks (re-injected)
    prefill_chunks_lost: int = 0      # dropped prefill chunks (re-emitted)
    reshards: int = 0                 # mid-run backend rebuilds
    # prefix caching: admissions that adopted shared prompt blocks, and
    # the prompt tokens those blocks covered (never re-prefilled —
    # prefill_tokens counts only actually-computed tokens)
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_tokens / self.decode_time_s if self.decode_time_s \
            else 0.0

    @property
    def prefill_tok_per_s(self) -> float:
        return self.prefill_tokens / self.prefill_time_s \
            if self.prefill_time_s else 0.0
