"""Token sampling: greedy / temperature / top-k / top-p, jit-friendly.

Two entry points:

``sample(logits, key, params)``
    Static dispatch on one :class:`SamplingParams` — the whole batch shares
    one policy.  Kept for reference decoding and tests.

``sample_batched(logits, keys, temp, top_k, top_p)``
    Per-row policy with *traced* parameters: every row carries its own
    temperature / top-k / top-p and its own PRNG key, so one compiled decode
    program serves a microbatch mixing greedy and sampled requests.  Top-k
    and top-p are mask-based (no gather/scatter of dynamic extent), so all
    shapes stay static.  Row semantics:

      - ``temp[i] <= 0``  → greedy (bit-identical to ``argmax`` on the raw
        logits — a greedy row in a mixed batch equals an all-greedy run).
      - ``top_k[i] <= 0`` → no top-k truncation.
      - ``top_p[i] >= 1`` → no nucleus truncation.
      - ties at the top-k / top-p cutoff are *kept* (same semantics as the
        static path: the mask is ``logits < cutoff``).

Per-slot keys are derived as ``fold_in(fold_in(PRNGKey(seed), request_id),
token_index)`` — a function of (seed, request, position) only, so sampled
outputs are reproducible across backends, microbatch layout, and admission
order.  :func:`fold_in_steps` performs the last fold inside the jit.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.request import SamplingParams


@dataclass
class RowSampling:
    """Per-row sampling state for one microbatch tick (host-side numpy;
    the engine slices these out of its per-slot arrays, the backend feeds
    them to the decode jit)."""
    keys: np.ndarray                  # (mb, 2) uint32 per-request base keys
    steps: np.ndarray                 # (mb,) int32 token index being sampled
    temp: np.ndarray                  # (mb,) float32
    top_k: np.ndarray                 # (mb,) int32
    top_p: np.ndarray                 # (mb,) float32

    @classmethod
    def zeros(cls, n: int) -> "RowSampling":
        return cls(keys=np.zeros((n, 2), np.uint32),
                   steps=np.zeros((n,), np.int32),
                   temp=np.zeros((n,), np.float32),
                   top_k=np.zeros((n,), np.int32),
                   top_p=np.ones((n,), np.float32))


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _apply_top_k(logits: jax.Array, k: int) -> jax.Array:
    vals, _ = jax.lax.top_k(logits, k)
    cutoff = vals[..., -1:]
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def _apply_top_p(logits: jax.Array, p: float) -> jax.Array:
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the smallest prefix with cumulative prob >= p (always >= 1 token)
    keep = cum - probs < p
    cutoff = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                     keepdims=True)
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def sample(logits: jax.Array, key: jax.Array,
           params: SamplingParams) -> jax.Array:
    """Sample next tokens.  Static dispatch on ``params``."""
    if params.temperature <= 0.0:
        return greedy(logits)
    logits = logits / params.temperature
    if params.top_k > 0:
        logits = _apply_top_k(logits, params.top_k)
    if params.top_p < 1.0:
        logits = _apply_top_p(logits, params.top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Per-row (device-side) sampling
# ---------------------------------------------------------------------------


def fold_in_steps(keys: jax.Array, steps: jax.Array) -> jax.Array:
    """Per-row ``fold_in``: ``keys`` (B, 2) uint32 per-request base keys,
    ``steps`` (B,) int32 token indices → (B, 2) per-token keys."""
    return jax.vmap(jax.random.fold_in)(keys, steps)


# Static cap on the fast path's partition width: one ``lax.top_k`` over
# ``min(_FAST_K_CAP, V-1) + 1`` values replaces the full-vocab sort when
# every sampled row's requested top-k fits under the cap (and no tie
# spills past it — see ``sample_batched``).
_FAST_K_CAP = 128


def sample_batched(logits: jax.Array, keys: jax.Array, temp: jax.Array,
                   top_k: jax.Array, top_p: jax.Array, *,
                   fast_path: bool = True) -> jax.Array:
    """Sample one token per row under per-row params (all traced).

    logits (B, V) fp32; keys (B, 2) uint32; temp/top_p (B,) fp32;
    top_k (B,) int32.  Returns (B,) int32 tokens.

    The sampled path is under a ``lax.cond`` on "any row non-greedy", so
    all-greedy ticks pay only the argmax.

    ``fast_path`` (static) enables the top-k partition + sort-of-k fast
    path: when every non-greedy row requests ``0 < top_k <= K`` (K =
    ``min(_FAST_K_CAP, V-1)``) and no row's top-k tie spills past K, one
    ``lax.top_k(x, K+1)`` replaces the ``[B, V]`` descending sort.  The
    kth-value cutoffs and the reconstructed sorted array are *bitwise*
    what the sort-based path produces (the K kept values padded with
    ``-inf`` — same ``[B, V]`` shape, so the shared softmax/cumsum
    nucleus pass rounds identically), and the ``(seed, request_id,
    token_idx)`` key discipline is untouched — outputs stay bit-identical
    either way.  Rows that don't qualify fall back to the sort in-jit
    (``lax.cond``), so enabling the fast path never changes results.
    """
    B, V = logits.shape
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    is_greedy = temp <= 0.0
    k_cap = min(_FAST_K_CAP, V - 1)

    def _nucleus_and_draw(x, sorted_desc):
        # top-p: keep the smallest prefix with cumulative prob >= p
        # (always >= 1 token)
        probs = jax.nn.softmax(sorted_desc, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep = cum - probs < top_p[:, None]
        cutoff = jnp.min(jnp.where(keep, sorted_desc, jnp.inf), axis=-1,
                         keepdims=True)
        x = jnp.where(x < cutoff, -jnp.inf, x)
        return jax.vmap(
            lambda l, k: jax.random.categorical(k, l, axis=-1))(
                x, keys).astype(jnp.int32)

    def _sorted_path(x):
        # top-k: keep rows' values >= their k-th largest (mask, static
        # shape); masking the *sorted* copy in place (values >= kth form a
        # descending prefix) saves re-sorting for the top-p pass
        sorted_desc = jnp.sort(x, axis=-1)[:, ::-1]
        kth = jnp.take_along_axis(
            sorted_desc, jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=-1)
        k_on = top_k[:, None] > 0
        x = jnp.where(k_on & (x < kth), -jnp.inf, x)
        sorted_desc = jnp.where(k_on & (sorted_desc < kth), -jnp.inf,
                                sorted_desc)
        return _nucleus_and_draw(x, sorted_desc)

    def sampled_path(_):
        x = logits / jnp.where(is_greedy, 1.0, temp)[:, None]
        if not fast_path or k_cap < 1:
            return _sorted_path(x)
        # one partition over K+1: the K largest per row (descending) plus
        # the (K+1)-th as the tie-spill sentinel.  The barrier keeps XLA
        # from folding the downstream slices into the top_k's sort+slice
        # form, which would defeat the CPU TopK rewrite and re-run the
        # full-vocab sort the fast path exists to avoid (~56x on V=32k)
        vals = jax.lax.optimization_barrier(
            jax.lax.top_k(x, k_cap + 1)[0])             # (B, K+1)
        kth = jnp.take_along_axis(
            vals, jnp.clip(top_k - 1, 0, k_cap)[:, None], axis=-1)
        # a row qualifies if greedy (its draw is discarded) or its top-k
        # fits under the cap with no tie surviving past position K
        ok = is_greedy | ((top_k > 0) & (top_k <= k_cap) &
                          (vals[:, -1] < kth[:, 0]))

        def _topk_path(_):
            xk = jnp.where(x < kth, -jnp.inf, x)
            head = jnp.where(vals[:, :k_cap] < kth, -jnp.inf,
                             vals[:, :k_cap])
            sorted_desc = jnp.concatenate(
                [head, jnp.full((B, V - k_cap), -jnp.inf, x.dtype)], axis=1)
            return _nucleus_and_draw(xk, sorted_desc)

        return jax.lax.cond(jnp.all(ok), _topk_path,
                            lambda _: _sorted_path(x), None)

    sampled = jax.lax.cond(jnp.any(~is_greedy), sampled_path,
                           lambda _: greedy_tok, None)
    return jnp.where(is_greedy, greedy_tok, sampled).astype(jnp.int32)


def token_logprobs(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Log-probability of ``tokens`` (B,) under the *model* distribution
    (raw logits, before any temperature / truncation)."""
    lp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(lp, tokens[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
