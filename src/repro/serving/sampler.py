"""Token sampling: greedy / temperature / top-k / top-p, jit-friendly.

All functions take fp32 logits (..., V) and return int32 tokens (...,).
The dispatch is static (SamplingParams fields are compile-time constants for
a given engine), so the sampled program contains no dead branches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.serving.request import SamplingParams


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _apply_top_k(logits: jax.Array, k: int) -> jax.Array:
    vals, _ = jax.lax.top_k(logits, k)
    cutoff = vals[..., -1:]
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def _apply_top_p(logits: jax.Array, p: float) -> jax.Array:
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the smallest prefix with cumulative prob >= p (always >= 1 token)
    keep = cum - probs < p
    cutoff = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                     keepdims=True)
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def sample(logits: jax.Array, key: jax.Array,
           params: SamplingParams) -> jax.Array:
    """Sample next tokens.  Static dispatch on ``params``."""
    if params.temperature <= 0.0:
        return greedy(logits)
    logits = logits / params.temperature
    if params.top_k > 0:
        logits = _apply_top_k(logits, params.top_k)
    if params.top_p < 1.0:
        logits = _apply_top_p(logits, params.top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
