"""Offline (throughput-oriented) serving engine with continuous batching.

The engine owns ``N_B`` *microbatches* of ``mb_size`` decode slots each —
the unit the DeServe pipeline keeps in flight.  Each step round-robins one
decode tick over the next microbatch; finished sequences release their pages
and the slot is immediately replenished from the queue (prefill), matching
the paper's workload ("replenishing them as the previous requests are
completed").

KV placement follows §4.2: microbatch ``m`` draws overflow pages from global
pool ``G_{m%2}``; an optional :class:`repro.core.offload.DoubleBufferOffloader`
swaps the non-resident pool to host between ticks (on TPU this is the
HBM↔host DMA the paper overlaps with compute; on CPU it is an explicit copy
— same bookkeeping, same schedule).

Prefill is exact-length (rounded to a multiple of 8 for attention-only
archs) and one sequence at a time; decode is one fully-batched jit per
microbatch.  All jit entry points have static shapes.
"""

from __future__ import annotations

import functools
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model as model_lib
from repro.models.common import Runtime
from repro.serving import kv_cache as kvc
from repro.serving.request import (EngineStats, Request, SamplingParams,
                                   SequenceState, Status)
from repro.serving.sampler import sample


class OfflineEngine:
    def __init__(self, cfg: ModelConfig, params, rt: Runtime, *,
                 mb_size: int = 4, num_microbatches: int = 1,
                 pool: Optional[kvc.PoolConfig] = None,
                 sampling: Optional[SamplingParams] = None,
                 offloader=None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.rt = rt
        self.mb_size = mb_size
        self.num_microbatches = num_microbatches
        self.batch = mb_size * num_microbatches
        self.pool = pool or kvc.PoolConfig()
        self.sampling = sampling or SamplingParams()
        self.offloader = offloader
        self.key = jax.random.PRNGKey(seed)

        self.alloc = kvc.PageAllocator(self.pool)
        self.caches = kvc.build_paged_caches(cfg, self.batch, self.pool, rt)
        self.table = np.zeros((self.batch, self.pool.max_pages_per_seq),
                              np.int32)
        self.cur_pos = np.zeros((self.batch,), np.int32)   # next position
        self.active = np.zeros((self.batch,), bool)
        self.slots: List[Optional[SequenceState]] = [None] * self.batch

        self.queue: deque = deque()
        self.finished: List[SequenceState] = []
        self.stats = EngineStats()
        self._decode_jit = jax.jit(functools.partial(
            self._decode_fn, cfg=cfg, rt=rt, sampling=self.sampling),
            static_argnames=("mb",))
        self._prefill_jits: Dict[int, object] = {}

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, requests: List[Request]) -> None:
        for r in requests:
            self.queue.append(SequenceState(request=r))

    def run(self, max_steps: int = 10_000) -> List[SequenceState]:
        for _ in range(max_steps):
            if not self.step():
                break
        return self.finished

    def step(self) -> bool:
        """One engine tick: reap finished, admit new, decode one microbatch.
        Returns False when fully drained."""
        self._reap()
        self._admit()
        if not self.active.any() and not self.queue:
            return False
        mb = self.stats.steps % self.num_microbatches
        self._decode_microbatch(mb)
        self.stats.steps += 1
        return True

    # ------------------------------------------------------------------
    # slot management
    # ------------------------------------------------------------------

    def _mb_of_slot(self, slot: int) -> int:
        return slot // self.mb_size

    def _reap(self) -> None:
        changed = False
        for slot, seq in enumerate(self.slots):
            if seq is not None and seq.is_done():
                seq.status = Status.FINISHED
                self.finished.append(seq)
                self.stats.finished_requests += 1
                self.alloc.release(slot)
                self.slots[slot] = None
                self.active[slot] = False
                self.table[slot] = 0            # park on scratch page 0
                self.cur_pos[slot] = 0
                changed = True
        if changed:
            self.caches = kvc.set_page_table(self.caches, self.table)

    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            seq = self.queue.popleft()
            try:
                self._prefill_into_slot(seq, slot)
            except MemoryError:
                self.queue.appendleft(seq)      # retry when pages free up
                break

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------

    def _prefill_len(self, n: int) -> int:
        if self.cfg.recurrent_layer_count() > 0:
            return n                            # exact (state correctness)
        return max(8, (n + 7) // 8 * 8)

    def _prefill_into_slot(self, seq: SequenceState, slot: int) -> None:
        prompt = seq.request.prompt
        plen = len(prompt)
        total_budget = plen + seq.request.sampling.max_new_tokens
        n_pages = -(-min(total_budget,
                         self.pool.max_pages_per_seq * self.pool.page_size)
                    // self.pool.page_size)
        gp = self._mb_of_slot(slot) % 2 if self.pool.n_global_pages else None
        self.alloc.allocate(slot, n_pages, global_pool=gp)
        self.table[slot] = self.alloc.table_row(slot)

        self.caches = kvc.reset_slot(self.caches, self.cfg, slot, self.rt)
        self.caches = kvc.set_page_table(self.caches, self.table)

        # engine-side generation budget: never outgrow the page allocation
        seq.budget = min(seq.request.sampling.max_new_tokens,
                         self.pool.max_pages_per_seq * self.pool.page_size
                         - plen)
        lp = self._prefill_len(plen)
        toks = np.zeros((lp,), np.int32)
        toks[:plen] = prompt
        fn = self._get_prefill_jit(lp)
        logits, self.caches = fn(self.params, jnp.asarray(toks)[None],
                                 self.caches, slot, plen - 1)
        self.key, sub = jax.random.split(self.key)
        first = int(sample(logits, sub, self.sampling))
        seq.generated.append(first)
        seq.slot = slot
        seq.status = Status.DECODING
        self.slots[slot] = seq
        self.active[slot] = True
        self.cur_pos[slot] = plen               # position of `first`
        self.stats.prefill_tokens += plen
        self.stats.decode_tokens += 1

    def _get_prefill_jit(self, lp: int):
        if lp not in self._prefill_jits:
            self._prefill_jits[lp] = jax.jit(functools.partial(
                self._prefill_fn, cfg=self.cfg, rt=self.rt),
                static_argnames=())
        return self._prefill_jits[lp]

    @staticmethod
    def _prefill_fn(params, tokens, caches, slot, last_idx, *, cfg, rt):
        """Prefill one sequence into batch-wide caches at ``slot``.

        Works on a batch-1 view: slice slot row from every cache leaf, run the
        model prefill, splice back.
        """
        def take(leaf, stacked):
            def one(x):
                if x.ndim == 0:
                    return x
                return jax.lax.dynamic_slice_in_dim(
                    x, slot, 1, axis=1 if stacked else 0)
            return jax.tree.map(one, leaf)

        def put(full, part, stacked):
            def one(f, p):
                if f.ndim == 0:
                    return f
                return jax.lax.dynamic_update_slice_in_dim(
                    f, p.astype(f.dtype), slot, axis=1 if stacked else 0)
            return jax.tree.map(one, full, part)

        # pools/page tables are shared; batch-ful leaves are sliced
        def split(c, stacked):
            shared = {k: v for k, v in c.items() if k.endswith("_pages")}
            perslot = {k: v for k, v in c.items() if not k.endswith("_pages")}
            return shared, perslot

        view = {"scan": [], "tail": []}
        for part, stacked in (("scan", True), ("tail", False)):
            for c in caches[part]:
                shared, perslot = split(c, stacked)
                view[part].append({**shared, **take(perslot, stacked)})

        logits, new_view = model_lib.prefill(
            params, {"tokens": tokens}, cfg, rt, 0, caches=view,
            last_index=jnp.asarray(last_idx).reshape(1))
        # mask ring stale positions beyond the true length
        def clean(c):
            if "pos" in c:
                c = {**c, "pos": jnp.where(c["pos"] <= last_idx, c["pos"], -1)}
            return c
        new_caches = {"scan": [], "tail": []}
        for part, stacked in (("scan", True), ("tail", False)):
            for c_old, c_new in zip(caches[part], new_view[part]):
                c_new = clean(c_new)
                shared, perslot_new = split(c_new, stacked)
                _, perslot_old = split(c_old, stacked)
                merged = {**{k: v for k, v in c_new.items()
                             if k.endswith("_pages")},
                          **put(perslot_old, perslot_new, stacked)}
                new_caches[part].append(merged)
        return logits[0], new_caches

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def _decode_microbatch(self, mb: int) -> None:
        lo = mb * self.mb_size
        hi = lo + self.mb_size
        if not self.active[lo:hi].any():
            return
        if self.offloader is not None:
            self.caches = self.offloader.ensure_resident(self.caches, mb)
            self.stats.swaps = self.offloader.swap_count
        tokens = np.zeros((self.batch,), np.int32)
        for slot in range(lo, hi):
            seq = self.slots[slot]
            if seq is not None and seq.generated:
                tokens[slot] = seq.generated[-1]
        self.key, sub = jax.random.split(self.key)
        next_tokens, self.caches = self._decode_jit(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(self.cur_pos), sub, mb=mb)
        next_np = np.asarray(next_tokens)
        for slot in range(lo, hi):
            seq = self.slots[slot]
            if seq is None or seq.is_done():
                continue            # finished at prefill (eos/budget): reap
                                    # next tick, never extend
            seq.generated.append(int(next_np[slot]))
            self.cur_pos[slot] += 1
            self.stats.decode_tokens += 1
            need = self.cur_pos[slot] + 1
            have = len(self.alloc.pages_of(slot)) * self.pool.page_size
            if need > have:
                gp = mb % 2 if self.pool.n_global_pages else None
                self.alloc.extend(slot, global_pool=gp)
                self.table[slot] = self.alloc.table_row(slot)
                self.caches = kvc.set_page_table(self.caches, self.table)

    @staticmethod
    def _decode_fn(params, caches, tokens, cur_pos, key, *, cfg, rt,
                   sampling, mb):
        logits, new_caches = model_lib.decode_step(
            params, tokens, caches, cur_pos, cfg, rt)
        return sample(logits, key, sampling), new_caches

    # ------------------------------------------------------------------

    def throughput_report(self) -> dict:
        return {
            "prefill_tokens": self.stats.prefill_tokens,
            "decode_tokens": self.stats.decode_tokens,
            "total_tokens": self.stats.total_tokens,
            "finished": self.stats.finished_requests,
            "steps": self.stats.steps,
            "swaps": self.stats.swaps,
        }
