"""Offline (throughput-oriented) serving engine with continuous batching.

The engine owns ``N_B`` *microbatches* of ``mb_size`` decode slots each —
the unit the DeServe pipeline keeps in flight.  Each step round-robins one
decode tick over the next microbatch; finished sequences release their pages
and the slot is immediately replenished from the queue (prefill), matching
the paper's workload ("replenishing them as the previous requests are
completed").

Compute is delegated to a pluggable :mod:`repro.serving.backend`: the
engine keeps every piece of host-side bookkeeping (queue, slots, page
allocator, page table, positions, stats) and the backend owns the device
caches and jit entry points.  ``backend="local"`` is the single-device
path; ``backend="pipelined"`` runs the same continuous-batching loop
through the ``N_S``-stage SPMD pipeline (``repro.core.pipeline``), where a
microbatch's decode tick enters the pipe at stage 0 and drains ``N_S − 1``
engine ticks later — the engine therefore applies decode results by the
microbatch id they carry, not the one it just injected.

Sampling is **per request and on device**: every slot carries its own
temperature / top-k / top-p and a PRNG key derived from
``(seed, request_id)`` (token ``t`` folds in ``t``), so one engine serves
mixed greedy+sampled workloads in one continuously-batched pipe and the
output stream of a request is reproducible across backends, microbatch
layout, and admission order.  The front door for callers is
:class:`repro.serving.llm.LLM`; this class is the scheduling core.

KV placement follows §4.2: microbatch ``m`` draws overflow pages from global
pool ``G_{m%2}``; the :class:`repro.core.offload.DoubleBufferOffloader`
swaps the non-resident pool to host between ticks (on TPU this is the
HBM↔host DMA the paper overlaps with compute; on CPU it is an explicit copy
— same bookkeeping, same schedule).

**Prefill is a first-class scheduler phase.**  For fully-paged archs
(every layer kind "attn"/"global") admission is *chunked*: each tick emits
at most one :class:`~repro.serving.backend.PrefillChunk` — up to
``prefill_rows`` queued/continuing prompts x ``prefill_chunk`` tokens,
budgeted by ``max_prefill_tokens_per_tick`` — through a single
fixed-shape chunk jit.  Sequences hold their slot across ticks with
``Status.PREFILLING`` and a ``prefill_pos`` cursor; the first token is
sampled only when the last chunk lands, under the same reproducible
``(seed, request_id, token_idx)`` key as every decode token.  The
device-wide page table keeps prefilling slots parked on the scratch page
(chunks carry their own table rows), so in-flight decode ticks can never
clobber half-written prompt KV; a slot's real table row is pushed — and
the row activated — only once its microbatch has no tick in flight.  On
the pipelined backend, chunks flow stage-to-stage through a second
persistent pipe and *overlap* in-flight decode microbatches.

Recurrent and sliding-window archs keep the exact-length fallback (state
correctness), one sequence at a time, with the pad length bucketed to the
next power of two so the per-length jit cache stays bounded (pad
positions are masked end-to-end — see ``model.prefill``).  All jit entry
points have static shapes.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import os
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.common import Runtime
from repro.serving import kv_cache as kvc
from repro.serving.backend import (DecodeResult, ExecutionBackend,
                                   PrefillChunk, PrefillResult, make_backend)
from repro.serving.request import (EngineStats, Request, SamplingParams,
                                   SequenceState, Status)
from repro.serving.sampler import (RowSampling, fold_in_steps,
                                   sample_batched, token_logprobs)

log = logging.getLogger(__name__)


def _strict_default(strict: Optional[bool]) -> bool:
    """``strict=None`` defers to the ``REPRO_STRICT`` env var (the test
    suite sets it to 1), so the invariant auditor guards every CI run
    without every construction site opting in."""
    if strict is not None:
        return bool(strict)
    return os.environ.get("REPRO_STRICT", "") not in ("", "0")


def _resolve_trace(trace):
    """``trace=`` accepts: None/False (off — the hot path carries no
    recorder and pays nothing), True (a default-capacity
    :class:`~repro.obs.trace.TraceRecorder`), an int (ring capacity), or
    an existing recorder instance (shared across engines/front ends)."""
    if trace is None or trace is False:
        return None
    from repro.obs.trace import TraceRecorder
    if trace is True:
        return TraceRecorder()
    if isinstance(trace, TraceRecorder):
        return trace
    if isinstance(trace, int):
        return TraceRecorder(capacity=trace)
    raise ValueError(
        f"trace must be None/False/True, an int capacity, or a "
        f"TraceRecorder, got {trace!r}")


@functools.partial(jax.jit, static_argnames=("fast",))
def _sample_first(logits, keys, steps, temp, top_k, top_p, *, fast=True):
    """First-token sampling on prefill logits — jitted at module scope so
    the compile caches across engines/prompts (eager ``lax.cond`` inside
    ``sample_batched`` would retrace per call)."""
    toks = sample_batched(logits, fold_in_steps(keys, steps), temp, top_k,
                          top_p, fast_path=fast)
    return toks, token_logprobs(logits, toks)


@dataclasses.dataclass
class SLOConfig:
    """Latency-SLO targets for online serving admission.

    The controller shapes the per-tick prefill/decode token-budget ratio
    (the same lever as ``max_prefill_tokens_per_tick``): when the
    engine's smoothed tick time exceeds ``itl_target_s`` — inter-token
    latency for decoding requests is one tick per microbatch round —
    admission sheds prefill down to ``floor_frac`` of the budget;  when
    the oldest waiting request has been queued for half its
    ``ttft_target_s``, the budget is restored (TTFT risk needs prefill).
    A zero target disables that half of the policy."""
    ttft_target_s: float = 0.0
    itl_target_s: float = 0.0
    floor_frac: float = 0.25
    ewma_alpha: float = 0.2

    def validate(self) -> None:
        if self.ttft_target_s < 0 or self.itl_target_s < 0:
            raise ValueError("SLO targets must be >= 0")
        if not (0.0 < self.floor_frac <= 1.0):
            raise ValueError(
                f"floor_frac must be in (0, 1], got {self.floor_frac}")
        if not (0.0 < self.ewma_alpha <= 1.0):
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {self.ewma_alpha}")


class SLOController:
    """Deterministic budget shaper for :class:`SLOConfig` (host-side,
    no device state — unit-testable without an engine)."""

    def __init__(self, cfg: SLOConfig):
        cfg.validate()
        self.cfg = cfg
        self.itl_ewma = 0.0

    def observe_tick(self, dt: float) -> None:
        """Feed one engine-tick wall time into the ITL estimate."""
        a = self.cfg.ewma_alpha
        self.itl_ewma = dt if self.itl_ewma == 0.0 else \
            (1.0 - a) * self.itl_ewma + a * dt

    def budget_frac(self, oldest_wait_s: float) -> float:
        """Fraction of the per-tick prefill token budget to admit."""
        c = self.cfg
        if c.ttft_target_s and oldest_wait_s >= 0.5 * c.ttft_target_s:
            return 1.0          # TTFT at risk: prefill must not starve
        if c.itl_target_s and self.itl_ewma > c.itl_target_s:
            return max(c.floor_frac, c.itl_target_s / self.itl_ewma)
        return 1.0


def prefill_chunk_cap(cfg: ModelConfig, rt: Runtime, link, *,
                      stage_time: float,
                      wire_dtype: str = "fp32") -> int:
    """Bandwidth cap on the prefill chunk length, in tokens.

    A chunk of C tokens ships ``C x`` the per-token decode payload over
    every ring link; on a bandwidth-capped link its serialisation time
    is ``C * token_wire_bytes / bandwidth``.  The cap is the largest C
    whose wire time fits one stage tick, so a prefill chunk never
    stretches the cadence the §4.3 planner sized ``N_B`` for.  The
    per-token wire bytes honour the codec: ``d_model * elem_bytes`` raw,
    ``d_model + 4`` packed int8 (one f32 row scale per token).  Returns
    0 when there is nothing to cap (no link, or unlimited bandwidth).
    """
    bw = getattr(link, "bandwidth_bps", 0.0) if link is not None else 0.0
    if not bw or stage_time <= 0:
        return 0
    if wire_dtype == "int8":
        token_bytes = cfg.d_model + 4
    else:
        token_bytes = cfg.d_model * jnp.dtype(rt.compute_dtype).itemsize
    return max(1, int(stage_time * bw // token_bytes))


class OfflineEngine:
    def __init__(self, cfg: ModelConfig, params, rt: Runtime, *,
                 mb_size: int = 4, num_microbatches: int = 1,
                 pool: Optional[kvc.PoolConfig] = None,
                 sampling: Optional[SamplingParams] = None,
                 offloader=None, seed: int = 0,
                 backend="local", n_stages: int = 2, mesh=None,
                 prefill_chunk: int = 0,
                 max_prefill_tokens_per_tick: int = 0,
                 prefill_mode: str = "auto", fault_plan=None,
                 transport=None, schedule: str = "circular",
                 wire_dtype: str = "fp32",
                 sample_fast_path: bool = True, offload_async: bool = True,
                 prefix_cache: bool = False,
                 slo: Optional[SLOConfig] = None,
                 trace=None,
                 strict: Optional[bool] = None):
        self.cfg = cfg
        self.params = params
        self.rt = rt
        self.recorder = _resolve_trace(trace)
        self.mb_size = mb_size
        self.num_microbatches = num_microbatches
        self.batch = mb_size * num_microbatches
        self.pool = pool or kvc.PoolConfig()
        # default for requests submitted with sampling=None (resolved at
        # submit(); explicit per-request params always win — the engine
        # has no global sampling policy)
        self.default_sampling = sampling or SamplingParams()
        self.seed = seed
        self._seed_key = jax.random.PRNGKey(seed)

        # fault recovery re-injects a lost microbatch/chunk with the same
        # tokens at the same positions, which is only bit-transparent when
        # every cache write is position-keyed (paged KV, ring slots).
        # Recurrent state updates are cumulative — re-applying one would
        # double-step the state — so fault injection is gated to archs
        # without recurrent layers (snapshot/restore is a follow-on).
        if fault_plan is not None and cfg.recurrent_layer_count() > 0:
            raise ValueError(
                f"{cfg.name}: fault injection needs position-idempotent "
                "cache writes; recurrent layers accumulate state and "
                "cannot replay a lost tick without a state snapshot")
        self._offloader = offloader
        self._mesh = mesh
        self.n_stages = n_stages
        self.sample_fast_path = sample_fast_path
        self.offload_async = offload_async

        self.backend: ExecutionBackend = make_backend(
            backend, cfg, params, rt, mb_size=mb_size,
            num_microbatches=num_microbatches, pool=self.pool,
            offloader=offloader, n_stages=n_stages, mesh=mesh,
            fault_plan=fault_plan, transport=transport, schedule=schedule,
            wire_dtype=wire_dtype, sample_fast_path=sample_fast_path,
            offload_async=offload_async, recorder=self.recorder)

        # elastic control plane: per-stage EWMA tick times (feeds the
        # admission budget) + the planner/mesh-plan bookkeeping reshard()
        # updates.  Only staged backends report times; the straggler is
        # None on the local path.
        from repro.distributed.elastic import (ElasticPlanner, MeshPlan,
                                               StragglerMitigator)
        stages = getattr(self.backend, "n_stages", None)
        self.straggler = StragglerMitigator(stages) if stages else None
        # lifetime per-stage drain-time totals (reported alongside the
        # straggler's EWMAs — the raw observations that feed admission
        # weighting, otherwise invisible); reset on reshard with the
        # mitigator since the stage count may change
        self._stage_time_total = [0.0] * (stages or 0)
        self._stage_time_count = [0] * (stages or 0)
        self._elastic = ElasticPlanner(model_parallel=1,
                                       pod_size=1 << 30)
        self._mesh_plan = MeshPlan(shape=(stages or 1, 1),
                                   axes=("data", "model"),
                                   devices_used=stages or 1,
                                   devices_spare=0)

        self.alloc = kvc.PageAllocator(self.pool)
        self.table = np.zeros((self.batch, self.pool.max_pages_per_seq),
                              np.int32)
        self.cur_pos = np.zeros((self.batch,), np.int32)   # next position
        self.active = np.zeros((self.batch,), bool)
        self.slots: List[Optional[SequenceState]] = [None] * self.batch
        # per-slot sampling state (set at admission, benign when idle)
        self.samp_keys = np.zeros((self.batch, 2), np.uint32)
        self.samp_temp = np.zeros((self.batch,), np.float32)
        self.samp_top_k = np.zeros((self.batch,), np.int32)
        self.samp_top_p = np.ones((self.batch,), np.float32)

        # ---- chunked-prefill scheduler state -------------------------------
        # chunked prefill requires every layer's KV to live in the shared
        # page pools (writes redirect through per-chunk table rows);
        # recurrent state and sliding-window rings take the exact fallback
        supports_chunked = all(k in ("attn", "global")
                               for k in cfg.layer_kinds())
        if prefill_mode not in ("auto", "chunked", "exact"):
            raise ValueError(
                f"prefill_mode must be 'auto'|'chunked'|'exact', "
                f"got {prefill_mode!r}")
        if prefill_mode == "chunked" and not supports_chunked:
            raise ValueError(
                f"{cfg.name}: prefill_mode='chunked' needs every layer kind "
                "to be paged ('attn'/'global'); recurrent and sliding-window "
                "archs must use exact-length prefill")
        self.chunked_prefill = supports_chunked and prefill_mode != "exact"
        cap = self.pool.max_pages_per_seq * self.pool.page_size
        if not prefill_chunk:           # default chunk: 32 tokens, shrunk
            prefill_chunk = min(32,     # to an explicit per-tick budget
                                max_prefill_tokens_per_tick or 32)
        self.prefill_chunk = min(cap, prefill_chunk)
        if self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {self.prefill_chunk}")
        budget = max_prefill_tokens_per_tick or self.prefill_chunk
        if budget < self.prefill_chunk:
            raise ValueError(
                f"max_prefill_tokens_per_tick={budget} < "
                f"prefill_chunk={self.prefill_chunk}: the per-tick budget "
                "must fit at least one chunk")
        self.max_prefill_tokens_per_tick = budget
        self.prefill_rows = max(1, budget // self.prefill_chunk)
        self.prefilling: List[SequenceState] = []   # own a slot, not done
        self._pending_activation: List[SequenceState] = []
        self._inject_snap: Dict[int, tuple] = {}    # mb -> (active, seqs)
                                                    # at decode injection

        # ---- online-serving policy knobs -----------------------------------
        # prefix caching shares fully-prefilled prompt blocks across
        # requests (refcounted in the allocator); it rides on the chunked
        # path — a prefix hit starts the chunk cursor mid-prompt, which
        # the exact-length fallback cannot do
        if prefix_cache and not self.chunked_prefill:
            raise ValueError(
                f"{cfg.name}: prefix_cache=True needs chunked prefill "
                "(fully-paged archs, prefill_mode != 'exact') — a prefix "
                "hit resumes prefill mid-prompt via the chunk cursor")
        self.prefix_cache: Optional[kvc.PrefixCache] = \
            kvc.PrefixCache(self.alloc) if prefix_cache else None
        self.slo: Optional[SLOController] = \
            SLOController(slo) if slo is not None else None

        self.queue: deque = deque()
        self.finished: List[SequenceState] = []
        self.stats = EngineStats()

        # strict mode: re-audit page accounting, Status FSM, transport
        # books, and jit cache sizes after every submit/step/reshard —
        # pure host bookkeeping, no device syncs (see
        # repro.analysis.invariants)
        self.strict = _strict_default(strict)
        if self.strict:
            from repro.analysis.invariants import EngineAuditor
            self.auditor: Optional[EngineAuditor] = EngineAuditor(self)
        else:
            self.auditor = None

    # ------------------------------------------------------------------
    # planned construction (DeServe §4.3: N_B, batch, pools from the link)
    # ------------------------------------------------------------------

    @classmethod
    def from_plan(cls, cfg: ModelConfig, params, rt: Runtime, *,
                  n_stages: int, stage_time: float, latency: float,
                  m_kv_bytes: float, page_size: int = 16,
                  max_pages_per_seq: int = 16, bandwidth: float = 0.0,
                  use_offload: bool = True, max_microbatches: int = 64,
                  choice=None, mb_size_cap: int = 0, backend="local",
                  sampling: Optional[SamplingParams] = None, seed: int = 0,
                  mesh=None, prefill_chunk: int = 0,
                  max_prefill_tokens_per_tick: int = 0,
                  prefill_mode: str = "auto", fault_plan=None,
                  transport=None, schedule: str = "circular",
                  link_latencies=None, worst_link=None,
                  wire_dtype: str = "fp32",
                  sample_fast_path: bool = True,
                  offload_async: bool = True,
                  prefix_cache: bool = False,
                  slo: Optional[SLOConfig] = None,
                  trace=None,
                  strict: Optional[bool] = None) -> "OfflineEngine":
        """Build an engine whose (N_B, per-microbatch batch, pool split) are
        *derived* from measured stage time + link latency via
        ``repro.core.scheduler.plan_schedule`` — the paper's planner —
        instead of hand-set flags.

        ``m_kv_bytes`` is the per-stage KV budget; ``choice`` may be a
        pre-computed :class:`repro.core.scheduler.ScheduleChoice` (then the
        planner is skipped and the choice is honored as-is).
        ``mb_size_cap`` bounds the per-microbatch batch for reduced/CPU
        runs where the planned batch would not fit the host.

        Prefer :meth:`repro.serving.llm.EngineConfig.plan` — this is the
        low-level entry it resolves to.
        """
        from repro.core import offload as offload_lib
        from repro.core.scheduler import plan_schedule
        if not bandwidth:
            bandwidth = offload_lib.TPU_HOST_DMA_BW
        page_bytes = kvc.kv_bytes_per_page(
            cfg, kvc.PoolConfig(page_size=page_size),
            dtype_bytes=jnp.dtype(rt.compute_dtype).itemsize)
        if page_bytes == 0:
            raise ValueError(
                f"{cfg.name}: from_plan needs at least one paged-attention "
                "layer (pure-recurrent archs have no KV pools to plan)")
        kv_bytes_per_seq = page_bytes * max_pages_per_seq
        if choice is None:
            choice = plan_schedule(
                n_stages=n_stages, stage_time=stage_time, latency=latency,
                link_latencies=link_latencies,
                m_kv_bytes=m_kv_bytes, kv_bytes_per_seq=kv_bytes_per_seq,
                offload_bandwidth=bandwidth, use_offload=use_offload,
                max_microbatches=max_microbatches)
        if choice.offload:
            plan = offload_lib.OffloadPlan.derive(
                m_kv_bytes=m_kv_bytes, page_bytes=page_bytes,
                page_size=page_size, max_pages_per_seq=max_pages_per_seq,
                bandwidth=bandwidth, stage_time=stage_time,
                n_microbatches=choice.n_microbatches)
            pool = plan.pool
        else:
            pool = kvc.PoolConfig(
                page_size=page_size,
                n_local_pages=max(2, int(m_kv_bytes // page_bytes)),
                n_global_pages=0, max_pages_per_seq=max_pages_per_seq)
        mb_size = max(1, choice.per_mb_batch)
        if mb_size_cap:
            mb_size = min(mb_size, mb_size_cap)
        offloader = None
        if choice.offload and pool.n_global_pages:
            offloader = offload_lib.DoubleBufferOffloader(
                pool, choice.n_microbatches, async_swap=offload_async)
        if not prefill_chunk:
            # planner-derived default: a prefill token costs the same model
            # FLOPs as a decode token, so a chunk of ~per-microbatch-batch
            # tokens costs <= one decode tick of stage time and never
            # stretches the stage cadence the planner sized N_B for
            # (floored at 8 so reduced/CPU runs don't degenerate to
            # token-at-a-time prefill)
            prefill_chunk = max(8, mb_size)
            cap = prefill_chunk_cap(cfg, rt, worst_link,
                                    stage_time=stage_time,
                                    wire_dtype=wire_dtype)
            if cap and cap < prefill_chunk:
                # bandwidth-shaped: a chunk payload is C x the decode
                # payload, so on a thin link the FLOPs-derived default
                # would stretch the stage cadence by its serialisation
                # time — shrink the CHUNK (not just the rows) until one
                # chunk's wire time fits a stage tick.  The per-tick
                # admission budget defaults to one chunk, so it follows.
                prefill_chunk = cap
        eng = cls(cfg, params, rt, mb_size=mb_size,
                  num_microbatches=choice.n_microbatches, pool=pool,
                  sampling=sampling, offloader=offloader, seed=seed,
                  backend=backend, n_stages=n_stages, mesh=mesh,
                  prefill_chunk=prefill_chunk,
                  max_prefill_tokens_per_tick=max_prefill_tokens_per_tick,
                  prefill_mode=prefill_mode, fault_plan=fault_plan,
                  transport=transport, schedule=schedule,
                  wire_dtype=wire_dtype, sample_fast_path=sample_fast_path,
                  offload_async=offload_async, prefix_cache=prefix_cache,
                  slo=slo, trace=trace, strict=strict)
        eng.schedule_choice = choice
        return eng

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, requests: List[Request]) -> List[SequenceState]:
        cap = self.pool.max_pages_per_seq * self.pool.page_size
        resolved = []
        for r in requests:          # validate all before enqueueing any,
                                    # so a raise never half-admits a batch.
            # ``sampling=None`` resolves to the engine default on a private
            # copy carried by the SequenceState — the caller's (possibly
            # shared) Request object is never written back
            sp = dataclasses.replace(r.sampling if r.sampling is not None
                                     else self.default_sampling)
            sp.validate()
            resolved.append(sp)
            if not r.prompt:
                raise ValueError(
                    f"request {r.request_id}: empty prompt — there is no "
                    "position to prefill or sample the first token from")
            if len(r.prompt) >= cap:
                raise ValueError(
                    f"request {r.request_id}: prompt length {len(r.prompt)} "
                    f">= per-sequence KV capacity {cap} tokens "
                    f"(max_pages_per_seq={self.pool.max_pages_per_seq} x "
                    f"page_size={self.pool.page_size}) — no generation "
                    "budget would remain; raise max_pages_per_seq or "
                    "truncate the prompt")
        now = time.perf_counter()
        seqs = []
        for r, sp in zip(requests, resolved):
            seq = SequenceState(request=r, sampling=sp,
                                submit_step=self.stats.steps,
                                submit_time=now)
            self.queue.append(seq)
            seqs.append(seq)
            if self.recorder is not None:
                # same float as seq.submit_time, so the trace's
                # queue-wait/TTFT math matches the engine's
                self.recorder.request_submit(r.request_id, now,
                                             len(r.prompt))
        self.stats.queue_depth = len(self.queue)
        if self.auditor is not None:
            self.auditor.after_submit()
        return seqs

    def run(self, max_steps: int = 10_000) -> List[SequenceState]:
        """Step until drained (or until ``max_steps``).  Returns finished
        sequences.  Exhausting the step budget with work still pending is
        surfaced: ``stats.aborted`` is set and a warning logged —
        ``pending()`` lists what was left behind."""
        self.stats.aborted = False
        for _ in range(max_steps):
            if not self.step():
                return self.finished
        if self.pending():
            self.stats.aborted = True
            log.warning(
                "OfflineEngine.run(max_steps=%d) exhausted its step budget "
                "with %d request(s) still pending (%d finished) — partial "
                "drain; raise max_steps or keep stepping", max_steps,
                len(self.pending()), len(self.finished))
        return self.finished

    def pending(self) -> List[SequenceState]:
        """Sequences submitted but not finished (queued or in a slot)."""
        return [s for s in self.slots if s is not None] + list(self.queue)

    def status_counts(self) -> Dict[str, int]:
        """Per-status sequence counts across queue, slots, and finished.

        Always writes the result back to ``stats.status_counts`` — the
        cached copy is a *mirror* of this computation, refreshed by every
        caller (``throughput_report()``, metrics snapshots), never a
        source of truth, so it cannot go stale across step/reshard."""
        counts = {s.value: 0 for s in Status}
        for seq in self.pending():
            counts[seq.status.value] += 1
        counts[Status.FINISHED.value] += len(self.finished)
        self.stats.status_counts = counts
        return counts

    # ------------------------------------------------------------------
    # elastic re-sharding (mesh resize mid-run)
    # ------------------------------------------------------------------

    def reshard(self, live_devices: Optional[int] = None, *,
                n_stages: Optional[int] = None, detector=None,
                now: Optional[float] = None) -> dict:
        """Rebuild the pipelined backend with a new stage count mid-run,
        keeping every in-flight request's progress.

        The target is either an explicit ``n_stages`` or derived from the
        live-device count (directly, or from a
        :class:`~repro.distributed.elastic.FailureDetector` at ``now``)
        through :class:`~repro.distributed.elastic.ElasticPlanner` — the
        pipe depth becomes the largest power of two that the live devices,
        ``num_microbatches`` (N_B >= N_S) and the local device count
        admit.

        Sequence: (1) drain the old pipe (both planes) so no tick is lost,
        (2) carry the engine-format cache pytree over — its layout is
        n_stages-independent, stage slicing happens inside the tick jit —
        (3) rebuild :class:`PipelinedBackend` on a fresh ``pod`` mesh
        (params re-split by ``split_scan_params`` at trace time), and
        (4) replay the engine's device-wide page table into the fresh
        backend.  ``seq``/``prefill_pos`` cursors are engine state and
        survive untouched, so no completed token is ever recomputed.

        Offloaded global pools migrate with the rebuild: the old
        backend's per-stage host stores are concatenated into full-period
        arrays (``export_offload_state``, after the drain so the books
        are stable) and re-split for the new stage count
        (``import_offload_state``), so swapped-out parities replay
        byte-identical through the fresh offloaders.

        Returns the planner's resharding plan.  Raises on the local
        backend.
        """
        from repro.distributed.elastic import MeshPlan
        from repro.serving.backend import PipelinedBackend
        if not isinstance(self.backend, PipelinedBackend):
            raise ValueError(
                "reshard: only the pipelined backend has a stage mesh to "
                f"rebuild (backend is {self.backend.name!r})")
        if detector is not None:
            if now is None:
                now = time.monotonic()
            live_devices = len(detector.live(now))
        if n_stages is None:
            if live_devices is None:
                raise ValueError(
                    "reshard: pass live_devices=, detector=, or n_stages=")
            from repro.models.common import make_layer_plan
            plan = self._elastic.plan(live_devices)
            n_periods = make_layer_plan(self.cfg.num_layers,
                                        self.cfg.block_pattern).n_periods
            n_stages = max(1, min(plan.data, self.num_microbatches,
                                  len(jax.devices()), n_periods))
        if n_stages > self.num_microbatches:
            raise ValueError(
                f"reshard: N_B >= N_S requires n_stages <= "
                f"{self.num_microbatches}, got {n_stages}")
        new_plan = MeshPlan(shape=(n_stages, 1), axes=("data", "model"),
                            devices_used=n_stages,
                            devices_spare=max(0, (live_devices or n_stages)
                                              - n_stages))
        reshard_plan = self._elastic.resharding_plan(self._mesh_plan,
                                                     new_plan)

        # (1) drain both planes: every in-flight tick completes and books
        # normally, so nothing is recomputed and recurrent/ring state in
        # the carried caches is consistent
        t_drain0 = time.perf_counter()
        old_stages = self.backend.n_stages
        tokens0 = np.zeros((self.mb_size,), np.int32)
        pos0 = np.zeros((self.mb_size,), np.int32)
        while self.backend.pending():
            for res in self.backend.decode(0, tokens0, pos0,
                                           RowSampling.zeros(self.mb_size),
                                           active=False):
                self._apply_result(res)
        while self.backend.prefill_pending():
            for res in self.backend.prefill_step(None):
                self._apply_prefill_result(res)
        self._activate_ready()          # pipe empty -> nothing is busy

        # offloaded global pools hold per-stage host content keyed to the
        # OLD stage split: concatenate each microbatch's per-stage ranges
        # into full-period host arrays now (pipe drained, caches stable),
        # re-split for the new stage count after the rebuild
        off_state = self.backend.export_offload_state()
        t_rebuild0 = time.perf_counter()
        if self.recorder is not None:
            self.recorder.reshard_span("drain", t_drain0, t_rebuild0,
                                       (("old_stages", old_stages),))

        # (2)+(3) carry caches (host round-trip: the old arrays are
        # committed to the old pod mesh), rebuild on a fresh mesh
        caches = jax.tree.map(lambda x: np.asarray(x), self.backend.caches)
        fault_plan = self.backend.fault_plan
        if fault_plan is not None:
            # a fault planned for a stage that no longer exists cannot
            # happen — prune instead of tripping the new backend's
            # stage-bounds validation mid-run.  Pending tick indices stay
            # plane-local: they are carried below so an event scheduled
            # for absolute tick T still fires at T.
            gone = [e for e in fault_plan.events if e.stage >= n_stages]
            if gone:
                log.warning("reshard: dropping %d pending fault event(s) "
                            "targeting stages >= %d: %s", len(gone),
                            n_stages, gone)
                fault_plan.events = [e for e in fault_plan.events
                                     if e.stage < n_stages]
        if self._mesh is not None:
            log.warning("reshard: the engine's custom mesh is built for "
                        "%d stage(s) — the rebuilt backend uses a default "
                        "mesh over jax.devices()[:%d]",
                        self.backend.n_stages, n_stages)
        old_ticks = (self.backend._decode_ticks, self.backend._prefill_ticks)
        log.info("reshard: %d -> %d stages (%s)", self.backend.n_stages,
                 n_stages, {k: v for k, v in reshard_plan.items()
                            if k not in ("old", "new")})
        self.backend = make_backend(
            "pipelined", self.cfg, self.params, self.rt,
            mb_size=self.mb_size, num_microbatches=self.num_microbatches,
            pool=self.pool, offloader=self._offloader, n_stages=n_stages,
            mesh=None, fault_plan=fault_plan,
            # the link policy survives the rebuild: for_stages retargets
            # per-link specs to the new ring (conservative worst-link
            # envelope when the count changed) and carries the virtual
            # clock so transport accounting stays monotonic
            transport=self.backend.transport.for_stages(n_stages),
            schedule=self.backend.schedule,
            wire_dtype=getattr(self.backend, "wire_dtype", "fp32"),
            recorder=self.recorder)
        # plane tick counters survive the rebuild, so FaultPlan tick
        # indices keep their absolute meaning across a reshard
        self.backend._decode_ticks, self.backend._prefill_ticks = old_ticks
        self.backend.caches = jax.tree.map(jnp.asarray, caches)
        # replay the migrated host stores into the fresh offloaders (the
        # carried caches already hold every RESIDENT parity's bytes; the
        # import covers the swapped-out parities)
        self.backend.import_offload_state(off_state)

        # (4) replay the device-wide page table; per-slot ring/recurrent
        # state rode along inside the cache pytree
        self.backend.set_page_table(self.table)

        from repro.distributed.elastic import StragglerMitigator
        self.straggler = StragglerMitigator(n_stages)
        self._stage_time_total = [0.0] * n_stages
        self._stage_time_count = [0] * n_stages
        self.n_stages = n_stages
        self._mesh_plan = new_plan
        self.stats.reshards += 1
        if self.recorder is not None:
            self.recorder.reshard_span("rebuild", t_rebuild0,
                                       time.perf_counter(),
                                       (("n_stages", n_stages),))
        if self.auditor is not None:
            self.auditor.after_reshard()
        return reshard_plan

    def step(self) -> bool:
        """One engine tick: reap finished, run the prefill phase (one
        budgeted chunk through the prefill plane, or the exact-length
        fallback admission), tick one microbatch through the backend.
        Returns False when fully drained."""
        t0 = time.perf_counter()
        self._reap()
        tp = time.perf_counter()
        if self.chunked_prefill:
            chunk = self._build_chunk()
            for res in self.backend.prefill_step(chunk):
                self._apply_prefill_result(res)
            self._activate_ready()
        else:
            self._admit()
        tp2 = time.perf_counter()
        self.stats.queue_depth = len(self.queue)
        # drained iff no slot is occupied (active, prefilling, or finished-
        # at-prefill awaiting reap), nothing queued, and neither the decode
        # nor the prefill plane has ticks in flight
        if not any(s is not None for s in self.slots) and not self.queue \
                and not self.backend.pending() \
                and not self.backend.prefill_pending():
            self.stats.prefill_time_s += tp2 - tp
            self.stats.decode_time_s += tp - t0
            self.stats.wall_time_s += time.perf_counter() - t0
            if self.recorder is not None:
                self.recorder.step_phase("reap", t0, tp, self.stats.steps)
                self.recorder.step_phase("prefill", tp, tp2,
                                         self.stats.steps)
            if self.auditor is not None:
                self.auditor.after_step()
            return False
        mb = self.stats.steps % self.num_microbatches
        self._decode_microbatch(mb)
        if self.straggler is not None:
            for s, dt in self.backend.drain_stage_times():
                self.straggler.observe(s, dt)
                self._stage_time_total[s] += dt
                self._stage_time_count[s] += 1
        self.stats.steps += 1
        t1 = time.perf_counter()
        self.stats.prefill_time_s += tp2 - tp
        self.stats.decode_time_s += (tp - t0) + (t1 - tp2)
        self.stats.wall_time_s += t1 - t0
        if self.slo is not None:
            self.slo.observe_tick(t1 - t0)
        if self.recorder is not None:
            # the stamps EngineStats uses anyway — no extra clock reads
            # on the hot path beyond the one t1 above
            step = self.stats.steps - 1
            self.recorder.step_phase("reap", t0, tp, step)
            self.recorder.step_phase("prefill", tp, tp2, step)
            self.recorder.step_phase("decode", tp2, t1, step)
        if self.auditor is not None:
            self.auditor.after_step()
        return True

    # ------------------------------------------------------------------
    # slot management
    # ------------------------------------------------------------------

    def _mb_of_slot(self, slot: int) -> int:
        return slot // self.mb_size

    def _reap(self) -> None:
        changed = False
        now = time.perf_counter()
        for slot, seq in enumerate(self.slots):
            if seq is not None and seq.is_done():
                seq.status = Status.FINISHED
                seq.finish_step = self.stats.steps
                seq.finish_time = now
                self.finished.append(seq)
                self.stats.finished_requests += 1
                if self.recorder is not None:
                    reason = seq.finish_reason()
                    self.recorder.request_finish(
                        seq.request.request_id, now,
                        reason.value if reason is not None else None)
                self.alloc.release(slot)
                self.slots[slot] = None
                self.active[slot] = False
                self.table[slot] = 0            # park on scratch page 0
                self.cur_pos[slot] = 0
                self.samp_temp[slot] = 0.0      # idle rows decode greedily
                self.samp_top_k[slot] = 0
                self.samp_top_p[slot] = 1.0
                self.samp_keys[slot] = 0
                changed = True
        if changed:
            self.backend.set_page_table(self.table)

    def _admit(self) -> None:
        # microbatches with a tick in flight must not have their cache rows
        # (or page-table rows) rewritten under them — skip until drained
        busy = self.backend.busy_microbatches()
        for slot in range(self.batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            if self._mb_of_slot(slot) in busy:
                continue
            seq = self.queue.popleft()
            seq.status = Status.PREFILLING
            try:
                self._prefill_into_slot(seq, slot)
            except MemoryError:
                seq.status = Status.QUEUED
                self.queue.appendleft(seq)      # retry when pages free up
                break

    # ------------------------------------------------------------------
    # chunked prefill (the default admission path for fully-paged archs)
    # ------------------------------------------------------------------

    def _allocate_slot(self, seq: SequenceState, slot: int,
                       global_pool: Optional[int]) -> None:
        """Allocate the slot's full page budget and bind the sequence to it
        (raises MemoryError with nothing bound on exhaustion).  The caller
        decides when to push the slot's real table row: the chunked path
        parks it until activation (chunks carry their own table rows), the
        exact path pushes it immediately."""
        sp = seq.sampling
        plen = seq.prompt_len
        total_budget = plen + sp.max_new_tokens
        n_pages = -(-min(total_budget,
                         self.pool.max_pages_per_seq * self.pool.page_size)
                    // self.pool.page_size)
        shared: List[int] = []
        if self.prefix_cache is not None:
            # adopt the longest cached full-page prompt prefix: refcounts
            # bump, no re-prefill — the chunk cursor starts past it
            shared = self.prefix_cache.match(seq.request.prompt)
            if shared:
                self.alloc.adopt(slot, shared)
        try:
            pages = self.alloc.allocate(slot, n_pages - len(shared),
                                        global_pool=global_pool)
        except MemoryError:
            # pool pressure: evict cold cached prefixes and retry once
            # before giving the caller its head-of-line retry
            if self.prefix_cache is None or \
                    not self.prefix_cache.evict(n_pages - len(shared)):
                if shared:
                    self.alloc.release(slot)
                raise
            if self.recorder is not None:
                self.recorder.prefix_event(
                    "evict", seq.request.request_id,
                    (n_pages - len(shared)) * self.pool.page_size,
                    time.perf_counter())
            try:
                pages = self.alloc.allocate(slot, n_pages - len(shared),
                                            global_pool=global_pool)
            except MemoryError:
                if shared:
                    self.alloc.release(slot)
                raise
        has_global = any(p >= self.pool.n_local_pages for p in pages)
        seq.global_parity = global_pool if has_global else None
        seq.slot = slot
        seq.prefill_pos = len(shared) * self.pool.page_size
        if shared:
            self.stats.prefix_hits += 1
            self.stats.prefix_hit_tokens += seq.prefill_pos
        if self.recorder is not None:
            rid = seq.request.request_id
            now = time.perf_counter()
            self.recorder.request_admit(rid, now)
            self.recorder.request_pages(rid, n_pages)
            if shared:
                self.recorder.prefix_event("hit", rid, seq.prefill_pos,
                                           now)
                self.recorder.request_prefix_hit(rid, seq.prefill_pos)
        seq.status = Status.PREFILLING
        seq.budget = min(sp.max_new_tokens,
                         self.pool.max_pages_per_seq * self.pool.page_size
                         - plen)
        self.slots[slot] = seq

    def _tick_prefill_rows(self) -> int:
        """Per-tick admission width: the configured ``prefill_rows``,
        lightened while a pipeline stage is straggling (the §4.3 ring tick
        is set by the slowest stage, so extra admission work must shrink
        with it — ``StragglerMitigator.microbatch_weights`` are mean-1
        inverse EWMAs, and the minimum weight scales the per-tick token
        budget, floored at one chunk).  The chunk's device shapes stay
        fixed at (prefill_rows, prefill_chunk); only fewer rows fill.

        The latency-SLO controller (when configured) composes on the same
        budget: its fraction sheds prefill while smoothed tick time blows
        the inter-token target, unless the oldest waiting request is at
        TTFT risk.  The stricter of the two weights wins — a straggling
        stage caps admission even when TTFT wants more prefill."""
        w = 1.0
        if self.straggler is not None and self.straggler.stragglers():
            w = min(w, min(self.straggler.microbatch_weights()))
        if self.slo is not None:
            now = time.perf_counter()
            waits = [now - s.submit_time for s in self.queue]
            waits += [now - s.submit_time for s in self.prefilling
                      if not s.generated]
            frac = self.slo.budget_frac(max(waits, default=0.0))
            w = min(w, frac)
            if self.recorder is not None:
                self.recorder.slo_budget(
                    frac,
                    int(self.max_prefill_tokens_per_tick * min(1.0, w)),
                    now)
        if w >= 1.0:
            return self.prefill_rows
        budget = int(self.max_prefill_tokens_per_tick * min(1.0, w))
        return max(1, min(self.prefill_rows, budget // self.prefill_chunk))

    def _build_chunk(self) -> Optional[PrefillChunk]:
        """Assemble this tick's prefill work unit: continue partially
        prefilled sequences first (FIFO), then admit queued prompts into
        free slots, up to ``prefill_rows`` rows of ``prefill_chunk`` tokens
        (the ``max_prefill_tokens_per_tick`` budget).  The offloader keys
        global-pool host copies by *microbatch id*, so all rows drawing on
        one pool parity must belong to the same microbatch (one per parity
        can ride along); head-of-line blocking on page exhaustion is
        preserved — the queue front retries after pages free up."""
        if not self.backend.prefill_can_accept():
            return None
        rows_cap = self._tick_prefill_rows()
        rows: List[SequenceState] = []
        # parity -> the single microbatch whose global-pool copy must be
        # resident for this chunk (the offloader stages copies per mb)
        parity_mb: Dict[int, Optional[int]] = {0: None, 1: None}
        for seq in self.prefilling:
            if len(rows) == rows_cap:
                break
            if seq.chunk_inflight:
                continue
            mb = self._mb_of_slot(seq.slot)
            if seq.global_parity is not None:
                if parity_mb[mb % 2] not in (None, mb):
                    continue            # another mb owns this parity slice
                parity_mb[mb % 2] = mb
            rows.append(seq)
        if len(rows) < rows_cap and self.queue:
            free = [s for s in range(self.batch) if self.slots[s] is None]
            for slot in free:
                if not self.queue or len(rows) == rows_cap:
                    break
                mb = self._mb_of_slot(slot)
                gp = mb % 2 if self.pool.n_global_pages else None
                if gp is not None and parity_mb[gp] not in (None, mb):
                    continue            # slot would pull the wrong mb's copy
                seq = self.queue[0]
                try:
                    self._allocate_slot(seq, slot, gp)
                except MemoryError:
                    break               # head-of-line retry next tick
                self.queue.popleft()
                if seq.global_parity is not None:
                    parity_mb[mb % 2] = mb
                self.prefilling.append(seq)
                rows.append(seq)
        if not rows:
            return None

        R, C = self.prefill_rows, self.prefill_chunk
        tokens = np.zeros((R, C), np.int32)
        slots = np.full((R,), -1, np.int32)
        offsets = np.zeros((R,), np.int32)
        n_valid = np.zeros((R,), np.int32)
        lasts = np.full((R,), -1, np.int32)
        tables = np.zeros((R, self.pool.max_pages_per_seq), np.int32)
        for i, seq in enumerate(rows):
            prompt = seq.request.prompt
            take = min(C, len(prompt) - seq.prefill_pos)
            tokens[i, :take] = prompt[seq.prefill_pos:seq.prefill_pos + take]
            slots[i] = seq.slot
            offsets[i] = seq.prefill_pos
            n_valid[i] = take
            if seq.prefill_pos + take == len(prompt):
                lasts[i] = take - 1
            tables[i] = self.alloc.table_row(seq.slot)
            seq.chunk_inflight = True
            if self.recorder is not None:
                self.recorder.request_chunk(seq.request.request_id, take)
        return PrefillChunk(
            tokens=tokens, slots=slots, offsets=offsets, n_valid=n_valid,
            lasts=lasts, tables=tables, seqs=rows,
            residency_mbs=tuple(m for m in parity_mb.values()
                                if m is not None))

    def _apply_prefill_result(self, res: PrefillResult) -> None:
        if res.lost:
            # a stage fault dropped the chunk mid-pipe: no prompt token
            # landed (prefill_pos untouched), so clearing the in-flight
            # flag makes _build_chunk re-emit the identical chunk —
            # prompt-KV writes are offset-keyed, the retry rewrites the
            # same pages and outputs stay bit-identical
            for seq in res.chunk.seqs:
                seq.chunk_inflight = False
            self.stats.prefill_chunks_lost += 1
            if self.recorder is not None:
                self.recorder.fault("recover", time.perf_counter(),
                                    (("plane", "prefill"),
                                     ("rows", len(res.chunk.seqs))))
            return
        for i, seq in enumerate(res.chunk.seqs):
            seq.chunk_inflight = False
            take = int(res.chunk.n_valid[i])
            seq.prefill_pos += take
            self.stats.prefill_tokens += take
            if seq.prefill_pos >= seq.prompt_len:
                self._finish_prefill(seq, res.logits[i])

    def _finish_prefill(self, seq: SequenceState, logits_row) -> None:
        """The sequence's last chunk landed: sample its first token (same
        keying as every decode token) and queue it for activation."""
        self._sample_first_token(seq, seq.slot, logits_row)
        if self.prefix_cache is not None:
            # register this prompt's fully-written blocks for future
            # sharers (existing entries win on a concurrent double-fill)
            self.prefix_cache.insert(seq.request.prompt,
                                     self.alloc.pages_of(seq.slot))
            if self.recorder is not None:
                self.recorder.prefix_event("insert",
                                           seq.request.request_id,
                                           seq.prompt_len,
                                           time.perf_counter())
        self.prefilling.remove(seq)
        if not seq.is_done():               # finished at prefill (eos /
            self._pending_activation.append(seq)    # zero budget): reap
                                                    # without ever decoding

    def _activate_ready(self) -> None:
        """Push real page-table rows and activate completed prefills whose
        microbatch has no decode tick in flight (an in-flight tick still
        writes its bubble rows against the parked table — swapping the row
        under it would clobber the fresh prompt KV at position 0)."""
        if not self._pending_activation:
            return
        busy = self.backend.busy_microbatches()
        held, changed = [], False
        for seq in self._pending_activation:
            if self._mb_of_slot(seq.slot) in busy:
                held.append(seq)
                continue
            self.table[seq.slot] = self.alloc.table_row(seq.slot)
            seq.status = Status.DECODING
            self.active[seq.slot] = True
            changed = True
        self._pending_activation = held
        if changed:
            self.backend.set_page_table(self.table)

    # ------------------------------------------------------------------
    # prefill (exact-length fallback: recurrent / sliding-window archs)
    # ------------------------------------------------------------------

    def _prefill_len(self, n: int) -> int:
        if self.cfg.recurrent_layer_count() > 0:
            # bucket to the next power of two so the per-length jit cache
            # is bounded (log2 entries); state stays exact — pad positions
            # are masked through the recurrences (model.prefill)
            return max(8, 1 << (n - 1).bit_length())
        return max(8, (n + 7) // 8 * 8)

    def _request_key(self, request_id: int) -> np.ndarray:
        """Per-request base PRNG key: ``fold_in(PRNGKey(seed), rid)`` —
        a function of (seed, request_id) only, so token streams reproduce
        across backends, N_B, and admission order."""
        return np.asarray(jax.random.fold_in(self._seed_key, request_id),
                          np.uint32)

    def _sample_first_token(self, seq: SequenceState, slot: int,
                            logits) -> None:
        """Set the slot's sampling state and sample the request's first
        token from its last-position prefill logits — the request's own
        params under its own key (token index 0), the same path as every
        decode token.  Shared by the chunked and exact prefill paths."""
        sp = seq.sampling
        base = self._request_key(seq.request.request_id)
        self.samp_keys[slot] = base
        self.samp_temp[slot] = sp.temperature
        self.samp_top_k[slot] = sp.top_k
        self.samp_top_p[slot] = sp.top_p
        # normalize to a plain single-device array: pipelined backends hand
        # back NamedSharding-committed logits after the first tick, which
        # would fork a second _sample_first compile cache entry
        # repro-audit: allow(host-sync) — once per request at prefill completion, not per tick; de-shards logits for a stable _sample_first cache
        logits = jnp.asarray(np.asarray(logits))
        first_arr, first_lp = _sample_first(
            logits[None], jnp.asarray(base[None]),
            jnp.zeros((1,), jnp.int32),
            jnp.asarray(self.samp_temp[slot:slot + 1]),
            jnp.asarray(self.samp_top_k[slot:slot + 1]),
            jnp.asarray(self.samp_top_p[slot:slot + 1]),
            fast=self.sample_fast_path)
        if sp.logprobs:
            # repro-audit: allow(host-sync) — first-token host booking, once per request at admission
            seq.logprobs = [float(first_lp[0])]
        # repro-audit: allow(host-sync) — first-token host booking, once per request at admission
        seq.generated.append(int(first_arr[0]))
        seq.first_token_time = time.perf_counter()   # engine-side TTFT mark
        if self.recorder is not None:
            # same float as seq.first_token_time: trace TTFT == seq.ttft_s
            rid = seq.request.request_id
            self.recorder.request_first_token(rid, seq.first_token_time)
            self.recorder.request_tokens(rid, 1, seq.first_token_time)
        self.cur_pos[slot] = seq.prompt_len     # position of the first token
        self.stats.decode_tokens += 1

    def _prefill_into_slot(self, seq: SequenceState, slot: int) -> None:
        prompt = seq.request.prompt
        plen = len(prompt)
        gp = self._mb_of_slot(slot) % 2 if self.pool.n_global_pages else None
        self._allocate_slot(seq, slot, gp)      # pages + budget + binding
        self.table[slot] = self.alloc.table_row(slot)
        self.backend.reset_slot(slot)
        self.backend.set_page_table(self.table)

        lp = self._prefill_len(plen)
        toks = np.zeros((lp,), np.int32)
        toks[:plen] = prompt
        logits = self.backend.prefill(
            toks, slot, plen - 1,
            has_global_pages=seq.global_parity is not None)
        self._sample_first_token(seq, slot, logits)
        seq.status = Status.DECODING
        self.active[slot] = True
        self.stats.prefill_tokens += plen

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def _row_sampling(self, lo: int, hi: int) -> RowSampling:
        """Snapshot the per-row sampling state for slots [lo, hi) — copied,
        because pipelined backends hold it until the tick drains."""
        steps = np.zeros((hi - lo,), np.int32)
        for i, slot in enumerate(range(lo, hi)):
            seq = self.slots[slot]
            if seq is not None:
                steps[i] = len(seq.generated)   # index of the token sampled
        return RowSampling(keys=self.samp_keys[lo:hi].copy(), steps=steps,
                           temp=self.samp_temp[lo:hi].copy(),
                           top_k=self.samp_top_k[lo:hi].copy(),
                           top_p=self.samp_top_p[lo:hi].copy())

    def _decode_microbatch(self, mb: int) -> None:
        lo = mb * self.mb_size
        hi = lo + self.mb_size
        mb_active = bool(self.active[lo:hi].any())
        if not mb_active and not self.backend.pending():
            return
        tokens = np.zeros((self.mb_size,), np.int32)
        for i, slot in enumerate(range(lo, hi)):
            seq = self.slots[slot]
            if seq is not None and seq.generated:
                tokens[i] = seq.generated[-1]
        if mb_active:
            # snapshot which rows (and which sequences) this injection is
            # for: with chunked prefill a slot can be reassigned, or become
            # active, while the tick is still in flight — the drained
            # result must never be booked against the new occupant
            self._inject_snap[mb] = (self.active[lo:hi].copy(),
                                     list(self.slots[lo:hi]))
        results = self.backend.decode(mb, tokens, self.cur_pos[lo:hi],
                                      self._row_sampling(lo, hi),
                                      active=mb_active)
        self.stats.swaps = self.backend.swap_count
        for res in results:
            self._apply_result(res)

    def _apply_result(self, res: DecodeResult) -> None:
        """Book one drained microbatch tick (possibly for an earlier
        microbatch than the one just injected — pipelined backends drain
        with N_S − 1 ticks of latency)."""
        if res.lost:
            # a stage fault dropped the microbatch's tick: nothing was
            # booked (seq/cur_pos cursors only advance on a drained
            # result), so recovery is to discard the injection snapshot
            # and let the round-robin re-inject the microbatch with the
            # same tokens at the same positions on its next turn — the
            # retry rewrites identical position-keyed KV and samples
            # under the same (seed, request_id, token_idx) keys
            self._inject_snap.pop(res.mb, None)
            self.stats.decode_ticks_lost += 1
            if self.recorder is not None:
                self.recorder.fault("recover", time.perf_counter(),
                                    (("plane", "decode"),
                                     ("mb", res.mb)))
            return
        lo = res.mb * self.mb_size
        snap = self._inject_snap.pop(res.mb, None)
        rec = self.recorder
        tnow = time.perf_counter() if rec is not None else 0.0
        for i, slot in enumerate(range(lo, lo + self.mb_size)):
            seq = self.slots[slot]
            if seq is None or seq.is_done():
                continue            # finished at prefill (eos/budget): reap
                                    # next tick, never extend
            if snap is not None and (not snap[0][i] or snap[1][i] is not seq):
                continue            # row wasn't live at injection (still
                                    # prefilling, or slot reassigned)
            seq.generated.append(int(res.tokens[i]))
            if seq.logprobs is not None:
                seq.logprobs.append(float(res.logprobs[i]))
            self.cur_pos[slot] += 1
            self.stats.decode_tokens += 1
            if rec is not None:
                rec.request_tokens(seq.request.request_id, 1, tnow)
            need = self.cur_pos[slot] + 1
            have = len(self.alloc.pages_of(slot)) * self.pool.page_size
            if need > have:
                gp = res.mb % 2 if self.pool.n_global_pages else None
                self.alloc.extend(slot, global_pool=gp)
                self.table[slot] = self.alloc.table_row(slot)
                self.backend.set_page_table(self.table)
                if rec is not None:
                    rec.request_pages(seq.request.request_id, 1)

    # ------------------------------------------------------------------

    def request_trace(self, request_id: int) -> Optional[dict]:
        """Per-request flight-recorder snapshot (queue wait, TTFT,
        per-token inter-token latencies, chunk/page/prefix-hit counts).
        ``None`` when tracing is off or the request has been evicted
        from the recorder's bounded table."""
        if self.recorder is None:
            return None
        return self.recorder.request_trace(request_id)

    def throughput_report(self) -> dict:
        lat_steps = [s.latency_steps for s in self.finished
                     if s.latency_steps is not None]
        lat_s = [s.latency_s for s in self.finished
                 if s.latency_s is not None]
        # per-status counts are O(batch + queue): computed on demand here
        # (status_counts() writes the stats mirror itself), never in the
        # per-tick loop
        self.status_counts()
        rep = {
            "backend": self.backend.name,
            "prefill_tokens": self.stats.prefill_tokens,
            "decode_tokens": self.stats.decode_tokens,
            "total_tokens": self.stats.total_tokens,
            "finished": self.stats.finished_requests,
            "steps": self.stats.steps,
            "swaps": self.stats.swaps,
            "wall_time_s": self.stats.wall_time_s,
            "prefill_time_s": self.stats.prefill_time_s,
            "decode_time_s": self.stats.decode_time_s,
            "decode_tok_per_s": self.stats.decode_tok_per_s,
            "prefill_tok_per_s": self.stats.prefill_tok_per_s,
            "queue_depth": self.stats.queue_depth,
            "status_counts": self.stats.status_counts,
            "aborted": self.stats.aborted,
            "decode_ticks_lost": self.stats.decode_ticks_lost,
            "prefill_chunks_lost": self.stats.prefill_chunks_lost,
            "reshards": self.stats.reshards,
            "mean_latency_steps":
                float(np.mean(lat_steps)) if lat_steps else 0.0,
            "mean_latency_s": float(np.mean(lat_s)) if lat_s else 0.0,
        }
        if self.prefix_cache is not None:
            rep["prefix_hits"] = self.stats.prefix_hits
            rep["prefix_hit_tokens"] = self.stats.prefix_hit_tokens
            rep["prefix_hit_rate"] = self.prefix_cache.hit_rate
            rep["prefix_cache_pages"] = len(self.prefix_cache)
        if self.straggler is not None:
            # the raw observations behind admission weighting, surfaced:
            # per-stage tick-time EWMAs, lifetime drain-time totals/counts,
            # the mean-1 inverse weights, and which stages are currently
            # flagged (all host lists the mitigation loop already holds)
            rep["stages"] = {
                "ewma_s": list(self.straggler.ewma),
                "total_s": list(self._stage_time_total),
                "counts": list(self._stage_time_count),
                "microbatch_weights": self.straggler.microbatch_weights(),
                "stragglers": self.straggler.stragglers(),
            }
        tstats = self.backend.transport_stats()
        if tstats:
            rep["transport"] = tstats
            vt = tstats.get("virtual_time_s", 0.0)
            if vt > 0:
                # decode tok/s on the simulated network's clock — what
                # the latency_curve benchmark compares across schedules
                rep["virtual_decode_tok_per_s"] = \
                    self.stats.decode_tokens / vt
        return rep
