"""Offline (throughput-oriented) serving engine with continuous batching.

The engine owns ``N_B`` *microbatches* of ``mb_size`` decode slots each —
the unit the DeServe pipeline keeps in flight.  Each step round-robins one
decode tick over the next microbatch; finished sequences release their pages
and the slot is immediately replenished from the queue (prefill), matching
the paper's workload ("replenishing them as the previous requests are
completed").

Compute is delegated to a pluggable :mod:`repro.serving.backend`: the
engine keeps every piece of host-side bookkeeping (queue, slots, page
allocator, page table, positions, stats) and the backend owns the device
caches and jit entry points.  ``backend="local"`` is the single-device
path; ``backend="pipelined"`` runs the same continuous-batching loop
through the ``N_S``-stage SPMD pipeline (``repro.core.pipeline``), where a
microbatch's decode tick enters the pipe at stage 0 and drains ``N_S − 1``
engine ticks later — the engine therefore applies decode results by the
microbatch id they carry, not the one it just injected.

KV placement follows §4.2: microbatch ``m`` draws overflow pages from global
pool ``G_{m%2}``; the :class:`repro.core.offload.DoubleBufferOffloader`
swaps the non-resident pool to host between ticks (on TPU this is the
HBM↔host DMA the paper overlaps with compute; on CPU it is an explicit copy
— same bookkeeping, same schedule).

Prefill is exact-length (rounded to a multiple of 8 for attention-only
archs) and one sequence at a time; decode is one jit over the microbatch's
``mb_size`` cache rows.  All jit entry points have static shapes.
"""

from __future__ import annotations

from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.common import Runtime
from repro.serving import kv_cache as kvc
from repro.serving.backend import DecodeResult, ExecutionBackend, make_backend
from repro.serving.request import (EngineStats, Request, SamplingParams,
                                   SequenceState, Status)
from repro.serving.sampler import sample


class OfflineEngine:
    def __init__(self, cfg: ModelConfig, params, rt: Runtime, *,
                 mb_size: int = 4, num_microbatches: int = 1,
                 pool: Optional[kvc.PoolConfig] = None,
                 sampling: Optional[SamplingParams] = None,
                 offloader=None, seed: int = 0,
                 backend="local", n_stages: int = 2, mesh=None):
        self.cfg = cfg
        self.params = params
        self.rt = rt
        self.mb_size = mb_size
        self.num_microbatches = num_microbatches
        self.batch = mb_size * num_microbatches
        self.pool = pool or kvc.PoolConfig()
        self.sampling = sampling or SamplingParams()
        self.key = jax.random.PRNGKey(seed)

        self.backend: ExecutionBackend = make_backend(
            backend, cfg, params, rt, mb_size=mb_size,
            num_microbatches=num_microbatches, pool=self.pool,
            sampling=self.sampling, offloader=offloader, n_stages=n_stages,
            mesh=mesh)

        self.alloc = kvc.PageAllocator(self.pool)
        self.table = np.zeros((self.batch, self.pool.max_pages_per_seq),
                              np.int32)
        self.cur_pos = np.zeros((self.batch,), np.int32)   # next position
        self.active = np.zeros((self.batch,), bool)
        self.slots: List[Optional[SequenceState]] = [None] * self.batch

        self.queue: deque = deque()
        self.finished: List[SequenceState] = []
        self.stats = EngineStats()

    # ------------------------------------------------------------------
    # planned construction (DeServe §4.3: N_B, batch, pools from the link)
    # ------------------------------------------------------------------

    @classmethod
    def from_plan(cls, cfg: ModelConfig, params, rt: Runtime, *,
                  n_stages: int, stage_time: float, latency: float,
                  m_kv_bytes: float, page_size: int = 16,
                  max_pages_per_seq: int = 16, bandwidth: float = 0.0,
                  use_offload: bool = True, max_microbatches: int = 64,
                  choice=None, mb_size_cap: int = 0, backend="local",
                  sampling: Optional[SamplingParams] = None, seed: int = 0,
                  mesh=None) -> "OfflineEngine":
        """Build an engine whose (N_B, per-microbatch batch, pool split) are
        *derived* from measured stage time + link latency via
        ``repro.core.scheduler.plan_schedule`` — the paper's planner —
        instead of hand-set flags.

        ``m_kv_bytes`` is the per-stage KV budget; ``choice`` may be a
        pre-computed :class:`repro.core.scheduler.ScheduleChoice` (then the
        planner is skipped and the choice is honored as-is).
        ``mb_size_cap`` bounds the per-microbatch batch for reduced/CPU
        runs where the planned batch would not fit the host.
        """
        from repro.core import offload as offload_lib
        from repro.core.scheduler import plan_schedule
        if not bandwidth:
            bandwidth = offload_lib.TPU_HOST_DMA_BW
        page_bytes = kvc.kv_bytes_per_page(
            cfg, kvc.PoolConfig(page_size=page_size),
            dtype_bytes=jnp.dtype(rt.compute_dtype).itemsize)
        if page_bytes == 0:
            raise ValueError(
                f"{cfg.name}: from_plan needs at least one paged-attention "
                "layer (pure-recurrent archs have no KV pools to plan)")
        kv_bytes_per_seq = page_bytes * max_pages_per_seq
        if choice is None:
            choice = plan_schedule(
                n_stages=n_stages, stage_time=stage_time, latency=latency,
                m_kv_bytes=m_kv_bytes, kv_bytes_per_seq=kv_bytes_per_seq,
                offload_bandwidth=bandwidth, use_offload=use_offload,
                max_microbatches=max_microbatches)
        if choice.offload:
            plan = offload_lib.OffloadPlan.derive(
                m_kv_bytes=m_kv_bytes, page_bytes=page_bytes,
                page_size=page_size, max_pages_per_seq=max_pages_per_seq,
                bandwidth=bandwidth, stage_time=stage_time,
                n_microbatches=choice.n_microbatches)
            pool = plan.pool
        else:
            pool = kvc.PoolConfig(
                page_size=page_size,
                n_local_pages=max(2, int(m_kv_bytes // page_bytes)),
                n_global_pages=0, max_pages_per_seq=max_pages_per_seq)
        mb_size = max(1, choice.per_mb_batch)
        if mb_size_cap:
            mb_size = min(mb_size, mb_size_cap)
        offloader = None
        if choice.offload and pool.n_global_pages:
            offloader = offload_lib.DoubleBufferOffloader(
                pool, choice.n_microbatches)
        eng = cls(cfg, params, rt, mb_size=mb_size,
                  num_microbatches=choice.n_microbatches, pool=pool,
                  sampling=sampling, offloader=offloader, seed=seed,
                  backend=backend, n_stages=n_stages, mesh=mesh)
        eng.schedule_choice = choice
        return eng

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, requests: List[Request]) -> None:
        cap = self.pool.max_pages_per_seq * self.pool.page_size
        for r in requests:          # validate all before enqueueing any,
            if len(r.prompt) >= cap:  # so a raise never half-admits a batch
                raise ValueError(
                    f"request {r.request_id}: prompt length {len(r.prompt)} "
                    f">= per-sequence KV capacity {cap} tokens "
                    f"(max_pages_per_seq={self.pool.max_pages_per_seq} x "
                    f"page_size={self.pool.page_size}) — no generation "
                    "budget would remain; raise max_pages_per_seq or "
                    "truncate the prompt")
        for r in requests:
            self.queue.append(SequenceState(request=r))

    def run(self, max_steps: int = 10_000) -> List[SequenceState]:
        for _ in range(max_steps):
            if not self.step():
                break
        return self.finished

    def step(self) -> bool:
        """One engine tick: reap finished, admit new, tick one microbatch
        through the backend.  Returns False when fully drained."""
        self._reap()
        self._admit()
        if not self.active.any() and not self.queue and \
                not self.backend.pending():
            return False
        mb = self.stats.steps % self.num_microbatches
        self._decode_microbatch(mb)
        self.stats.steps += 1
        return True

    # ------------------------------------------------------------------
    # slot management
    # ------------------------------------------------------------------

    def _mb_of_slot(self, slot: int) -> int:
        return slot // self.mb_size

    def _reap(self) -> None:
        changed = False
        for slot, seq in enumerate(self.slots):
            if seq is not None and seq.is_done():
                seq.status = Status.FINISHED
                self.finished.append(seq)
                self.stats.finished_requests += 1
                self.alloc.release(slot)
                self.slots[slot] = None
                self.active[slot] = False
                self.table[slot] = 0            # park on scratch page 0
                self.cur_pos[slot] = 0
                changed = True
        if changed:
            self.backend.set_page_table(self.table)

    def _admit(self) -> None:
        # microbatches with a tick in flight must not have their cache rows
        # (or page-table rows) rewritten under them — skip until drained
        busy = self.backend.busy_microbatches()
        for slot in range(self.batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            if self._mb_of_slot(slot) in busy:
                continue
            seq = self.queue.popleft()
            try:
                self._prefill_into_slot(seq, slot)
            except MemoryError:
                self.queue.appendleft(seq)      # retry when pages free up
                break

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------

    def _prefill_len(self, n: int) -> int:
        if self.cfg.recurrent_layer_count() > 0:
            return n                            # exact (state correctness)
        return max(8, (n + 7) // 8 * 8)

    def _prefill_into_slot(self, seq: SequenceState, slot: int) -> None:
        prompt = seq.request.prompt
        plen = len(prompt)
        total_budget = plen + seq.request.sampling.max_new_tokens
        n_pages = -(-min(total_budget,
                         self.pool.max_pages_per_seq * self.pool.page_size)
                    // self.pool.page_size)
        gp = self._mb_of_slot(slot) % 2 if self.pool.n_global_pages else None
        pages = self.alloc.allocate(slot, n_pages, global_pool=gp)
        self.table[slot] = self.alloc.table_row(slot)
        has_global = any(p >= self.pool.n_local_pages for p in pages)

        self.backend.reset_slot(slot)
        self.backend.set_page_table(self.table)

        # engine-side generation budget: never outgrow the page allocation
        seq.budget = min(seq.request.sampling.max_new_tokens,
                         self.pool.max_pages_per_seq * self.pool.page_size
                         - plen)
        lp = self._prefill_len(plen)
        toks = np.zeros((lp,), np.int32)
        toks[:plen] = prompt
        logits = self.backend.prefill(toks, slot, plen - 1,
                                      has_global_pages=has_global)
        self.key, sub = jax.random.split(self.key)
        first = int(sample(logits, sub, self.sampling))
        seq.generated.append(first)
        seq.slot = slot
        seq.status = Status.DECODING
        self.slots[slot] = seq
        self.active[slot] = True
        self.cur_pos[slot] = plen               # position of `first`
        self.stats.prefill_tokens += plen
        self.stats.decode_tokens += 1

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def _decode_microbatch(self, mb: int) -> None:
        lo = mb * self.mb_size
        hi = lo + self.mb_size
        mb_active = bool(self.active[lo:hi].any())
        if not mb_active and not self.backend.pending():
            return
        tokens = np.zeros((self.mb_size,), np.int32)
        for i, slot in enumerate(range(lo, hi)):
            seq = self.slots[slot]
            if seq is not None and seq.generated:
                tokens[i] = seq.generated[-1]
        self.key, sub = jax.random.split(self.key)
        results = self.backend.decode(mb, tokens, self.cur_pos[lo:hi], sub,
                                      active=mb_active)
        self.stats.swaps = self.backend.swap_count
        for res in results:
            self._apply_result(res)

    def _apply_result(self, res: DecodeResult) -> None:
        """Book one drained microbatch tick (possibly for an earlier
        microbatch than the one just injected — pipelined backends drain
        with N_S − 1 ticks of latency)."""
        lo = res.mb * self.mb_size
        for i, slot in enumerate(range(lo, lo + self.mb_size)):
            seq = self.slots[slot]
            if seq is None or seq.is_done():
                continue            # finished at prefill (eos/budget): reap
                                    # next tick, never extend
            seq.generated.append(int(res.tokens[i]))
            self.cur_pos[slot] += 1
            self.stats.decode_tokens += 1
            need = self.cur_pos[slot] + 1
            have = len(self.alloc.pages_of(slot)) * self.pool.page_size
            if need > have:
                gp = res.mb % 2 if self.pool.n_global_pages else None
                self.alloc.extend(slot, global_pool=gp)
                self.table[slot] = self.alloc.table_row(slot)
                self.backend.set_page_table(self.table)

    # ------------------------------------------------------------------

    def throughput_report(self) -> dict:
        return {
            "backend": self.backend.name,
            "prefill_tokens": self.stats.prefill_tokens,
            "decode_tokens": self.stats.decode_tokens,
            "total_tokens": self.stats.total_tokens,
            "finished": self.stats.finished_requests,
            "steps": self.stats.steps,
            "swaps": self.stats.swaps,
        }
