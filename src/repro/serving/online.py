"""Online (submit-while-running) serving front end.

:class:`OnlineLLM` turns the pull-based offline engine into a live
service: ``submit()`` may be called at any time — including while the
engine loop is mid-drain — and returns a :class:`RequestStream` that
yields tokens *per engine tick*, not after the batch finishes.  The
continuous-batching admission already supports joining a live loop (the
queue is drained into free slots every tick), so the front end is pure
orchestration: an admission inbox, a per-request delivery cursor, and an
optional background pump thread.

Two pump modes, one delivery surface:

* **cooperative** (default): no thread.  A consumer blocking on
  ``stream.next_event()`` drives ``OnlineLLM.step()`` inline until its
  event arrives — single-threaded, deterministic, what the tests and the
  Poisson bench use.
* **threaded**: ``start()`` launches a daemon pump; ``submit()`` from any
  thread wakes it, consumers block on a condition variable.  ``close()``
  stops the pump.  (An ``async for`` adapter rides on top via
  ``RequestStream.__aiter__`` — the blocking ``next_event`` runs in the
  event loop's default executor.)

Token streams are **bit-identical to offline** ``LLM.generate``: every
token is a function of ``(seed, request_id, token_idx)`` only, so
arrival timing, admission order, and prefix-cache hits change *when* a
token is delivered, never *which* token.

Latency accounting: each :class:`StreamEvent` is stamped when the pump
books it (serving-side delivery, the number an operator's SLO sees);
``RequestStream.ttft_s`` / ``inter_token_s()`` derive p50/p99-able
samples from those stamps.  The engine additionally stamps
``SequenceState.first_token_time`` when the token is *sampled*.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

from repro.serving.llm import LLM, EngineConfig, RequestOutput
from repro.serving.request import SamplingParams, SequenceState, Status

__all__ = ["OnlineLLM", "RequestStream", "StreamEvent"]


@dataclass(frozen=True)
class StreamEvent:
    """One delivered token of one online request."""
    request_id: int
    index: int                  # token index in the generated stream
    token: int
    time: float                 # perf_counter at delivery (pump-side)
    finished: bool = False      # True on the request's last token
    finish_reason: Optional[str] = None


class RequestStream:
    """Per-request token stream handed back by :meth:`OnlineLLM.submit`.

    Iterate it (sync ``for`` or ``async for``) or call
    :meth:`next_event` directly; ``None``/StopIteration marks the end of
    the stream.  With no pump thread running, the consumer itself steps
    the engine (cooperative mode)."""

    def __init__(self, online: "OnlineLLM", request_id: int,
                 prompt: List[int]):
        self._online = online
        self.request_id = request_id
        self.prompt = prompt
        self.seq: Optional[SequenceState] = None    # bound at admission
        self.submit_time = time.perf_counter()
        self._events: deque = deque()
        self._cv = threading.Condition()
        self._closed = False
        self._tokens: List[int] = []
        self._event_times: List[float] = []
        self.first_token_time: Optional[float] = None

    # -- producer side (pump) -------------------------------------------

    def _push(self, ev: StreamEvent) -> None:
        with self._cv:
            if self.first_token_time is None:
                self.first_token_time = ev.time
            self._event_times.append(ev.time)
            self._tokens.append(ev.token)
            self._events.append(ev)
            if ev.finished:
                self._closed = True
            self._cv.notify_all()

    # -- consumer side ----------------------------------------------------

    def next_event(self, timeout: Optional[float] = None
                   ) -> Optional[StreamEvent]:
        """Next :class:`StreamEvent`, or ``None`` when the stream is
        complete.  Blocks (threaded pump) or steps the engine inline
        (cooperative mode) until one is available."""
        deadline = None if timeout is None else \
            time.perf_counter() + timeout
        while True:
            with self._cv:
                if self._events:
                    return self._events.popleft()
                if self._closed:
                    return None
                if self._online._thread is not None:
                    wait = 0.1 if deadline is None else \
                        deadline - time.perf_counter()
                    if wait <= 0 or not self._cv.wait(timeout=wait):
                        if deadline is not None and \
                                time.perf_counter() >= deadline:
                            raise TimeoutError(
                                f"request {self.request_id}: no token "
                                f"within {timeout}s")
                    continue
            # cooperative: drive the shared engine until our event lands
            if not self._online.step():
                with self._cv:
                    if self._events or self._closed:
                        continue
                raise RuntimeError(
                    f"request {self.request_id}: engine drained with the "
                    "stream still open (was the engine aborted?)")

    def __iter__(self):
        while True:
            ev = self.next_event()
            if ev is None:
                return
            yield ev

    def __aiter__(self):
        return self._agen()

    async def _agen(self):
        import asyncio
        loop = asyncio.get_running_loop()
        while True:
            ev = await loop.run_in_executor(None, self.next_event)
            if ev is None:
                return
            yield ev

    # -- results / metrics ------------------------------------------------

    @property
    def finished(self) -> bool:
        with self._cv:
            return self._closed and not self._events

    def tokens(self) -> List[int]:
        """Tokens delivered so far (grows as the stream advances)."""
        with self._cv:
            return list(self._tokens)

    def result(self) -> RequestOutput:
        """Drain the stream to completion and return the final
        :class:`RequestOutput` — the online counterpart of
        ``LLM.generate``'s return value."""
        for _ in self:
            pass
        assert self.seq is not None
        # the engine reaps a finished sequence on the tick AFTER its last
        # token (freeing the slot + stamping finish_time/status); make
        # sure that bookkeeping ran before snapshotting the output
        while self.seq.status is not Status.FINISHED and self._online.step():
            pass
        return RequestOutput.from_seq(
            self.seq,
            trace=self._online.engine.request_trace(self.request_id))

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit-to-first-delivered-token, pump-side (None until then)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    def inter_token_s(self) -> List[float]:
        """Deltas between consecutive delivery stamps (ITL samples)."""
        with self._cv:
            ts = list(self._event_times)
        return [b - a for a, b in zip(ts, ts[1:])]


class OnlineLLM:
    """Submit-while-running front end over :class:`repro.serving.llm.LLM`.

        online = OnlineLLM("yi-9b", config=EngineConfig(prefix_cache=True))
        s1 = online.submit(prompt_a)            # joins the live loop
        s2 = online.submit(prompt_b)            # ... at any time
        for ev in s1:                           # tokens per tick
            print(ev.token, ev.finished)
        out = s2.result()                       # drain to a RequestOutput

    Pass ``llm=`` to wrap an existing engine instead of building one.
    Thread-safe: ``submit`` may be called from any thread; engine
    stepping is serialised by an internal lock."""

    def __init__(self, model=None, *,
                 config: Optional[EngineConfig] = None, params=None,
                 rt=None, reduced: bool = True,
                 llm: Optional[LLM] = None):
        if llm is None:
            if model is None:
                raise ValueError("OnlineLLM needs a model (arch name / "
                                 "ModelConfig) or an existing llm=")
            llm = LLM(model, config=config, params=params, rt=rt,
                      reduced=reduced)
        self.llm = llm
        self.engine = llm.engine
        self._inbox: deque = deque()            # (Request, RequestStream)
        self._streams: Dict[int, RequestStream] = {}
        self._delivered: Dict[int, int] = {}
        self._lock = threading.Lock()           # inbox + stream registry
        self._step_lock = threading.Lock()      # serialises engine access
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._stop = False

    # -- submission --------------------------------------------------------

    def submit(self, prompt: Sequence[int],
               sampling: Union[SamplingParams, None] = None
               ) -> RequestStream:
        """Enqueue one prompt into the live loop; returns its stream.
        Request ids are assigned in submission order (the same counter as
        ``LLM.generate``), so a given arrival order reproduces the exact
        offline token streams."""
        with self._lock:
            req = self.llm._make_requests(
                [prompt], sampling if sampling is None else [sampling])[0]
            stream = RequestStream(self, req.request_id, req.prompt)
            self._inbox.append((req, stream))
            self._streams[req.request_id] = stream
            self._delivered[req.request_id] = 0
        self._wake.set()
        return stream

    # -- pump --------------------------------------------------------------

    def step(self) -> bool:
        """One pump iteration: admit queued submissions, advance the
        engine one tick, deliver newly generated tokens to their streams.
        Returns True while any work remains."""
        with self._step_lock:
            self._drain_inbox()
            alive = self.engine.step()
            self._dispatch()
        with self._lock:
            return alive or bool(self._inbox)

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Cooperative drain (no thread): step until nothing is pending.
        Returns the number of steps taken."""
        steps = 0
        while steps < max_steps and self.step():
            steps += 1
        return steps

    def _drain_inbox(self) -> None:
        with self._lock:
            items = list(self._inbox)
            self._inbox.clear()
        rec = self.engine.recorder
        for req, stream in items:
            stream.seq = self.engine.submit([req])[0]
            if rec is not None:
                # the stream's own submit stamp — the float its ttft_s
                # subtracts — so trace TTFT matches the stream bitwise
                rec.request_stream_submit(req.request_id,
                                          stream.submit_time)

    def _dispatch(self) -> None:
        now = time.perf_counter()
        rec = self.engine.recorder
        with self._lock:
            live = list(self._streams.items())
        done: List[int] = []
        for rid, stream in live:
            seq = stream.seq
            if seq is None:
                continue
            n = len(seq.generated)
            d = self._delivered[rid]
            if d >= n:
                continue
            if rec is not None:
                # every event pushed this tick carries the same ``now``
                # stamp, so recording it once per request keeps the trace
                # delivery times bitwise equal to the stream's
                rec.request_delivery(rid, now, n - d)
            fin = seq.is_done()
            reason = seq.finish_reason()
            while d < n:
                last = fin and d == n - 1
                stream._push(StreamEvent(
                    request_id=rid, index=d, token=seq.generated[d],
                    time=now, finished=last,
                    finish_reason=reason.value if last and reason else None))
                d += 1
            self._delivered[rid] = d
            if fin:
                done.append(rid)
        if done:
            with self._lock:
                for rid in done:
                    self._streams.pop(rid, None)
                    self._delivered.pop(rid, None)

    # -- threaded pump -----------------------------------------------------

    def start(self) -> "OnlineLLM":
        """Launch the background pump thread.  Consumers then block on
        delivery instead of stepping the engine themselves."""
        if self._thread is None:
            self._stop = False
            self._thread = threading.Thread(
                target=self._pump, name="online-pump", daemon=True)
            self._thread.start()
        return self

    def _pump(self) -> None:
        while not self._stop:
            if not self.step():
                # idle: sleep until a submit wakes us (short timeout so
                # close() is prompt even without a wake)
                self._wake.clear()
                self._wake.wait(timeout=0.05)

    def close(self, timeout: float = 10.0) -> None:
        """Stop the pump thread (no-op in cooperative mode)."""
        self._stop = True
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def __enter__(self) -> "OnlineLLM":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reporting ---------------------------------------------------------

    def stats(self) -> dict:
        return self.engine.throughput_report()
