"""Per-request generation front end: ``LLM`` / ``EngineConfig`` /
``RequestOutput``.

This is the serving surface callers program against — the engine
(:class:`repro.serving.engine.OfflineEngine`) stays the scheduling core,
but nobody should have to scrape ``SequenceState`` internals or hand-wire
pools/offloaders/backends:

    llm = LLM("yi-9b", config=EngineConfig(mb_size=2, num_microbatches=2))
    outs = llm.generate(prompts, SamplingParams(temperature=0.8, top_p=0.95))
    for o in outs:
        print(o.request_id, o.finish_reason, o.token_ids)

Sampling params are **per request**: ``generate`` accepts one
``SamplingParams`` for all prompts or one per prompt, and a single engine
run serves greedy and sampled requests side by side in the same
continuously-batched pipe.  Outputs are reproducible functions of
``(config.seed, request_id)`` across backends, microbatch layout, and
admission order.

``EngineConfig`` consolidates the engine's construction knobs into one
validated object; ``EngineConfig.plan(...)`` carries the §4.3 planner
arguments (measured stage time + link latency → N_B / batch / pools) and
subsumes ``OfflineEngine.from_plan``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Union

from repro.config import ModelConfig, get_arch, reduced_config
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import (FinishReason, Request, SamplingParams,
                                   SequenceState, Status)

_BACKENDS = ("local", "pipelined")


@dataclass
class EngineConfig:
    """Everything needed to build an :class:`OfflineEngine`, validated.

    Either set the knobs directly, or build one via :meth:`plan` to derive
    (``num_microbatches``, ``mb_size``, pool split) from a measured stage
    time + link latency through the paper's §4.3 planner.
    """
    mb_size: int = 4                  # sequences per microbatch
    num_microbatches: int = 1         # N_B
    pool: Optional[PoolConfig] = None
    offload: bool = True              # double-buffer the global pools
                                      # (no-op when the pool has none)
    backend: str = "local"            # "local" | "pipelined"
    n_stages: int = 2                 # N_S (pipelined backend)
    seed: int = 0
    mesh: Optional[object] = None
    # chunked prefill: prompts are admitted in budgeted chunks interleaved
    # with decode ticks (fully-paged archs; recurrent/sliding-window archs
    # fall back to exact-length prefill).  0 = derive a default — 32
    # tokens, or ~the planned per-microbatch batch under .plan() so one
    # chunk costs <= one decode tick of model FLOPs.
    prefill_chunk: int = 0            # tokens per chunk (0 = auto)
    max_prefill_tokens_per_tick: int = 0   # per-tick budget (0 = one chunk)
    prefill_mode: str = "auto"        # "auto" | "chunked" | "exact"
    # deterministic fault injection (tests / drills): a
    # repro.distributed.elastic.FaultPlan consumed by the pipelined
    # backend — dropped ticks are re-injected by the engine, outputs stay
    # bit-identical to an undisturbed run
    fault_plan: Optional[object] = None
    # inter-stage link seam (pipelined backend): a
    # repro.distributed.transport.Transport instance, a DeploymentPlan,
    # or a float (uniform simulated one-way latency in seconds).  None =
    # InProcessTransport, today's zero-cost shard_map links.  Simulated
    # links never touch the computation — outputs stay bit-identical —
    # they account per-link latency/bandwidth on a virtual clock.
    transport: Optional[object] = None
    # "circular" is DeServe §4.3 (the default); "round_flush" reproduces
    # the vLLM-PP baseline (pipe drained every token round) for the
    # latency-curve comparison
    schedule: str = "circular"
    # wire codec for the inter-stage activation payload (pipelined
    # backend): "fp32" ships raw compute-dtype activations (bit-identical
    # outputs); "int8" quantizes per row INSIDE the tick jits — one f32
    # scale per row travels with the payload — ~4x fewer bytes on every
    # ring link at a bounded logit perturbation.  The backend wraps a
    # bookkeeping transport in CompressedTransport so the accounted wire
    # bytes equal the packed payload.  (top-k has no in-jit path: it
    # remains accounting-only via CompressedTransport(method="topk").)
    wire_dtype: str = "fp32"
    # runtime invariant auditor (repro.analysis.invariants): audits page
    # accounting, the Status FSM, transport books, and jit cache sizes
    # after every submit/step/reshard, raising InvariantViolation at the
    # tick that corrupted state.  None = follow the REPRO_STRICT
    # environment variable (the test suite defaults it on); True/False
    # force it either way.  Host-side bookkeeping only — no device syncs.
    strict: Optional[bool] = None
    # paged-decode attention: KV pages streamed per Pallas grid step
    # (0 = autotuned from the (page_size, Dh, G) shape; see
    # repro.kernels.paged_attention.tuned_pages_per_block)
    attn_pages_per_block: int = 0
    # decode-tick sampling epilogue: replace the full-vocab sort with one
    # lax.top_k partition when every sampled row's top_k fits the cap —
    # bit-identical outputs either way (ineligible ticks fall back in-jit)
    sample_fast_path: bool = True
    # §4.2 offload swaps: keep the departing microbatch's host copy as a
    # lazy device future (D2H overlaps the next tick) instead of a
    # blocking numpy materialisation at the tick boundary
    offload_async: bool = True
    # online serving: share fully-prefilled prompt blocks across requests
    # with a common prefix (refcounted paged-KV sharing — a prefix hit
    # adopts the cached pages and starts prefill past them).  Requires
    # chunked prefill; local pages only (global pools parity-swap).
    prefix_cache: bool = False
    # latency-SLO admission shaping (repro.serving.engine.SLOConfig):
    # sheds the per-tick prefill token budget while smoothed tick time
    # exceeds the inter-token target, restores it when the oldest queued
    # request nears the TTFT target.  None = no shaping.
    slo: Optional[object] = None
    # flight recorder (repro.obs): None/False = off (zero-cost — the hot
    # path carries no recorder), True = default-capacity TraceRecorder,
    # int = ring capacity, or an existing TraceRecorder instance.  The
    # recorder threads through backend/transport/offloader and surfaces
    # as engine.recorder (export via repro.obs.write_chrome_trace) and
    # per-request as RequestOutput.trace.
    trace: object = None
    plan_args: Optional[dict] = None  # set by .plan(); overrides mb_size /
                                      # num_microbatches / pool / offload

    def __post_init__(self) -> None:
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, "
                             f"got {self.backend!r}")
        if self.mb_size < 1:
            raise ValueError(f"mb_size must be >= 1, got {self.mb_size}")
        if self.num_microbatches < 1:
            raise ValueError("num_microbatches must be >= 1, "
                             f"got {self.num_microbatches}")
        if self.n_stages < 1:
            raise ValueError(f"n_stages must be >= 1, got {self.n_stages}")
        if self.prefill_mode not in ("auto", "chunked", "exact"):
            raise ValueError("prefill_mode must be 'auto'|'chunked'|'exact'"
                             f", got {self.prefill_mode!r}")
        if self.prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, "
                             f"got {self.prefill_chunk}")
        if self.max_prefill_tokens_per_tick < 0:
            raise ValueError("max_prefill_tokens_per_tick must be >= 0, "
                             f"got {self.max_prefill_tokens_per_tick}")
        if self.prefill_chunk and self.max_prefill_tokens_per_tick and \
                self.max_prefill_tokens_per_tick < self.prefill_chunk:
            raise ValueError(
                f"max_prefill_tokens_per_tick="
                f"{self.max_prefill_tokens_per_tick} < prefill_chunk="
                f"{self.prefill_chunk}: the per-tick budget must fit at "
                "least one chunk")
        if self.plan_args is None and self.backend == "pipelined" \
                and self.num_microbatches < self.n_stages:
            raise ValueError(
                f"pipelined backend needs num_microbatches >= n_stages "
                f"(N_B >= N_S), got N_B={self.num_microbatches} < "
                f"N_S={self.n_stages}")
        if self.fault_plan is not None and self.backend != "pipelined":
            raise ValueError(
                "fault_plan requires backend='pipelined' — the local "
                "backend has no stages to drop")
        if self.schedule not in ("circular", "round_flush"):
            raise ValueError("schedule must be 'circular'|'round_flush', "
                             f"got {self.schedule!r}")
        if self.wire_dtype not in ("fp32", "int8"):
            raise ValueError("wire_dtype must be 'fp32'|'int8', got "
                             f"{self.wire_dtype!r} (top-k stays wire-byte "
                             "accounting only — no in-jit codec)")
        if self.backend != "pipelined" and (self.transport is not None or
                                            self.schedule != "circular" or
                                            self.wire_dtype != "fp32"):
            raise ValueError(
                "transport / schedule / wire_dtype require "
                "backend='pipelined' — the local backend has no stage "
                "boundaries for a link to cross")
        if self.attn_pages_per_block < 0:
            raise ValueError("attn_pages_per_block must be >= 0 (0 = "
                             f"autotuned), got {self.attn_pages_per_block}")
        if self.prefix_cache and self.prefill_mode == "exact":
            raise ValueError(
                "prefix_cache=True needs chunked prefill (a prefix hit "
                "resumes prefill mid-prompt via the chunk cursor) — "
                "prefill_mode='exact' cannot share prompt blocks")

    @classmethod
    def plan(cls, *, n_stages: Optional[int] = None,
             stage_time: float, latency: Optional[float] = None,
             m_kv_bytes: float, page_size: int = 16,
             max_pages_per_seq: int = 16, bandwidth: float = 0.0,
             use_offload: bool = True, max_microbatches: int = 64,
             choice=None, mb_size_cap: int = 0, backend: str = "local",
             seed: int = 0, mesh=None, prefill_chunk: int = 0,
             max_prefill_tokens_per_tick: int = 0,
             prefill_mode: str = "auto",
             fault_plan: Optional[object] = None,
             deployment: Optional[object] = None,
             transport: Optional[object] = None,
             schedule: str = "circular",
             wire_dtype: str = "fp32",
             prefix_cache: bool = False,
             slo: Optional[object] = None,
             trace: object = None,
             strict: Optional[bool] = None) -> "EngineConfig":
        """A config whose (N_B, per-microbatch batch, pool split) are
        derived by ``repro.core.scheduler.plan_schedule`` at build time —
        the planned counterpart of hand-set knobs (subsumes
        ``OfflineEngine.from_plan``).  ``prefill_chunk=0`` derives the
        chunk from the plan: ~the per-microbatch decode batch, so one
        chunk costs at most one decode tick of stage time — shrunk
        further on a bandwidth-capped deployment so one chunk's wire
        time also fits a stage tick (the thin-link rule; see
        ``serving.engine.prefill_chunk_cap``).

        ``deployment`` — a :class:`repro.distributed.transport
        .DeploymentPlan` (e.g. from ``framework.registry.match``):
        supplies ``n_stages`` (its stage count), ``latency`` (its
        **max ring-link latency** — the slowest link sets the §4.3
        bubble budget, replacing a scalar guess) plus the full per-link
        ``link_latencies`` the planner now consumes, the worst
        ``LinkSpec`` that caps the prefill chunk, and, on the pipelined
        backend, a per-link :class:`SimulatedLinkTransport` unless an
        explicit ``transport`` is given."""
        link_latencies = worst_link = None
        if deployment is not None:
            if n_stages is None:
                n_stages = deployment.n_stages
            if latency is None:
                latency = deployment.max_link_latency
            if transport is None and backend == "pipelined":
                transport = deployment.transport()
            link_latencies = list(deployment.link_latencies)
            worst_link = deployment.worst_link
        if n_stages is None or latency is None:
            raise ValueError("EngineConfig.plan needs n_stages= and "
                             "latency= (or a deployment= plan supplying "
                             "both)")
        return cls(backend=backend, n_stages=n_stages, seed=seed, mesh=mesh,
                   prefill_chunk=prefill_chunk,
                   max_prefill_tokens_per_tick=max_prefill_tokens_per_tick,
                   prefill_mode=prefill_mode, fault_plan=fault_plan,
                   transport=transport, schedule=schedule,
                   wire_dtype=wire_dtype, prefix_cache=prefix_cache,
                   slo=slo, trace=trace, strict=strict,
                   plan_args=dict(
                       n_stages=n_stages, stage_time=stage_time,
                       latency=latency, link_latencies=link_latencies,
                       worst_link=worst_link, m_kv_bytes=m_kv_bytes,
                       page_size=page_size,
                       max_pages_per_seq=max_pages_per_seq,
                       bandwidth=bandwidth, use_offload=use_offload,
                       max_microbatches=max_microbatches, choice=choice,
                       mb_size_cap=mb_size_cap))

    def build(self, cfg: ModelConfig, params, rt) -> OfflineEngine:
        """Construct the engine this config describes."""
        if self.attn_pages_per_block and \
                rt.attn_pages_per_block != self.attn_pages_per_block:
            rt = rt.replace(attn_pages_per_block=self.attn_pages_per_block)
        if self.plan_args is not None:
            return OfflineEngine.from_plan(
                cfg, params, rt, backend=self.backend, seed=self.seed,
                mesh=self.mesh, prefill_chunk=self.prefill_chunk,
                max_prefill_tokens_per_tick=self.max_prefill_tokens_per_tick,
                prefill_mode=self.prefill_mode, fault_plan=self.fault_plan,
                transport=self.transport, schedule=self.schedule,
                wire_dtype=self.wire_dtype,
                sample_fast_path=self.sample_fast_path,
                offload_async=self.offload_async,
                prefix_cache=self.prefix_cache, slo=self.slo,
                trace=self.trace, strict=self.strict,
                **self.plan_args)
        pool = self.pool or PoolConfig()
        offloader = None
        if self.offload and pool.n_global_pages:
            from repro.core.offload import DoubleBufferOffloader
            offloader = DoubleBufferOffloader(pool, self.num_microbatches,
                                              async_swap=self.offload_async)
        return OfflineEngine(
            cfg, params, rt, mb_size=self.mb_size,
            num_microbatches=self.num_microbatches, pool=pool,
            offloader=offloader, seed=self.seed, backend=self.backend,
            n_stages=self.n_stages, mesh=self.mesh,
            prefill_chunk=self.prefill_chunk,
            max_prefill_tokens_per_tick=self.max_prefill_tokens_per_tick,
            prefill_mode=self.prefill_mode, fault_plan=self.fault_plan,
            transport=self.transport, schedule=self.schedule,
            wire_dtype=self.wire_dtype,
            sample_fast_path=self.sample_fast_path,
            offload_async=self.offload_async,
            prefix_cache=self.prefix_cache, slo=self.slo,
            trace=self.trace, strict=self.strict)


@dataclass
class RequestOutput:
    """What a caller gets back for one request — no engine internals."""
    request_id: int
    prompt: List[int]
    token_ids: List[int]              # generated tokens so far
    finished: bool
    finish_reason: Optional[str]      # "eos" | "length" | "page_budget";
                                      # None while in flight / aborted
    status: str                       # Status value ("queued", ...)
    logprobs: Optional[List[float]] = None    # per token, if requested
    latency_steps: Optional[int] = None       # submit -> finish, engine steps
    latency_s: Optional[float] = None         # submit -> finish, wall clock
    ttft_s: Optional[float] = None            # submit -> first token sampled
    # per-request flight-recorder snapshot (EngineConfig(trace=...) on):
    # queue_wait_s / ttft_s / inter_token_s, chunks, pages,
    # prefix_hit_tokens — None when tracing is off
    trace: Optional[dict] = None

    @classmethod
    def from_seq(cls, seq: SequenceState,
                 trace: Optional[dict] = None) -> "RequestOutput":
        reason = seq.finish_reason()
        return cls(
            request_id=seq.request.request_id,
            prompt=list(seq.request.prompt),
            token_ids=list(seq.generated),
            finished=seq.status is Status.FINISHED,
            finish_reason=reason.value if reason is not None and
            seq.status is Status.FINISHED else None,
            status=seq.status.value,
            logprobs=list(seq.logprobs) if seq.logprobs is not None else None,
            latency_steps=seq.latency_steps,
            latency_s=seq.latency_s,
            ttft_s=seq.ttft_s,
            trace=trace)


class LLM:
    """Front door for offline generation over the DeServe engine.

    ``model`` is an arch name (``"yi-9b"``) or a :class:`ModelConfig`.
    By default the registered arch is shrunk with ``reduced_config`` (CPU
    scale) and parameters are randomly initialised from ``config.seed``;
    pass ``reduced=False`` and/or ``params=`` for real deployments.
    """

    def __init__(self, model: Union[str, ModelConfig], *,
                 config: Optional[EngineConfig] = None, params=None,
                 rt=None, reduced: bool = True):
        import jax
        import jax.numpy as jnp

        from repro.models import model as model_lib
        from repro.models.common import Runtime

        cfg = get_arch(model) if isinstance(model, str) else model
        if reduced and isinstance(model, str):
            cfg = reduced_config(cfg)
        self.config = config or EngineConfig()
        self.cfg = cfg
        self.rt = rt or Runtime(param_dtype=jnp.float32,
                                compute_dtype=jnp.float32)
        if params is None:
            params = model_lib.init_params(
                cfg, jax.random.PRNGKey(self.config.seed), self.rt)
        self.params = params
        self.engine = self.config.build(cfg, params, self.rt)
        self._next_id = 0

    # ------------------------------------------------------------------

    def _make_requests(self, prompts: Sequence[Sequence[int]],
                       sampling_params) -> List[Request]:
        if sampling_params is None:
            sampling_params = self.engine.default_sampling
        if isinstance(sampling_params, SamplingParams):
            sampling_params = [sampling_params] * len(prompts)
        if len(sampling_params) != len(prompts):
            raise ValueError(
                f"got {len(prompts)} prompts but "
                f"{len(sampling_params)} sampling_params — pass one "
                "SamplingParams, or exactly one per prompt")
        reqs = []
        for p, sp in zip(prompts, sampling_params):
            reqs.append(Request(self._next_id, [int(t) for t in p],
                                dataclasses.replace(sp)))
            self._next_id += 1
        return reqs

    def generate(self, prompts: Sequence[Sequence[int]],
                 sampling_params: Union[SamplingParams,
                                        Sequence[SamplingParams],
                                        None] = None, *,
                 max_steps: int = 100_000) -> List[RequestOutput]:
        """Generate to completion for every prompt; returns one
        :class:`RequestOutput` per prompt, in prompt order.  If
        ``max_steps`` is exhausted first, in-flight outputs come back with
        ``finished=False`` (and ``engine.stats.aborted`` is set)."""
        seqs = self._submit(prompts, sampling_params)
        self.engine.run(max_steps=max_steps)
        return [RequestOutput.from_seq(
            s, trace=self.engine.request_trace(s.request.request_id))
            for s in seqs]

    def generate_iter(self, prompts: Sequence[Sequence[int]],
                      sampling_params: Union[SamplingParams,
                                             Sequence[SamplingParams],
                                             None] = None, *,
                      max_steps: int = 100_000
                      ) -> Iterator[List[RequestOutput]]:
        """Streaming form: yields the full output snapshot (finished and
        in-flight requests, prompt order) after every engine step, then a
        final snapshot.  Mirrors ``run()``'s drain surfacing: exhausting
        ``max_steps`` with work pending sets ``engine.stats.aborted``."""
        seqs = self._submit(prompts, sampling_params)
        self.engine.stats.aborted = False
        steps = 0
        while steps < max_steps and self.engine.step():
            steps += 1
            yield [RequestOutput.from_seq(s) for s in seqs]
        if steps >= max_steps and self.engine.pending():
            self.engine.stats.aborted = True
        # only the final snapshot carries per-request traces (the
        # per-step snapshots stay cheap)
        yield [RequestOutput.from_seq(
            s, trace=self.engine.request_trace(s.request.request_id))
            for s in seqs]

    def _submit(self, prompts, sampling_params) -> List[SequenceState]:
        reqs = self._make_requests(prompts, sampling_params)
        return self.engine.submit(reqs)

    # ------------------------------------------------------------------

    def stats(self) -> Dict:
        return self.engine.throughput_report()


def __getattr__(name):
    # lazy re-exports so `from repro.serving.llm import OnlineLLM` works
    # without importing threading machinery on the offline path
    if name in ("OnlineLLM", "RequestStream", "StreamEvent"):
        from repro.serving import online
        return getattr(online, name)
    if name == "SLOConfig":
        from repro.serving.engine import SLOConfig
        return SLOConfig
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["LLM", "EngineConfig", "RequestOutput", "SamplingParams",
           "FinishReason", "OnlineLLM", "RequestStream", "StreamEvent",
           "SLOConfig"]
