"""Paged KV cache: shared page pools + host-side page-table allocator.

This is the DeServe §4.2 memory layout (Figure 3), adapted to the TPU memory
hierarchy.  The page id space of each attention layer's pool is partitioned:

      [0, n_local)                          — local pools (never offloaded)
      [n_local, n_local + n_global)         — global pool G0
      [n_local + n_global, n_local + 2·n_global) — global pool G1

Microbatch ``m`` allocates its overflow pages from global pool ``G_{m % 2}``;
the complementary pool is swapped to host memory by the double-buffer
offloader (``repro.core.offload``) while the resident one feeds compute.

Device-side state is a cache pytree compatible with ``repro.models.model``:
attention layers get ``{"k_pages","v_pages","page_table", ...}`` (pools
stacked over scan periods), sliding-window layers keep bounded dense rings,
recurrent layers keep O(1) states.  Bookkeeping (free lists, per-sequence
page lists) is host-side Python — identical to vLLM's split of concerns.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ATTN_KINDS, ModelConfig
from repro.models.common import Runtime, make_layer_plan
from repro.models.model import _kind_cache


@dataclass(frozen=True)
class PoolConfig:
    page_size: int = 16
    n_local_pages: int = 64           # shared by all microbatches' local pools
    n_global_pages: int = 0           # per global pool (2 pools total)
    max_pages_per_seq: int = 16

    @property
    def n_pages(self) -> int:
        return self.n_local_pages + 2 * self.n_global_pages

    def global_range(self, pool_id: int) -> range:
        s = self.n_local_pages + pool_id * self.n_global_pages
        return range(s, s + self.n_global_pages)


class PageAllocator:
    """Host-side free-list allocator over the partitioned page id space.

    Page 0 is reserved as a *scratch* page: released slots' page tables point
    at it, so the (masked, harmless) decode writes of inactive slots can
    never corrupt pages that have been reallocated to live sequences.

    Pages are **refcounted**: a page may be owned by several slots at once
    (prefix caching shares fully-prefilled prompt blocks) and retained by
    the :class:`PrefixCache` on top, so ``release`` *decrements* — a page
    only returns to its free list when the last owner lets go.  Returning
    a page that is already free, or releasing a slot that owns nothing,
    raises: a silent double-free would eventually grant one page to two
    live sequences."""

    def __init__(self, pool: PoolConfig):
        self.pool = pool
        assert pool.n_local_pages >= 2, "need >= 2 local pages (page 0 is scratch)"
        self._free_local: List[int] = list(range(1, pool.n_local_pages))
        self._free_global: Dict[int, List[int]] = {
            0: list(pool.global_range(0)),
            1: list(pool.global_range(1)),
        }
        self._seq_pages: Dict[int, List[int]] = {}
        # page -> owner count (slots listing it + one per cache retain);
        # a page is in _refs iff it is NOT on a free list
        self._refs: Dict[int, int] = {}

    # -- queries ------------------------------------------------------------

    def free_local(self) -> int:
        return len(self._free_local)

    def free_global(self, pool_id: int) -> int:
        return len(self._free_global[pool_id])

    def pages_of(self, slot: int) -> List[int]:
        return list(self._seq_pages.get(slot, ()))

    def refcount(self, p: int) -> int:
        return self._refs.get(p, 0)

    # -- allocation ---------------------------------------------------------

    def allocate(self, slot: int, n_pages: int, *,
                 global_pool: Optional[int] = None) -> List[int]:
        """Allocate ``n_pages`` for ``slot``: local pages first, overflow from
        ``global_pool`` (if given).  Raises MemoryError when exhausted."""
        got: List[int] = []
        while len(got) < n_pages and self._free_local:
            got.append(self._free_local.pop())
        while len(got) < n_pages and global_pool is not None and \
                self._free_global[global_pool]:
            got.append(self._free_global[global_pool].pop())
        if len(got) < n_pages:
            for p in got:        # roll back (never granted, refs never set)
                self._give_back(p)
            raise MemoryError(
                f"page pool exhausted: need {n_pages}, got {len(got)} "
                f"(local free={self.free_local()}, "
                f"global={ {i: self.free_global(i) for i in (0, 1)} })")
        for p in got:
            self._refs[p] = 1
        self._seq_pages.setdefault(slot, []).extend(got)
        return got

    def extend(self, slot: int, *, global_pool: Optional[int] = None) -> int:
        return self.allocate(slot, 1, global_pool=global_pool)[0]

    def adopt(self, slot: int, pages: List[int]) -> None:
        """Bind already-owned ``pages`` to ``slot`` as a *shared* prefix:
        each page's refcount is incremented, never re-granted from a free
        list.  Must run before ``allocate`` for the slot so the shared
        pages head its table row (page ``i`` maps positions
        ``[i*page_size, (i+1)*page_size)``)."""
        if self._seq_pages.get(slot):
            raise ValueError(
                f"adopt: slot {slot} already owns pages "
                f"{self._seq_pages[slot]} — shared prefix pages must come "
                "first in the table row")
        for p in pages:
            if p not in self._refs:
                raise ValueError(
                    f"adopt: page {p} is not currently owned — a free "
                    "page cannot be shared (stale prefix-cache entry?)")
        for p in pages:
            self._refs[p] += 1
        self._seq_pages[slot] = list(pages)

    def retain(self, p: int) -> None:
        """Add a non-slot owner (the prefix cache) to an owned page."""
        if p not in self._refs:
            raise ValueError(f"retain: page {p} is not currently owned")
        self._refs[p] += 1

    def drop(self, p: int) -> bool:
        """Release a ``retain`` reference; returns True when the page
        actually went back to its free list (last owner)."""
        return self._decref(p)

    def release(self, slot: int) -> None:
        """Release every page ``slot`` owns (decrement — shared pages stay
        live for their other owners).  Releasing a slot that owns nothing
        raises: the engine frees exactly once per occupied slot, so a
        second release is a bookkeeping bug, not a no-op."""
        if slot not in self._seq_pages:
            raise KeyError(
                f"release: slot {slot} owns no pages (double release, or "
                "a slot that was never allocated)")
        for p in self._seq_pages.pop(slot):
            self._decref(p)

    def _decref(self, p: int) -> bool:
        n = self._refs.get(p)
        if n is None:
            raise ValueError(
                f"page {p} released but not owned (double free)")
        if n > 1:
            self._refs[p] = n - 1
            return False
        del self._refs[p]
        self._give_back(p)
        return True

    def _give_back(self, p: int) -> None:
        if p < self.pool.n_local_pages:
            target = self._free_local
        elif p in self.pool.global_range(0):
            target = self._free_global[0]
        else:
            target = self._free_global[1]
        if p in target:
            raise ValueError(
                f"page {p} returned to the free list twice — a later "
                "allocate would grant one page to two sequences")
        target.append(p)

    # -- page table ---------------------------------------------------------

    def table_row(self, slot: int) -> np.ndarray:
        row = np.zeros((self.pool.max_pages_per_seq,), np.int32)
        pages = self._seq_pages.get(slot, ())
        row[: len(pages)] = pages
        return row


@dataclass
class _PrefixEntry:
    page: int
    children: int = 0                 # longer cached prefixes extending this
    last_use: int = 0                 # LRU clock (lookups + inserts)


class PrefixCache:
    """Prefix-block index over the paged KV pools (vLLM-style, host-side).

    Requests sharing a system-prompt prefix hit the same pages instead of
    re-prefilling: the index maps each *full-page* token prefix (the exact
    token tuple, chain of ``page_size`` blocks) to the page holding its KV.
    Paged attention KV at position ``t`` is a deterministic function of
    ``tokens[:t+1]`` alone, so blocks written by different slots for the
    same token prefix are interchangeable.

    Only **local** pages are ever registered: global-pool content is
    parity-swapped per microbatch by the §4.2 offloader, so a cross-slot
    share spanning microbatches would be clobbered by the next swap.

    Matches are capped at ``prompt_len - 1`` tokens — the final prompt
    position must always be prefilled to produce the first-token logits.
    Cached pages carry one ``PageAllocator.retain`` reference each; LRU
    leaf eviction (``evict``) drops them when the pool runs dry."""

    def __init__(self, alloc: PageAllocator):
        self.alloc = alloc
        self.page_size = alloc.pool.page_size
        self.n_local_pages = alloc.pool.n_local_pages
        self._entries: Dict[tuple, _PrefixEntry] = {}
        self._clock = 0
        self.hit_requests = 0
        self.miss_requests = 0
        self.hit_tokens = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def pages_retained(self) -> List[int]:
        """Pages the cache holds a retain reference on (one per entry)."""
        return [e.page for e in self._entries.values()]

    def match(self, prompt: List[int]) -> List[int]:
        """Pages covering the longest cached full-page prefix of
        ``prompt`` (possibly empty), in table-row order."""
        self._clock += 1
        pages: List[int] = []
        n_full = (len(prompt) - 1) // self.page_size
        for i in range(n_full):
            e = self._entries.get(tuple(prompt[: (i + 1) * self.page_size]))
            if e is None:
                break
            e.last_use = self._clock
            pages.append(e.page)
        if pages:
            self.hit_requests += 1
            self.hit_tokens += len(pages) * self.page_size
        else:
            self.miss_requests += 1
        return pages

    def insert(self, prompt: List[int], pages: List[int]) -> int:
        """Register a fully-prefilled sequence's prompt blocks (``pages``
        in table-row order).  Existing entries win — two requests that
        prefilled the same prefix concurrently keep the incumbent's page.
        Returns the number of pages newly retained."""
        self._clock += 1
        added = 0
        n_full = min((len(prompt) - 1) // self.page_size, len(pages))
        parent: Optional[_PrefixEntry] = None
        for i in range(n_full):
            key = tuple(prompt[: (i + 1) * self.page_size])
            e = self._entries.get(key)
            if e is None:
                p = pages[i]
                if p >= self.n_local_pages:
                    break       # global pages parity-swap per mb: unshareable
                self.alloc.retain(p)
                e = _PrefixEntry(page=p, last_use=self._clock)
                self._entries[key] = e
                if parent is not None:
                    parent.children += 1
                added += 1
            else:
                e.last_use = self._clock
            parent = e
        return added

    def evict(self, n_pages: int) -> int:
        """Drop LRU *leaf* entries until ``n_pages`` pages actually
        returned to the free lists (entries whose pages are still shared
        by live slots free nothing) or the cache is empty.  Returns the
        number of pages freed."""
        freed = 0
        while freed < n_pages and self._entries:
            key = min((k for k, e in self._entries.items()
                       if e.children == 0),
                      key=lambda k: self._entries[k].last_use)
            e = self._entries.pop(key)
            if len(key) > self.page_size:
                parent = self._entries.get(key[:-self.page_size])
                if parent is not None:
                    parent.children -= 1
            if self.alloc.drop(e.page):
                freed += 1
            self.evictions += 1
        return freed

    def clear(self) -> int:
        """Drop every entry (shutdown / tests); returns pages freed."""
        return self.evict(len(self._entries) + 1) if self._entries else 0

    @property
    def hit_rate(self) -> float:
        total = self.hit_requests + self.miss_requests
        return self.hit_requests / total if total else 0.0


# ---------------------------------------------------------------------------
# Cache pytree construction
# ---------------------------------------------------------------------------


def _paged_kind_cache(cfg: ModelConfig, batch: int, pool: PoolConfig,
                      rt: Runtime, lead: tuple = ()) -> dict:
    cd = rt.compute_dtype
    Hk, Dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k_pages": jnp.zeros(lead + (pool.n_pages, pool.page_size, Hk, Dh), cd),
        "v_pages": jnp.zeros(lead + (pool.n_pages, pool.page_size, Hk, Dh), cd),
        "page_table": jnp.zeros(lead + (batch, pool.max_pages_per_seq),
                                jnp.int32),
    }


def build_paged_caches(cfg: ModelConfig, batch: int, pool: PoolConfig,
                       rt: Runtime) -> dict:
    """Engine cache pytree: paged pools for full-attention kinds, dense rings
    for sliding-window kinds, O(1) states for recurrent kinds."""
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)

    def kind_cache(kind: str, lead: tuple):
        if kind in ("attn", "global"):
            return _paged_kind_cache(cfg, batch, pool, rt, lead)
        # "local" (bounded ring) and recurrent kinds: window-capacity dense
        cap = cfg.window_size if kind == "local" and cfg.window_size else \
            pool.max_pages_per_seq * pool.page_size
        return _kind_cache(kind, cfg, batch, cap, rt, lead)

    scan = [kind_cache(k, (plan.n_periods,)) for k in plan.period_kinds] \
        if plan.n_periods else []
    tail = [kind_cache(k, ()) for k in plan.tail_kinds]
    return {"scan": scan, "tail": tail}


def _map_paged_leaves(caches: dict, fn):
    """Apply ``fn(layer_cache_dict, stacked: bool)`` to every attention-kind
    sub-dict in the cache pytree, returning a new pytree."""
    def one(c, stacked):
        if isinstance(c, dict) and ("k_pages" in c or "pos" in c):
            return fn(c, stacked)
        return c
    return {
        "scan": [one(c, True) for c in caches["scan"]],
        "tail": [one(c, False) for c in caches["tail"]],
    }


def set_page_table(caches: dict, table: np.ndarray) -> dict:
    """Broadcast the host page table (B, max_pages) into every paged layer."""
    dev = jnp.asarray(table, jnp.int32)

    def fn(c, stacked):
        if "page_table" not in c:
            return c
        t = c["page_table"]
        new = jnp.broadcast_to(dev[None], t.shape) if stacked else dev
        return {**c, "page_table": new}
    return _map_paged_leaves(caches, fn)


def reset_slot(caches: dict, cfg: ModelConfig, slot: int,
               rt: Runtime) -> dict:
    """Clear per-slot state when a decode slot is reassigned: ring positions
    back to -1, recurrent states back to init.  Paged pools need no clearing
    (validity is governed by seq_lens)."""
    def clear(c, stacked):
        if "k_pages" in c:
            return c
        out = dict(c)
        idx = (slice(None), slot) if stacked else (slot,)
        if "pos" in c:
            out["pos"] = c["pos"].at[idx].set(-1)
            return out
        for name, leaf in c.items():      # recurrent states
            init = 1e-6 if name == "n" and leaf.ndim == (2 + int(stacked)) \
                else 0.0
            out[name] = leaf.at[idx].set(init)
        return out
    return _map_paged_leaves(caches, clear)


def _split_shared(c: dict):
    """Split one layer cache dict into (shared pool leaves, per-slot
    leaves).  Pool leaves (``*_pages``) are batch-global; everything else
    (page tables, rings, recurrent states) has a batch axis."""
    shared = {k: v for k, v in c.items() if k.endswith("_pages")}
    per = {k: v for k, v in c.items() if not k.endswith("_pages")}
    return shared, per


def slot_view(caches: dict, start, size: int) -> dict:
    """A ``size``-row view of the batch axis starting at ``start`` (traced
    values ok).  Per-slot leaves are batched on axis 1 in "scan" (period-
    stacked) and axis 0 in "tail"; shared page pools pass through whole."""
    out = {"scan": [], "tail": []}
    for part, axis in (("scan", 1), ("tail", 0)):
        for c in caches[part]:
            shared, per = _split_shared(c)
            view = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(
                x, start, size, axis=axis), per)
            out[part].append({**shared, **view})
    return out


def slot_merge(caches: dict, view: dict, start) -> dict:
    """Splice an updated row view back into the batch-wide caches.  Pool
    leaves are taken from the view (decode/prefill write KV into them);
    per-slot rows are spliced at ``start``."""
    out = {"scan": [], "tail": []}
    for part, axis in (("scan", 1), ("tail", 0)):
        for c_old, c_new in zip(caches[part], view[part]):
            shared_new, per_new = _split_shared(c_new)
            _, per_old = _split_shared(c_old)
            merged = jax.tree.map(
                lambda f, p: jax.lax.dynamic_update_slice_in_dim(
                    f, p.astype(f.dtype), start, axis=axis),
                per_old, per_new)
            out[part].append({**shared_new, **merged})
    return out


def kv_bytes_per_page(cfg: ModelConfig, pool: PoolConfig,
                      dtype_bytes: int = 2) -> int:
    """Bytes one page occupies across all paged layers (k+v)."""
    n_paged = sum(1 for k in cfg.layer_kinds() if k in ("attn", "global"))
    return (2 * n_paged * pool.page_size * cfg.num_kv_heads * cfg.head_dim
            * dtype_bytes)


def global_slice(pool: PoolConfig, pool_id: int) -> slice:
    r = pool.global_range(pool_id)
    return slice(r.start, r.stop)
