"""Pluggable execution backends for the offline serving engine.

The engine (``repro.serving.engine.OfflineEngine``) owns every piece of
*bookkeeping* — request queue, decode slots, page allocator, page table,
positions — while a backend owns the *compute plane*: the device cache
pytree and every jit entry point.  The seam:

  ``prefill_step(chunk)``  — advance the prefill plane one tick,
        optionally injecting a :class:`PrefillChunk` (a fixed-shape batch
        of prompt-token rows with their own page-table rows) — compiled
        once.  Local backends return the chunk's :class:`PrefillResult`
        immediately; pipelined backends route it stage-to-stage through a
        second persistent pipe (overlapping in-flight decode) and return
        it ``N_S − 1`` ticks later.  ``prefill_can_accept`` /
        ``prefill_pending`` expose the pipe state.
  ``prefill(tokens, slot, last_index)``  — the exact-length fallback
        (recurrent / sliding-window archs): run one sequence's (padded)
        prompt into the caches at ``slot``, return last-position logits.
  ``decode(mb, tokens, cur_pos, samp)``  — advance microbatch ``mb`` by one
        token tick; returns zero or more :class:`DecodeResult`.  A result
        may be for an *earlier* microbatch: pipelined backends drain with
        latency, so the engine applies results by the microbatch id they
        carry, not by the one it just injected.  ``samp`` is a per-row
        :class:`repro.serving.sampler.RowSampling` — every slot carries its
        own temperature / top-k / top-p and PRNG key, so one compiled
        decode serves mixed greedy+sampled microbatches.
  cache ownership — ``set_page_table`` / ``reset_slot`` push the engine's
        host-side bookkeeping into the device caches.

Two implementations ship:

``LocalBackend``
    The single-device path: one jitted decode per microbatch tick, one
    jitted prefill per (padded) prompt length.  Decode slices the
    microbatch's ``mb_size`` cache rows (never the full batch), so
    non-microbatch rows are untouched by construction.

``PipelinedBackend``
    DeServe's §4.3 circular schedule as a *persistent stepper* over the
    ``N_S``-stage ``shard_map`` pipeline (``repro.core.pipeline``).  Each
    engine tick injects one microbatch at stage 0 and advances every
    in-flight microbatch one stage; the microbatch leaving the last stage
    drains through the shared epilogue + sampler and is returned to the
    engine ``N_S − 1`` ticks after injection.  Paged KV pools and the
    §4.2 double-buffer offloader run per stage: stage ``s`` swaps its own
    period-slice of the global pools when a microbatch arrives at it.
"""

from __future__ import annotations

import abc
import functools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model as model_lib
from repro.models.common import Runtime
from repro.serving import kv_cache as kvc
from repro.serving.sampler import (RowSampling, fold_in_steps,
                                   sample_batched, token_logprobs)


@dataclass
class DecodeResult:
    """One drained microbatch tick: ``tokens[i]`` is the next token for
    slot ``mb * mb_size + i`` (the engine decides which rows are live).
    ``lost=True`` marks a *fault*: the microbatch's tick was dropped by a
    failed stage — ``tokens``/``logprobs`` are garbage and the engine must
    re-inject the microbatch instead of booking them."""
    mb: int
    tokens: np.ndarray                  # (mb_size,) int32
    logprobs: np.ndarray                # (mb_size,) f32 — model logprob of
                                        # tokens[i] (raw-logits distribution)
    lost: bool = False


@dataclass
class PrefillChunk:
    """One per-tick prefill work unit: up to R rows of C prompt tokens each,
    batched across queued/continuing sequences.  Shapes are fixed by the
    engine (``prefill_rows`` x ``prefill_chunk``) so the chunk jit compiles
    exactly once — padded rows carry ``n_valid == 0``."""
    tokens: np.ndarray                  # (R, C) int32
    slots: np.ndarray                   # (R,) int32 slot per row, -1 = pad
    offsets: np.ndarray                 # (R,) int32 tokens already prefilled
    n_valid: np.ndarray                 # (R,) int32 real tokens this chunk
    lasts: np.ndarray                   # (R,) int32 within-chunk index of the
                                        # final prompt token (-1: not final)
    tables: np.ndarray                  # (R, max_pages) int32 page-table rows
                                        # (the device-wide table keeps
                                        # prefilling slots parked on scratch)
    seqs: list                          # engine-side SequenceState refs —
                                        # opaque to the backend
    residency_mbs: tuple = ()           # microbatch ids (<= one per global-
                                        # pool parity — the offloader keys
                                        # host copies by mb, not parity)
                                        # whose global pages the chunk
                                        # writes; () = all-local


@dataclass
class PrefillResult:
    """A drained prefill chunk: ``logits[i]`` are the last-position logits
    of row ``i`` — meaningful only for rows whose chunk was their last
    (``chunk.lasts[i] >= 0``).  ``lost=True`` marks a dropped chunk tick:
    ``logits`` are garbage and the engine must re-emit the chunk."""
    chunk: PrefillChunk
    logits: np.ndarray                  # (R, V) f32
    lost: bool = False


# cache-view helpers live with the cache layout; re-exported here because
# backends are their main consumer
slot_view = kvc.slot_view
slot_merge = kvc.slot_merge


# ---------------------------------------------------------------------------
# Interface + shared slot-cache machinery
# ---------------------------------------------------------------------------


class ExecutionBackend(abc.ABC):
    """Compute plane behind the engine.  Owns caches and jit entries."""

    name: str = "abstract"

    @abc.abstractmethod
    def prefill(self, tokens: np.ndarray, slot: int, last_index: int,
                has_global_pages: bool = True) -> jax.Array:
        """Prefill one (padded) prompt into ``slot``; returns (V,) logits
        at ``last_index``.  ``has_global_pages=False`` tells the backend
        the slot's allocation is all-local, so no offload residency work
        is needed before the prompt KV is written."""

    @abc.abstractmethod
    def decode(self, mb: int, tokens: np.ndarray, cur_pos: np.ndarray,
               samp: RowSampling, active: bool = True) -> List[DecodeResult]:
        """Advance microbatch ``mb`` one tick (``active=False`` advances
        the pipe without injecting — used to drain).  ``samp`` carries the
        per-row sampling params/keys of the microbatch being injected."""

    @abc.abstractmethod
    def set_page_table(self, table: np.ndarray) -> None:
        """Push the engine's (batch, max_pages) page table to the device."""

    @abc.abstractmethod
    def reset_slot(self, slot: int) -> None:
        """Clear per-slot ring/recurrent state when a slot is reassigned."""

    def busy_microbatches(self) -> set:
        """Microbatches with an in-flight tick (their slots and cache rows
        must not be touched by admission)."""
        return set()

    def pending(self) -> bool:
        """True while ticks are still in flight (engine keeps draining)."""
        return False

    # -- chunked prefill (batched admission) -------------------------------

    def prefill_step(self, chunk: Optional["PrefillChunk"]
                     ) -> List["PrefillResult"]:
        """Advance the prefill plane one engine tick, optionally injecting
        ``chunk``.  Local backends run the chunk synchronously and return
        its result immediately; pipelined backends route it stage-to-stage
        through the pipe (overlapping in-flight decode microbatches) and
        return it ``N_S - 1`` ticks later.  Returns zero or more drained
        :class:`PrefillResult`."""
        if chunk is None:
            return []
        raise NotImplementedError(
            f"{type(self).__name__} does not implement chunked prefill")

    def prefill_can_accept(self) -> bool:
        """True when a new chunk may be injected this tick."""
        return True

    def prefill_pending(self) -> bool:
        """True while prefill chunks are still in flight."""
        return False

    def drain_stage_times(self) -> List[tuple]:
        """(stage, seconds) tick-time observations since the last call —
        non-empty only on staged (pipelined) backends."""
        return []

    def transport_stats(self) -> Dict:
        """Inter-stage transport accounting (virtual clock, wire bytes,
        link stalls) — non-empty only on staged backends whose transport
        keeps books (see ``repro.distributed.transport``)."""
        return {}

    def jit_entries(self) -> Dict[str, object]:
        """Serve-loop jit callables by name, for the strict-mode cache
        probes: each must hold at most one compiled trace over a full
        serve run (a second entry is a silent mid-serve retrace)."""
        return {}

    @property
    def swap_count(self) -> int:
        return 0


class _SlotCacheBackend(ExecutionBackend):
    """Shared prefill / page-table / reset plumbing over engine-format
    paged caches.  Subclasses implement ``decode``."""

    def __init__(self, cfg: ModelConfig, params, rt: Runtime, *,
                 mb_size: int, num_microbatches: int, pool: kvc.PoolConfig):
        self.cfg = cfg
        self.params = params
        self.rt = rt
        self.mb_size = mb_size
        self.num_microbatches = num_microbatches
        self.batch = mb_size * num_microbatches
        self.pool = pool
        self.caches = kvc.build_paged_caches(cfg, self.batch, pool, rt)
        self._prefill_jits: Dict[int, object] = {}

    # -- cache bookkeeping entry points ------------------------------------

    def set_page_table(self, table: np.ndarray) -> None:
        self.caches = kvc.set_page_table(self.caches, table)

    def reset_slot(self, slot: int) -> None:
        self.caches = kvc.reset_slot(self.caches, self.cfg, slot, self.rt)

    def jit_entries(self) -> Dict[str, object]:
        return {f"_prefill_jits[{lp}]": fn
                for lp, fn in self._prefill_jits.items()}

    # -- prefill -----------------------------------------------------------

    def _prefill_residency(self, mb: int) -> None:
        """Make ``mb``'s global-pool parity resident before prompt KV is
        written (a prefill may allocate overflow pages from the global
        pool while a different microbatch's content is resident — without
        this the next swap would clobber the fresh prompt KV)."""

    def prefill(self, tokens: np.ndarray, slot: int, last_index: int,
                has_global_pages: bool = True) -> jax.Array:
        if has_global_pages:
            self._prefill_residency(slot // self.mb_size)
        lp = len(tokens)
        if lp not in self._prefill_jits:
            # lengths are pow2/8-bucketed (engine._prefill_len) so this
            # dict holds O(log max_len) wrappers, each built once and
            # reused — not a per-call jit
            # repro-audit: allow(retrace-jit) — bounded per-length cache, one wrapper per bucketed length
            self._prefill_jits[lp] = jax.jit(functools.partial(
                self._prefill_fn, cfg=self.cfg, rt=self.rt))
        fn = self._prefill_jits[lp]
        logits, self.caches = fn(self.params, jnp.asarray(tokens)[None],
                                 self.caches, slot, last_index)
        return logits

    # -- chunked prefill ---------------------------------------------------

    @staticmethod
    def _chunk_fn(params, caches, tokens, offsets, n_valid, lasts, tables,
                  *, cfg, rt):
        """One prefill chunk over the batch-wide caches.

        Every paged layer's page table is swapped for the chunk's per-row
        table rows (the device-wide table keeps prefilling slots parked on
        the scratch page until activation, so in-flight decode ticks can
        never clobber half-written prompt KV); pools are written in place;
        the parked per-slot table leaves pass through untouched."""
        def swap(c, stacked):
            pt = jnp.broadcast_to(
                tables[None], (c["page_table"].shape[0],) + tables.shape) \
                if stacked else tables
            return {**c, "page_table": pt}
        view = {"scan": [swap(c, True) for c in caches["scan"]],
                "tail": [swap(c, False) for c in caches["tail"]]}
        logits, new = model_lib.prefill_chunk(params, tokens, view, offsets,
                                              n_valid, lasts, cfg, rt)
        keep = lambda n, o: {**n, "page_table": o["page_table"]}
        return logits, {
            "scan": [keep(n, o) for n, o in zip(new["scan"], caches["scan"])],
            "tail": [keep(n, o) for n, o in zip(new["tail"], caches["tail"])]}

    @staticmethod
    def _prefill_fn(params, tokens, caches, slot, last_idx, *, cfg, rt):
        """Prefill one sequence into batch-wide caches at ``slot``: slice
        the slot row from every per-slot leaf, run the model prefill,
        splice back."""
        view = slot_view(caches, slot, 1)
        logits, new_view = model_lib.prefill(
            params, {"tokens": tokens}, cfg, rt, 0, caches=view,
            last_index=jnp.asarray(last_idx).reshape(1))

        # mask ring stale positions beyond the true length
        def clean(c):
            if "pos" in c:
                c = {**c, "pos": jnp.where(c["pos"] <= last_idx,
                                           c["pos"], -1)}
            return c
        new_view = {part: [clean(c) for c in new_view[part]]
                    for part in ("scan", "tail")}
        return logits[0], slot_merge(caches, new_view, slot)


# ---------------------------------------------------------------------------
# LocalBackend — the single-device path
# ---------------------------------------------------------------------------


class LocalBackend(_SlotCacheBackend):
    name = "local"

    def __init__(self, cfg: ModelConfig, params, rt: Runtime, *,
                 mb_size: int, num_microbatches: int, pool: kvc.PoolConfig,
                 offloader=None, sample_fast_path: bool = True,
                 recorder=None):
        super().__init__(cfg, params, rt, mb_size=mb_size,
                         num_microbatches=num_microbatches, pool=pool)
        self.offloader = offloader
        self.recorder = recorder
        if offloader is not None:
            offloader.recorder = recorder
        self._decode_jit = jax.jit(functools.partial(
            self._decode_fn, cfg=cfg, rt=rt, mb_size=mb_size,
            sample_fast=sample_fast_path))
        self._chunk_jit = jax.jit(functools.partial(
            self._chunk_fn, cfg=cfg, rt=rt))

    def _prefill_residency(self, mb: int) -> None:
        if self.offloader is not None and self.pool.n_global_pages:
            self.caches = self.offloader.ensure_resident(self.caches, mb)

    def jit_entries(self) -> Dict[str, object]:
        out = super().jit_entries()
        out["_decode_jit"] = self._decode_jit
        out["_chunk_jit"] = self._chunk_jit
        return out

    def prefill_step(self, chunk) -> List[PrefillResult]:
        if chunk is None:
            return []
        for mb in chunk.residency_mbs:
            self._prefill_residency(mb)
        logits, self.caches = self._chunk_jit(
            self.params, self.caches, jnp.asarray(chunk.tokens),
            jnp.asarray(chunk.offsets), jnp.asarray(chunk.n_valid),
            jnp.asarray(chunk.lasts), jnp.asarray(chunk.tables))
        # repro-audit: allow(host-sync) — prefill drain: the engine samples the first token from these logits on host, once per chunk
        return [PrefillResult(chunk=chunk, logits=np.asarray(logits))]

    def decode(self, mb: int, tokens: np.ndarray, cur_pos: np.ndarray,
               samp: RowSampling, active: bool = True) -> List[DecodeResult]:
        if not active:
            return []
        if self.offloader is not None:
            self.caches = self.offloader.ensure_resident(self.caches, mb)
        toks, lps, self.caches = self._decode_jit(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(cur_pos), jnp.int32(mb * self.mb_size),
            jnp.asarray(samp.keys), jnp.asarray(samp.steps),
            jnp.asarray(samp.temp), jnp.asarray(samp.top_k),
            jnp.asarray(samp.top_p))
        # §4.3 return link: the host-driven engine books the drained
        # microbatch's token ids, so one transfer per decode call is the
        # loop's single intended sync point — batched (tokens, logprobs)
        # in one device_get rather than two separate np.asarray syncs
        # repro-audit: allow(host-sync) — intended §4.3 return-link sync, one batched transfer per drain
        toks, lps = jax.device_get((toks, lps))
        return [DecodeResult(mb=mb, tokens=toks, logprobs=lps)]

    @staticmethod
    def _decode_fn(params, caches, tokens, cur_pos, row0, keys, steps, temp,
                   top_k, top_p, *, cfg, rt, mb_size, sample_fast=True):
        """One decode tick over an ``mb_size`` row view of the caches —
        the full batch is never fed through the model, and rows outside
        the microbatch are untouched by construction.  Sampling is per-row
        (``sample_batched``) with per-token keys folded in on device."""
        view = slot_view(caches, row0, mb_size)
        logits, new_view = model_lib.decode_step(
            params, tokens, view, cur_pos, cfg, rt)
        toks = sample_batched(logits, fold_in_steps(keys, steps), temp,
                              top_k, top_p, fast_path=sample_fast)
        return toks, token_logprobs(logits, toks), \
            slot_merge(caches, new_view, row0)

    @property
    def swap_count(self) -> int:
        return self.offloader.swap_count if self.offloader else 0


# ---------------------------------------------------------------------------
# PipelinedBackend — the §4.3 circular schedule as a persistent stepper
# ---------------------------------------------------------------------------


class PipelinedBackend(_SlotCacheBackend):
    name = "pipelined"

    def __init__(self, cfg: ModelConfig, params, rt: Runtime, *,
                 mb_size: int, num_microbatches: int, pool: kvc.PoolConfig,
                 n_stages: int = 2, offload: bool = False, mesh=None,
                 fault_plan=None, transport=None, schedule: str = "circular",
                 wire_dtype: str = "fp32", sample_fast_path: bool = True,
                 offload_async: bool = True, recorder=None):
        from repro.core import pipeline as PL
        from repro.core.offload import DoubleBufferOffloader
        if wire_dtype not in ("fp32", "int8"):
            raise ValueError(f"wire_dtype must be 'fp32'|'int8', got "
                             f"{wire_dtype!r} (top-k has no in-jit codec — "
                             "it stays wire-byte accounting only)")
        self.wire_dtype = wire_dtype
        if num_microbatches < n_stages:
            raise ValueError(
                f"continuous batching over a {n_stages}-stage pipe needs "
                f"N_B >= N_S (got N_B={num_microbatches}); see §4.3 — a "
                "microbatch must drain before its next injection")
        super().__init__(cfg, params, rt, mb_size=mb_size,
                         num_microbatches=num_microbatches, pool=pool)
        self.n_stages = n_stages
        self.pps, self.leftover = PL.split_layers(cfg, n_stages)
        if mesh is None:
            devs = jax.devices()
            if len(devs) < n_stages:
                raise RuntimeError(
                    f"pipelined backend needs >= {n_stages} devices for the "
                    f"pod axis, have {len(devs)} — set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={n_stages} "
                    "before initialising jax, or reduce --stages")
            mesh = jax.sharding.Mesh(np.array(devs[:n_stages]), ("pod",))
        self.mesh = mesh
        # Sharding discipline for the persistent tick jits: every array
        # input must carry ONE stable sharding per serve run, or the jit
        # cache key flips and the tick silently recompiles (caught by the
        # strict-mode jit probes).  Fresh jnp.zeros and host-side table
        # writes are SingleDeviceSharding-uncommitted while tick outputs
        # come back NamedSharding-committed (stage-stacked leaves
        # P("pod")), so: (1) state starts replicated-committed; (2) just
        # before the first tick of each plane, _probe_layout AOT-compiles
        # the tick and commits the state to the compiled OUTPUT shardings
        # — the layout every later tick hands back — so the counted call
        # cache only ever sees the steady layout; (3) every later
        # host-side write re-commits through _commit() to it.
        self._replicated = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        self._cache_shardings = None    # learned on the first tick
        self._act_sharding = None
        self._pf_act_sharding = None
        self._layout_learned = {"decode": False, "prefill": False}
        self.caches = self._commit(self.caches)
        # per-stage input activations: act[s] feeds stage s next tick
        self.act = jax.device_put(
            jnp.zeros((n_stages, mb_size, 1, cfg.d_model),
                      rt.compute_dtype), self._replicated)
        # shift register of in-flight injections: entry for stage s is the
        # (mb, positions-at-injection, RowSampling-at-injection) whose
        # activation sits in act[s]
        self._entries: List[Optional[tuple]] = [None] * n_stages
        self._tick_jit = jax.jit(functools.partial(
            PL.pipeline_decode_tick, cfg=cfg, rt=rt,
            n_stages=n_stages, mb_size=mb_size, mesh=mesh,
            wire_dtype=wire_dtype, sample_fast_path=sample_fast_path))
        # prefill pipe: a second persistent stepper with its own activation
        # carry / shift register, so prompt chunks flow stage-to-stage and
        # OVERLAP in-flight decode microbatches instead of pausing them.
        # Shapes (chunk rows x chunk length) are fixed by the engine; the
        # activation buffer and jit are built lazily on the first chunk.
        self._pf_entries: List[Optional[PrefillChunk]] = [None] * n_stages
        self._pf_act = None
        self._pf_tick_jit = jax.jit(functools.partial(
            PL.pipeline_prefill_chunk_tick, cfg=cfg, rt=rt,
            n_stages=n_stages, mesh=mesh, wire_dtype=wire_dtype))
        # Probe the decode plane NOW (arg shapes are fixed by n_stages and
        # mb_size): the exact-prefill jits below take the caches as input
        # and always run before the first tick, so the caches must already
        # carry the steady layout or each per-length wrapper retraces after
        # the layout commit.
        _zs = RowSampling.zeros(mb_size)
        self._probe_layout("decode", (
            self.params, self.caches, self.act,
            jnp.zeros((mb_size,), jnp.int32),
            jnp.full((n_stages,), -1, jnp.int32),
            jnp.zeros((n_stages, mb_size), jnp.int32),
            jnp.asarray(_zs.keys), jnp.asarray(_zs.steps),
            jnp.asarray(_zs.temp), jnp.asarray(_zs.top_k),
            jnp.asarray(_zs.top_p), jnp.int32(-1)))

        # fault injection (tests / drills): a FaultPlan consumed one event
        # set per plane tick.  Drops null the shift-register entry (the
        # microbatch/chunk is lost — the engine re-injects it); the
        # drop_stage marker threaded into the tick jit re-masks the same
        # stage's cache writes — redundant under this caller, but it keeps
        # the fault seam explicit for direct users of the tick functions.
        # Delays inflate the stage-time observations that feed straggler
        # mitigation.
        if fault_plan is not None:
            bad = [e for e in fault_plan.events if e.stage >= n_stages]
            if bad:
                raise ValueError(
                    f"fault plan targets stage(s) "
                    f"{sorted({e.stage for e in bad})} but the pipe has "
                    f"only {n_stages} stage(s) — fix the "
                    "kind@plane:tick:stage spec")
        self.fault_plan = fault_plan
        self._decode_ticks = 0          # plane-local tick counters: only
        self._prefill_ticks = 0         # ticks where the pipe advanced
        self._stage_times: List[tuple] = []   # (stage, seconds) since the
                                              # last drain_stage_times()

        # inter-stage links: every shift-register entry crossing a stage
        # boundary — decode ticks AND prefill chunks — travels the
        # configured transport.  InProcessTransport is today's zero-cost
        # shard_map behaviour; SimulatedLinkTransport accounts per-link
        # WAN latency on a virtual clock (outputs stay bit-identical —
        # the links never touch the computation).
        from repro.distributed.transport import (CompressedTransport,
                                                 InProcessTransport,
                                                 make_transport)
        self.transport = make_transport(transport, n_stages)
        # the decode/prefill call sites below pass RAW activation bytes;
        # pricing the packed int8 payload is the transport's job, so a
        # real in-jit codec forces the matching CompressedTransport wrap
        # (or retunes an existing one) — wire accounting then equals the
        # actual ppermute payload: 1 B/element + one f32 scale per row.
        _db = jnp.dtype(rt.compute_dtype).itemsize
        if wire_dtype == "int8" and \
                not isinstance(self.transport, InProcessTransport):
            if isinstance(self.transport, CompressedTransport):
                if self.transport.method != "int8":
                    raise ValueError(
                        f"wire_dtype='int8' but the transport accounts "
                        f"'{self.transport.method}' — use one codec for "
                        "both the wire and the books")
                self.transport.elem_bytes = _db
                self.transport.row_elems = cfg.d_model
                self.transport._wire_cache.clear()
            else:
                self.transport = CompressedTransport(
                    self.transport, method="int8", elem_bytes=_db,
                    row_elems=cfg.d_model).bind(n_stages)
        # the flight recorder rides on the OUTER transport (a compressed
        # wrap forwards to its inner, which accumulates the books — so
        # the recorded ledger carries the re-priced wire bytes)
        self.recorder = recorder
        self.transport.set_recorder(recorder)
        if schedule not in ("circular", "round_flush"):
            raise ValueError(f"schedule must be 'circular'|'round_flush', "
                             f"got {schedule!r}")
        # "round_flush" reproduces the vLLM-PP baseline: the pipe is
        # drained (fill/drain bubbles) every token round instead of
        # running the §4.3 circular schedule — the latency-curve
        # benchmark's comparison point.  Drain ticks run the same jits
        # with bubble entries, so outputs stay bit-identical.
        self.schedule = schedule
        self._last_inject_mb = -1       # round boundary detector
        self._ret_ready: Dict[int, float] = {}  # mb -> virtual time its
                                                # drained return payload
                                                # lands at the injector
        self._dtype_bytes = jnp.dtype(rt.compute_dtype).itemsize

        # §4.2 offloading, per stage: stage s double-buffers its own
        # period-slice of the global pools; the epilogue (leftover periods
        # + tail) forms one extra stage-unit keyed to the draining mb.
        self._stage_off: List = []
        self._epi_off = None
        if offload and pool.n_global_pages:
            self._stage_off = [
                DoubleBufferOffloader(pool, num_microbatches,
                                      async_swap=offload_async)
                for _ in range(n_stages)]
            if self._unit_has_paged(self._epi_view()):
                self._epi_off = DoubleBufferOffloader(
                    pool, num_microbatches, async_swap=offload_async)
            for off in self._stage_off + ([self._epi_off]
                                          if self._epi_off else []):
                off.recorder = recorder

    # -- per-stage offload residency ---------------------------------------

    @staticmethod
    def _unit_has_paged(view: dict) -> bool:
        return any(isinstance(c, dict) and "k_pages" in c
                   for part in ("scan", "tail") for c in view[part])

    def _stage_view(self, s: int) -> dict:
        lo, hi = s * self.pps, (s + 1) * self.pps
        return {"scan": [jax.tree.map(lambda x: x[lo:hi], c)
                         for c in self.caches["scan"]], "tail": []}

    def _epi_view(self) -> dict:
        lo = self.n_stages * self.pps
        scan = [jax.tree.map(lambda x: x[lo:], c)
                for c in self.caches["scan"]] if self.leftover else []
        return {"scan": scan, "tail": self.caches["tail"]}

    def _splice_scan(self, view: dict, lo: int) -> None:
        new_scan = self.caches["scan"]
        if view["scan"]:                # epilogue views may carry tail only
            new_scan = [jax.tree.map(
                lambda full, part: full.at[lo:lo + part.shape[0]].set(
                    part.astype(full.dtype)), c_full, c_new)
                for c_full, c_new in zip(self.caches["scan"], view["scan"])]
        self.caches = self._commit(
            {"scan": new_scan,
             "tail": view["tail"] or self.caches["tail"]})

    def _commit(self, tree):
        """Pin every cache leaf to the steady tick-jit layout (or the
        replicated bootstrap before the first tick learned it).
        Host-side writes (page-table publish, slot reset, offload splice,
        exact prefill) otherwise hand the next tick arrays whose sharding
        differs from the previous tick's outputs — a silent jit cache-key
        flip and recompile.  A no-op for already-committed leaves."""
        if self._cache_shardings is None:
            return jax.device_put(tree, self._replicated)
        return jax.tree.map(jax.device_put, tree, self._cache_shardings)

    def _probe_layout(self, plane: str, args: tuple) -> None:
        """Before the first tick of ``plane``: AOT-compile the tick on the
        bootstrap inputs and commit the persistent state to the compiled
        OUTPUT shardings — the layout every tick hands back.  jax.jit
        wrappers over the same callable share one C++ call cache, so a
        trace keyed on the bootstrap layout could never be evicted; the
        only way to keep steady state at exactly one compile per (shape,
        wire_dtype) config is to never let the bootstrap layout reach a
        counted call.  ``.lower().compile()`` does not populate
        ``_cache_size`` (verified on jax 0.4.37), so the probe itself is
        invisible to the strict-mode jit probes."""
        self._layout_learned[plane] = True
        if plane == "decode":
            out_sh = self._tick_jit.lower(*args).compile().output_shardings
            _, _, self._cache_shardings, self._act_sharding = out_sh
            self.act = jax.device_put(self.act, self._act_sharding)
        else:
            out_sh = (self._pf_tick_jit.lower(*args).compile()
                      .output_shardings)
            _, self._cache_shardings, self._pf_act_sharding = out_sh
            self._pf_act = jax.device_put(self._pf_act,
                                          self._pf_act_sharding)
        self.caches = self._commit(self.caches)

    def set_page_table(self, table: np.ndarray) -> None:
        super().set_page_table(table)
        self.caches = self._commit(self.caches)

    def reset_slot(self, slot: int) -> None:
        super().reset_slot(slot)
        self.caches = self._commit(self.caches)

    def prefill(self, tokens: np.ndarray, slot: int, last_index: int,
                has_global_pages: bool = True) -> jax.Array:
        logits = super().prefill(tokens, slot, last_index, has_global_pages)
        self.caches = self._commit(self.caches)
        return logits

    def _ensure_stage_resident(self, s: int, mb: int) -> None:
        if not self._stage_off:
            return
        view = self._stage_view(s)
        new = self._stage_off[s].ensure_resident(view, mb)
        if new is not view:
            self._splice_scan(new, s * self.pps)

    def _ensure_epi_resident(self, mb: int) -> None:
        if self._epi_off is None:
            return
        view = self._epi_view()
        new = self._epi_off.ensure_resident(view, mb)
        if new is not view:
            self._splice_scan({"scan": new["scan"], "tail": new["tail"]},
                              self.n_stages * self.pps)

    def _prefill_residency(self, mb: int) -> None:
        # a prefill writes every period's pools: all stage units + epilogue
        for s in range(self.n_stages):
            self._ensure_stage_resident(s, mb)
        self._ensure_epi_resident(mb)

    # -- host-store migration (reshard) -------------------------------------

    def _offload_units(self):
        """(offloader, cache view, first scan period) per stage unit."""
        units = [(o, self._stage_view(s), s * self.pps)
                 for s, o in enumerate(self._stage_off)]
        if self._epi_off is not None:
            units.append((self._epi_off, self._epi_view(),
                          self.n_stages * self.pps))
        return units

    def export_offload_state(self) -> dict:
        """Concatenate every offloader's host store into *full-period*
        host arrays, keyed by microbatch — the stage-split-independent
        form ``import_offload_state`` re-splits for a new stage count.

        Per microbatch the state holds, for each paged scan kind, one
        ``(n_periods, n_global, page, heads, head_dim)`` array (periods a
        unit never staged out for that mb stay zero — identical to the
        offloader's own zero-fill-on-first-touch semantics) and for each
        paged tail kind one ``(n_global, ...)`` array.  Currently
        *resident* microbatches are snapshotted from the live pools too:
        the rebuilt backend's offloaders start with empty resident maps,
        so their first ``ensure_resident`` must find every microbatch's
        bytes in the host store.  Call only with both planes drained."""
        units = self._offload_units()
        if not units:
            return {}
        paged_scan = [c for c in self.caches["scan"]
                      if isinstance(c, dict) and "k_pages" in c]
        paged_tail = [c for c in self.caches["tail"]
                      if isinstance(c, dict) and "k_pages" in c]
        n_glob = self.pool.n_global_pages
        state: Dict[int, dict] = {}

        def entry(mb: int) -> dict:
            if mb not in state:
                state[mb] = {
                    "scan": [{
                        "k": np.zeros((c["k_pages"].shape[0], n_glob)
                                      + tuple(c["k_pages"].shape[2:]),
                                      np.dtype(c["k_pages"].dtype)),
                        "v": np.zeros((c["v_pages"].shape[0], n_glob)
                                      + tuple(c["v_pages"].shape[2:]),
                                      np.dtype(c["v_pages"].dtype)),
                    } for c in paged_scan],
                    "tail": [{
                        "k": np.zeros((n_glob,)
                                      + tuple(c["k_pages"].shape[1:]),
                                      np.dtype(c["k_pages"].dtype)),
                        "v": np.zeros((n_glob,)
                                      + tuple(c["v_pages"].shape[1:]),
                                      np.dtype(c["v_pages"].dtype)),
                    } for c in paged_tail],
                }
            return state[mb]

        for o, view, lo in units:
            o.settle()
            stores: Dict[int, List[dict]] = {}
            for parity, mb in o.resident.items():
                if mb is None:
                    continue
                sl = kvc.global_slice(self.pool, parity)
                snap = []
                for c, axis in o._paged_layers(view):
                    idx = (slice(None), sl) if axis == 1 else (sl,)
                    snap.append({"k": np.asarray(c["k_pages"][idx]),
                                 "v": np.asarray(c["v_pages"][idx])})
                stores[mb] = snap
            for mb, layers in o._host.items():
                stores[mb] = [{k: np.asarray(v) for k, v in layer.items()}
                              for layer in layers]
            n_scan = sum(1 for c in view["scan"]
                         if isinstance(c, dict) and "k_pages" in c)
            for mb, layers in stores.items():
                dst = entry(mb)
                for j in range(n_scan):
                    hi = lo + layers[j]["k"].shape[0]
                    dst["scan"][j]["k"][lo:hi] = layers[j]["k"]
                    dst["scan"][j]["v"][lo:hi] = layers[j]["v"]
                for j, layer in enumerate(layers[n_scan:]):
                    dst["tail"][j]["k"][...] = layer["k"]
                    dst["tail"][j]["v"][...] = layer["v"]
        return state

    def import_offload_state(self, state: dict) -> None:
        """Re-split full-period host arrays (``export_offload_state`` of
        the pre-reshard backend) across THIS backend's stage units.  The
        fresh offloaders keep empty resident maps: the first
        ``ensure_resident`` per microbatch pops its imported store and
        writes the pool — by then the departing parity (if any) has been
        staged out, so no carried byte is lost."""
        if not state:
            return
        for s, o in enumerate(self._stage_off):
            lo, hi = s * self.pps, (s + 1) * self.pps
            for mb, full in state.items():
                o._host[mb] = [{"k": f["k"][lo:hi].copy(),
                                "v": f["v"][lo:hi].copy()}
                               for f in full["scan"]]
        if self._epi_off is not None:
            lo = self.n_stages * self.pps
            for mb, full in state.items():
                store = [{"k": f["k"][lo:].copy(),
                          "v": f["v"][lo:].copy()}
                         for f in full["scan"]] if self.leftover else []
                store += [{"k": f["k"].copy(), "v": f["v"].copy()}
                          for f in full["tail"]]
                self._epi_off._host[mb] = store

    # -- fault injection ----------------------------------------------------

    def _take_faults(self, plane: str, tick: int, entries: list):
        """Consume this tick's fault events: drops null the shift-register
        entry (the payload is *lost* — the engine re-injects it) and
        return the dropped stage for the in-jit write mask; delays are
        returned as per-stage synthetic seconds for straggler tracking."""
        drop_stage, delays, lost = -1, {}, []
        if self.fault_plan is not None:
            for ev in self.fault_plan.take(plane, tick):
                if ev.kind == "drop":
                    if entries[ev.stage] is not None:
                        lost.append(entries[ev.stage])
                        entries[ev.stage] = None
                    drop_stage = ev.stage
                else:
                    delays[ev.stage] = delays.get(ev.stage, 0.0) + ev.delay_s
        return drop_stage, delays, lost

    def _record_faults(self, plane: str, lost_mbs: list,
                       delays: dict) -> None:
        """Flight-record this tick's injected faults (host-side stamps;
        callers gate on ``self.recorder is not None``)."""
        rec = self.recorder
        now = time.perf_counter()
        for m in lost_mbs:
            rec.fault("drop", now, (("plane", plane), ("mb", int(m))))
        for s, d in sorted(delays.items()):
            rec.fault("delay", now, (("plane", plane), ("stage", int(s)),
                                     ("delay_s", float(d))))

    def _observe_stages(self, dt: float, delays: dict,
                        stalls=None) -> None:
        # uniform share of the tick's dispatch time per stage, plus any
        # injected synthetic delay (the deterministic signal tests use —
        # dispatch is async, so dt alone is a weak lower bound), plus the
        # measured per-stage link stall from the transport: a stage
        # behind a slow link looks exactly like a straggler to the
        # mitigation loop, shrinking prefill admission the same way
        share = dt / self.n_stages
        for s in range(self.n_stages):
            extra = delays.get(s, 0.0)
            if stalls is not None:
                extra += float(stalls[s])
            self._stage_times.append((s, share + extra))
        if len(self._stage_times) > 4096:       # standalone use: the
            del self._stage_times[:-4096]       # engine drains every step

    def drain_stage_times(self) -> List[tuple]:
        """(stage, seconds) observations since the last call — feed into
        ``StragglerMitigator.observe``."""
        out, self._stage_times = self._stage_times, []
        return out

    # -- the prefill stepper ------------------------------------------------

    def prefill_can_accept(self) -> bool:
        return self._pf_entries[0] is None

    def prefill_pending(self) -> bool:
        return any(e is not None for e in self._pf_entries)

    def prefill_step(self, chunk) -> List[PrefillResult]:
        entries = list(self._pf_entries)
        if chunk is not None:
            assert entries[0] is None, "prefill pipe stage 0 is occupied"
            entries[0] = chunk
        if not any(e is not None for e in entries):
            return []
        tick = self._prefill_ticks
        self._prefill_ticks += 1
        drop_stage, delays, lost = self._take_faults("prefill", tick,
                                                     entries)
        if self.recorder is not None and (lost or delays):
            self._record_faults("prefill", [-1] * len(lost), delays)
        results = [PrefillResult(chunk=c,
                                 logits=np.zeros((c.tokens.shape[0], 1),
                                                 np.float32), lost=True)
                   for c in lost]
        if not any(e is not None for e in entries):
            self._pf_entries = [None] * self.n_stages
            return results
        ref = next(e for e in entries if e is not None)
        rows, clen = ref.tokens.shape
        n_pages_row = ref.tables.shape[1]
        if self._pf_act is None or self._pf_act.shape[1:3] != (rows, clen):
            self._pf_act = jax.device_put(
                jnp.zeros((self.n_stages, rows, clen, self.cfg.d_model),
                          self.rt.compute_dtype),
                self._pf_act_sharding or self._replicated)

        tokens = entries[0].tokens if entries[0] is not None \
            else np.zeros((rows, clen), np.int32)
        offs = np.zeros((self.n_stages, rows), np.int32)
        nval = np.zeros((self.n_stages, rows), np.int32)
        tabs = np.zeros((self.n_stages, rows, n_pages_row), np.int32)
        for s, e in enumerate(entries):
            if e is None:
                continue
            offs[s], nval[s], tabs[s] = e.offsets, e.n_valid, e.tables
            for mb in e.residency_mbs:
                self._ensure_stage_resident(s, mb)
        drained = entries[-1]
        if drained is not None:
            for mb in drained.residency_mbs:
                self._ensure_epi_resident(mb)
        lasts = drained.lasts if drained is not None \
            else np.zeros((rows,), np.int32)

        tick_args = (jnp.asarray(tokens, jnp.int32), jnp.asarray(offs),
                     jnp.asarray(nval), jnp.asarray(tabs),
                     jnp.asarray(lasts, jnp.int32), jnp.int32(drop_stage))
        if not self._layout_learned["prefill"]:
            self._probe_layout(
                "prefill",
                (self.params, self.caches, self._pf_act) + tick_args)
        t0 = time.perf_counter()
        logits, self.caches, self._pf_act = self._pf_tick_jit(
            self.params, self.caches, self._pf_act, *tick_args)
        t1 = time.perf_counter()
        dt = t1 - t0
        # the chunk activation (R, C, D) crosses each occupied boundary
        obs = self.transport.tick(
            [e is not None for e in entries],
            rows * clen * self.cfg.d_model * self._dtype_bytes,
            [dt / self.n_stages] * self.n_stages, plane="prefill")
        self._observe_stages(dt, delays, obs.stalls)
        if self.recorder is not None:
            # per-stage occupancy: prompt rows in flight at each stage
            # (host ints the stepper already holds)
            self.recorder.pipe_tick(
                "prefill", t0, t1,
                tuple(len(e.seqs) if e is not None else 0
                      for e in entries))
        self._pf_entries = [None] + entries[:-1]
        if drained is None:
            return results
        # repro-audit: allow(host-sync) — prefill drain: first-token logits leave the pipe for host-side sampling, once per chunk
        logits = np.asarray(logits)
        return results + [PrefillResult(chunk=drained, logits=logits)]

    # -- the stepper --------------------------------------------------------

    def busy_microbatches(self) -> set:
        return {e[0] for e in self._entries if e is not None}

    def pending(self) -> bool:
        return any(e is not None for e in self._entries)

    def decode(self, mb: int, tokens: np.ndarray, cur_pos: np.ndarray,
               samp: RowSampling, active: bool = True) -> List[DecodeResult]:
        results: List[DecodeResult] = []
        if active and self.schedule == "round_flush" \
                and mb <= self._last_inject_mb:
            # vLLM-PP behaviour: the microbatch counter wrapped — a new
            # token round starts, so drain the pipe completely first
            # (fill/drain bubbles every round).  The drained results ride
            # back with this call; the engine books them by mb id.
            while self.pending():
                results += self._decode_tick(mb, tokens, cur_pos, samp,
                                             active=False)
            self._last_inject_mb = -1
        if active:
            self._last_inject_mb = mb
        return results + self._decode_tick(mb, tokens, cur_pos, samp,
                                           active=active)

    def _decode_tick(self, mb: int, tokens: np.ndarray, cur_pos: np.ndarray,
                     samp: RowSampling, active: bool) -> List[DecodeResult]:
        entries = list(self._entries)
        entries[0] = (mb, np.asarray(cur_pos, np.int32).copy(), samp) \
            if active else None
        if not any(e is not None for e in entries):
            return []
        tick = self._decode_ticks
        self._decode_ticks += 1
        drop_stage, delays, lost = self._take_faults("decode", tick, entries)
        if self.recorder is not None and (lost or delays):
            self._record_faults("decode", [e[0] for e in lost], delays)
        results = [DecodeResult(mb=e[0],
                                tokens=np.zeros((self.mb_size,), np.int32),
                                logprobs=np.zeros((self.mb_size,),
                                                  np.float32), lost=True)
                   for e in lost]
        if not any(e is not None for e in entries):
            self._entries = [None] * self.n_stages
            return results

        mb_assign = np.full((self.n_stages,), -1, np.int32)
        pos_stage = np.zeros((self.n_stages, self.mb_size), np.int32)
        for s, e in enumerate(entries):
            if e is not None:
                mb_assign[s] = e[0]
                pos_stage[s] = e[1]
                self._ensure_stage_resident(s, e[0])
        drained = entries[-1]
        if drained is not None:
            self._ensure_epi_resident(drained[0])
        # sampling params travel with the microbatch: the tick samples the
        # *draining* microbatch with the RowSampling captured at injection
        dsamp = drained[2] if drained is not None \
            else RowSampling.zeros(self.mb_size)

        tick_args = (jnp.asarray(tokens, jnp.int32), jnp.asarray(mb_assign),
                     jnp.asarray(pos_stage), jnp.asarray(dsamp.keys),
                     jnp.asarray(dsamp.steps), jnp.asarray(dsamp.temp),
                     jnp.asarray(dsamp.top_k), jnp.asarray(dsamp.top_p),
                     jnp.int32(drop_stage))
        if not self._layout_learned["decode"]:
            self._probe_layout(
                "decode", (self.params, self.caches, self.act) + tick_args)
        t0 = time.perf_counter()
        toks, lps, self.caches, self.act = self._tick_jit(
            self.params, self.caches, self.act, *tick_args)
        t1 = time.perf_counter()
        dt = t1 - t0
        # the (mb_size, 1, D) activation crosses each occupied boundary;
        # an injection may not start before its microbatch's previous
        # drain returned over the last link (the §4.3 dependency)
        obs = self.transport.tick(
            [e is not None for e in entries],
            self.mb_size * self.cfg.d_model * self._dtype_bytes,
            [dt / self.n_stages] * self.n_stages,
            inject_t=self._ret_ready.get(mb, 0.0)
            if entries[0] is not None else 0.0, plane="decode")
        self._observe_stages(dt, delays, obs.stalls)
        if self.recorder is not None:
            # per-stage occupancy: which microbatch sat in each stage
            # slot this tick (-1 = bubble) — host ints from mb_assign
            self.recorder.pipe_tick("decode", t0, t1,
                                    tuple(int(m) for m in mb_assign))
        self._entries = [None] + entries[:-1]
        if drained is None:
            return results
        self._ret_ready[drained[0]] = obs.return_ready
        # §4.3 return link: token ids of the draining microbatch ride
        # back to the host injector — one batched (tokens, logprobs)
        # transfer per drained tick, not two separate syncs
        # repro-audit: allow(host-sync) — intended §4.3 return-link sync, one batched transfer per drain
        toks, lps = jax.device_get((toks, lps))
        return results + [DecodeResult(mb=drained[0], tokens=toks,
                                       logprobs=lps)]

    def transport_stats(self) -> Dict:
        return self.transport.stats()

    def jit_entries(self) -> Dict[str, object]:
        out = super().jit_entries()
        out["_tick_jit"] = self._tick_jit
        out["_pf_tick_jit"] = self._pf_tick_jit
        return out

    @property
    def swap_count(self) -> int:
        n = sum(o.swap_count for o in self._stage_off)
        return n + (self._epi_off.swap_count if self._epi_off else 0)


def make_backend(kind, cfg, params, rt, *, mb_size, num_microbatches, pool,
                 offloader=None, n_stages=2, mesh=None, fault_plan=None,
                 transport=None, schedule="circular", wire_dtype="fp32",
                 sample_fast_path=True, offload_async=True,
                 recorder=None) -> ExecutionBackend:
    """Engine-side factory: ``kind`` is "local", "pipelined", or an already
    constructed :class:`ExecutionBackend` (passed through)."""
    if isinstance(kind, ExecutionBackend):
        return kind
    if kind == "local":
        if fault_plan is not None:
            raise ValueError(
                "fault injection (FaultPlan) requires the pipelined "
                "backend — the local backend has no stages to drop")
        if transport is not None or schedule != "circular" \
                or wire_dtype != "fp32":
            raise ValueError(
                "stage transports / schedules / wire codecs require the "
                "pipelined backend — the local backend has no stage "
                "boundaries for a link to cross")
        return LocalBackend(cfg, params, rt, mb_size=mb_size,
                            num_microbatches=num_microbatches, pool=pool,
                            offloader=offloader,
                            sample_fast_path=sample_fast_path,
                            recorder=recorder)
    if kind == "pipelined":
        return PipelinedBackend(cfg, params, rt, mb_size=mb_size,
                                num_microbatches=num_microbatches, pool=pool,
                                n_stages=n_stages,
                                offload=offloader is not None, mesh=mesh,
                                fault_plan=fault_plan, transport=transport,
                                schedule=schedule, wire_dtype=wire_dtype,
                                sample_fast_path=sample_fast_path,
                                offload_async=offload_async,
                                recorder=recorder)
    raise ValueError(f"unknown backend {kind!r} (want 'local'|'pipelined')")
