"""Pure-jnp numerical oracles for every Pallas kernel in this package.

Each oracle is the *definition* of correctness; the kernels must match it to
float tolerance across shape/dtype sweeps (see tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# flash-attention oracle: the chunked online-softmax reference.
from repro.models.attention import decode_attention as decode_attention_ref
from repro.models.attention import flash_attention as flash_attention_ref

# RG-LRU oracle: parallel associative-scan form.
from repro.models.rglru import rglru_scan as _rglru_assoc


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    return _rglru_assoc(a.astype(jnp.float32), b.astype(jnp.float32),
                        h0.astype(jnp.float32))


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, seq_lens, *,
                               window: int = 0):
    """Gather-then-attend oracle for the paged decode kernel.

    Shapes as in ``repro.kernels.paged_attention.paged_decode_attention``.
    """
    b, h, dh = q.shape
    page_size = k_pages.shape[1]
    max_pages = page_table.shape[1]
    c = max_pages * page_size
    pt = jnp.clip(page_table, 0, k_pages.shape[0] - 1)
    k = k_pages[pt].reshape(b, c, *k_pages.shape[2:])    # (B, C, Hk, Dh)
    v = v_pages[pt].reshape(b, c, *v_pages.shape[2:])
    pos = jnp.arange(c)[None]                            # logical positions
    slot_pos = jnp.where(pos < seq_lens[:, None], pos, -1).astype(jnp.int32)
    cur = (seq_lens - 1).astype(jnp.int32)
    return decode_attention_ref(q, k, v, slot_pos, cur, window=window)
