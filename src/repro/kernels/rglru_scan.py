"""Pallas TPU kernel for the RG-LRU linear recurrence  h_t = a_t·h_{t-1} + b_t.

The recurrence is bandwidth-bound (2 loads + 1 store per element, 2 FLOPs),
so the kernel's job is streaming: tile (S, Dr) into (s_blk, d_blk) VMEM
blocks, carry ``h`` across sequence blocks in VMEM scratch, and let the VPU
process ``d_blk`` lanes per time step.  Grid = (B, n_d, n_s) with the
sequence dimension innermost (sequential on TPU, carries the scratch).

Oracle: ``repro.models.rglru.rglru_scan`` (associative_scan form).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, carry_ref, *, s_blk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = h0_ref[...]

    def body(i, _):
        h = a_ref[0, i] * carry_ref[0] + b_ref[0, i]
        carry_ref[0] = h
        o_ref[0, i] = h
        return 0

    jax.lax.fori_loop(0, s_blk, body, 0)


@functools.partial(jax.jit, static_argnames=("s_blk", "d_blk", "interpret"))
def rglru_scan_pallas(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                      s_blk: int = 256, d_blk: int = 512,
                      interpret: bool = True) -> jax.Array:
    """a, b (B, S, Dr) f32; h0 (B, Dr) f32 -> all h_t (B, S, Dr) f32."""
    bsz, s, dr = a.shape
    s_blk = min(s_blk, s)
    d_blk = min(d_blk, dr)
    ps, pd = (-s) % s_blk, (-dr) % d_blk
    if ps or pd:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pd)))
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pd)))
        h0 = jnp.pad(h0, ((0, 0), (0, pd)))
    n_s = (s + ps) // s_blk
    n_d = (dr + pd) // d_blk

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, s_blk=s_blk),
        grid=(bsz, n_d, n_s),
        in_specs=[
            pl.BlockSpec((1, s_blk, d_blk), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, s_blk, d_blk), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, d_blk), lambda bi, di, si: (bi, di)),
        ],
        out_specs=pl.BlockSpec((1, s_blk, d_blk),
                               lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((bsz, s + ps, dr + pd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, d_blk), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32))
    return out[:, :s, :dr]
