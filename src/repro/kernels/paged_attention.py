"""Pallas TPU paged decode-attention kernel.

This is the TPU adaptation of the paper's FlashInfer paged-KV decode path:
the KV cache lives in a *page pool* (``(n_pages, page_size, Hk, Dh)``) and
each sequence owns a list of pages (``page_table`` (B, max_pages)).  The
kernel streams a sequence's pages into VMEM — the page indirection is
resolved by the BlockSpec index_map reading the scalar-prefetched page table
(``PrefetchScalarGridSpec``), so pages travel HBM→VMEM without a gather
materialising the contiguous KV.

Layout/tuning (FlashInfer-style multi-page streaming):

* Grid = ``(B, Hk, n_blocks)`` — one grid dimension per KV head so GQA
  groups never share a softmax scratch, and the innermost dimension walks
  *blocks* of ``pages_per_block`` pages.  Each grid step DMAs
  ``pages_per_block`` pages and runs ONE online-softmax rescale over all of
  them, amortising the rescale and the per-step DMA setup that a
  one-page-per-step walk pays ``pages_per_block`` times.
* ``pages_per_block`` is autotuned per ``(page_size, Dh, G)`` via
  ``tuned_pages_per_block`` (overridable per call).
* The running ``m``/``l`` statistics live in one fused ``(G, 2)`` VMEM
  scratch (column 0 = running max, column 1 = running denominator) — one
  buffer to initialise and one address stream instead of two.
* With a sliding window, blocks entirely below the window are skipped
  before the dot (``pl.when`` on the block-level live predicate), not
  merely masked after it.

Skipped-slot handling: table slots at or beyond ``ceil(seq_len/page_size)``
carry no meaning, and earlier revisions clamped their *page id* to pool
page 0 — issuing a (read-only, masked) DMA against whatever request owns
page 0.  That aliasing assumption is gone: the index map now clamps the
*slot* to the sequence's own last valid page, so masked grid steps only
ever re-read a page the row already owns.  The one residual read outside a
row's pages is the ``seq_len == 0`` row (no valid pages at all), which
reads the page id in its own table slot 0 — the allocator zero-fills
unused table rows, and pool page 0 is the allocator's reserved scratch
page, never user data.

Oracle: ``repro.kernels.ref.paged_decode_attention_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# Autotuned block choices, keyed (page_size, Dh, G).  Values picked so one
# grid step streams a few hundred KV tokens (amortising the rescale) while
# the K+V block pair stays well inside VMEM at bf16.  Shapes not listed
# fall back to the same ~512-token target with a VMEM-budget cap.
_TUNED_PPB = {
    (8, 64, 1): 8, (8, 64, 2): 8, (8, 64, 4): 8, (8, 64, 8): 4,
    (8, 128, 1): 8, (8, 128, 2): 4, (8, 128, 4): 4, (8, 128, 8): 4,
    (16, 64, 1): 4, (16, 64, 2): 4, (16, 64, 4): 4, (16, 64, 8): 2,
    (16, 128, 1): 4, (16, 128, 2): 4, (16, 128, 4): 2, (16, 128, 8): 2,
    (32, 64, 1): 2, (32, 64, 4): 2, (32, 128, 1): 2, (32, 128, 4): 2,
    (64, 64, 1): 2, (64, 128, 1): 1, (128, 64, 1): 1, (128, 128, 1): 1,
}
_PPB_VMEM_CAP = 128 * 1024        # bytes per K/V block pair (bf16)


def tuned_pages_per_block(page_size: int, dh: int, g: int) -> int:
    """Pages streamed per grid step for a ``(page_size, Dh, G)`` shape."""
    ppb = _TUNED_PPB.get((page_size, dh, g))
    if ppb is None:
        target = 512 if dh <= 64 else 256          # KV tokens per step
        ppb = max(1, target // page_size)
        while ppb > 1 and 2 * ppb * page_size * dh * 2 > _PPB_VMEM_CAP:
            ppb //= 2
    return ppb


def _paged_kernel(pt_ref, len_ref, q_ref, *refs, page_size: int,
                  g: int, window: int, ppb: int, n_blocks: int):
    ks = refs[:ppb]
    vs = refs[ppb:2 * ppb]
    o_ref = refs[2 * ppb]
    acc_ref, ml_ref = refs[2 * ppb + 1], refs[2 * ppb + 2]

    b = pl.program_id(0)
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ml_ref[:, 0] = jnp.full((g,), NEG_INF, jnp.float32)
        ml_ref[:, 1] = jnp.zeros((g,), jnp.float32)

    seq_len = len_ref[b]                       # tokens in cache (incl. current)
    n_pages = (seq_len + page_size - 1) // page_size
    base = blk * ppb                           # first page slot of this block
    live = base < n_pages
    if window > 0:
        # first in-window token is seq_len - window; blocks whose last page
        # ends before it contribute nothing — skip them before the dot.
        lo_page = jnp.maximum(seq_len - window, 0) // page_size
        live = jnp.logical_and(live, base + ppb > lo_page)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                        # (G, Dh)
        if ppb == 1:
            k = ks[0][0, :, 0]                 # (page_size, Dh)
            v = vs[0][0, :, 0]
        else:
            k = jnp.concatenate([kr[0, :, 0] for kr in ks], axis=0)
            v = jnp.concatenate([vr[0, :, 0] for vr in vs], axis=0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / (q_ref.shape[-1] ** 0.5))          # (G, ppb·page)

        span = ppb * page_size
        tok = base * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, span), 1)
        mask = tok < seq_len
        if window > 0:
            mask &= tok > seq_len - 1 - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = ml_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        pr = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        ml_ref[:, 1] = ml_ref[:, 1] * alpha + jnp.sum(pr, axis=-1)
        pv = jax.lax.dot_general(pr.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        ml_ref[:, 0] = m_cur

    @pl.when(blk == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(ml_ref[:, 1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "pages_per_block", "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           seq_lens: jax.Array, *, window: int = 0,
                           pages_per_block: int = 0,
                           interpret: bool = True) -> jax.Array:
    """Decode attention over a paged KV pool.

    q          (B, H, Dh)         current-token queries
    k/v_pages  (P, page, Hk, Dh)  shared page pool
    page_table (B, max_pages)     page ids per sequence (row-major in time)
    seq_lens   (B,)               tokens present per sequence
    pages_per_block               KV pages streamed per grid step
                                  (0 = autotuned per (page_size, Dh, G))
    -> (B, H, Dh)
    """
    b, h, dh = q.shape
    n_pool, page_size, hk, _ = k_pages.shape
    g = h // hk
    max_pages = page_table.shape[1]

    ppb = pages_per_block or tuned_pages_per_block(page_size, dh, g)
    ppb = max(1, min(ppb, max_pages))
    n_blocks = (max_pages + ppb - 1) // ppb

    qr = q.reshape(b, hk, g, dh)
    # defensive pool-range clamp (matches the oracle); the slot clamp in
    # the index maps below is what keeps skipped steps on the row's pages
    pt = jnp.clip(page_table, 0, n_pool - 1).astype(jnp.int32)

    def _kv_map(j):
        def index_map(bi, hi, blki, pt_ref, len_ref):
            # clamp the slot to this row's own last valid page: masked
            # grid steps re-read a page the row owns instead of page 0
            n_pages = (len_ref[bi] + page_size - 1) // page_size
            last = jnp.minimum(jnp.maximum(n_pages - 1, 0), max_pages - 1)
            slot = jnp.minimum(blki * ppb + j, last)
            return (pt_ref[bi, slot], 0, hi, 0)
        return index_map

    kv_specs = [pl.BlockSpec((1, page_size, 1, dh), _kv_map(j))
                for j in range(ppb)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda bi, hi, pi, pt_ref, len_ref: (bi, hi, 0, 0)),
            *kv_specs,
            *kv_specs,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, dh),
            lambda bi, hi, pi, pt_ref, len_ref: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 2), jnp.float32),      # fused (m, l) statistics
        ],
    )
    kernel = functools.partial(_paged_kernel, page_size=page_size,
                               g=g, window=window, ppb=ppb,
                               n_blocks=n_blocks)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dh), q.dtype),
        interpret=interpret,
    )(pt, seq_lens.astype(jnp.int32), qr,
      *([k_pages] * ppb), *([v_pages] * ppb))
    return out.reshape(b, h, dh)
