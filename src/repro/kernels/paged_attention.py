"""Pallas TPU paged decode-attention kernel.

This is the TPU adaptation of the paper's FlashInfer paged-KV decode path:
the KV cache lives in a *page pool* (``(n_pages, page_size, Hk, Dh)``) and
each sequence owns a list of pages (``page_table`` (B, max_pages)).  The
kernel walks a sequence's pages, DMA-ing one page per grid step into VMEM —
the page indirection is resolved by the BlockSpec index_map reading the
scalar-prefetched page table (``PrefetchScalarGridSpec``), so pages stream
HBM→VMEM without a gather materialising the contiguous KV.

Grid = (B, Hk, max_pages); online softmax in VMEM scratch; pages beyond
``ceil(seq_len / page_size)`` are skipped with ``pl.when`` (no DMA issued for
unused table slots on TPU since the index map still reads a valid page id —
we clamp to page 0 — but the FLOPs are skipped).

Oracle: ``repro.kernels.ref.paged_decode_attention_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page_size: int, max_pages: int,
                  g: int, window: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = len_ref[b]                       # tokens in cache (incl. current)
    n_pages = (seq_len + page_size - 1) // page_size

    @pl.when(p < n_pages)
    def _compute():
        q = q_ref[0, 0]                        # (G, Dh)
        k = k_ref[0, :, 0]                     # (page_size, Dh)
        v = v_ref[0, :, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / (q_ref.shape[-1] ** 0.5))          # (G, page)

        tok = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1)
        mask = tok < seq_len
        if window > 0:
            mask &= tok > seq_len - 1 - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        pr = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(pr, axis=-1)
        pv = jax.lax.dot_general(pr.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[:, 0] = m_cur

    @pl.when(p == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           seq_lens: jax.Array, *, window: int = 0,
                           interpret: bool = True) -> jax.Array:
    """Decode attention over a paged KV pool.

    q          (B, H, Dh)         current-token queries
    k/v_pages  (P, page, Hk, Dh)  shared page pool
    page_table (B, max_pages)     page ids per sequence (row-major in time)
    seq_lens   (B,)               tokens present per sequence
    -> (B, H, Dh)
    """
    b, h, dh = q.shape
    n_pool, page_size, hk, _ = k_pages.shape
    g = h // hk
    max_pages = page_table.shape[1]

    qr = q.reshape(b, hk, g, dh)
    # clamp table so skipped slots still index a resident page
    pt = jnp.clip(page_table, 0, n_pool - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda bi, hi, pi, pt_ref, len_ref: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda bi, hi, pi, pt_ref, len_ref:
                         (pt_ref[bi, pi], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda bi, hi, pi, pt_ref, len_ref:
                         (pt_ref[bi, pi], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, dh),
            lambda bi, hi, pi, pt_ref, len_ref: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel, page_size=page_size,
                               max_pages=max_pages, g=g, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dh), q.dtype),
        interpret=interpret,
    )(pt, seq_lens.astype(jnp.int32), qr, k_pages, v_pages)
    return out.reshape(b, h, dh)
