"""jit'd dispatch layer over the Pallas kernels.

Routes to the Pallas implementation when the shape is TPU-tileable, and to
the pure-jnp oracle otherwise.  On non-TPU backends the kernels execute in
``interpret=True`` mode (Python evaluation of the kernel body) — numerically
identical, structurally the same program.

The routing predicate is conservative: Pallas requires the head dim to be a
multiple of the 128-lane register width for MXU efficiency (64 is accepted:
it packs two heads per register row on v5e).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import (flash_attention_pallas,
                                           tuned_flash_blocks)
from repro.kernels.paged_attention import paged_decode_attention as _paged_pl
from repro.kernels.rglru_scan import rglru_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _aligned(*dims: int) -> bool:
    return all(d % 64 == 0 and d > 0 for d in dims)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 512, q_offset=0,
                    scheme: str = "masked"):
    """Drop-in replacement for the jnp flash attention (prefill/train)."""
    dh = q.shape[-1]
    sq, skv = q.shape[1], k.shape[1]
    offset_static = isinstance(q_offset, int) and q_offset == 0
    if _aligned(dh) and offset_static and sq >= 8 and skv >= 8:
        g = q.shape[2] // k.shape[2]
        tq, tkv = tuned_flash_blocks(dh, g)
        q_blk = max(8, min(q_chunk, tq))
        kv_blk = max(8, min(kv_chunk, tkv))
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_blk=q_blk, kv_blk=kv_blk,
                                      interpret=_interpret())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, q_chunk=q_chunk,
                                   kv_chunk=kv_chunk, scheme=scheme)


def decode_attention(q, k_cache, v_cache, slot_pos, cur_pos, *,
                     window: int = 0):
    """Dense-cache decode attention (jnp; the paged pool path is the kernel)."""
    return ref.decode_attention_ref(q, k_cache, v_cache, slot_pos, cur_pos,
                                    window=window)


def paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                           window: int = 0, pages_per_block: int = 0):
    """``pages_per_block=0`` autotunes the per-grid-step page count from
    the ``(page_size, Dh, G)`` shape (see ``tuned_pages_per_block``)."""
    dh = q.shape[-1]
    page_size = k_pages.shape[1]
    if _aligned(dh) and page_size % 8 == 0:
        return _paged_pl(q, k_pages, v_pages, page_table, seq_lens,
                         window=window, pages_per_block=pages_per_block,
                         interpret=_interpret())
    return ref.paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                                          seq_lens, window=window)


def rglru_scan(a, b, h0=None):
    bsz, s, dr = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, dr), jnp.float32)
    if dr % 128 == 0 and s >= 8:
        return rglru_scan_pallas(a, b, h0, interpret=_interpret())
    return ref.rglru_scan_ref(a, b, h0)
