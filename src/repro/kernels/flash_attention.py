"""Pallas TPU flash-attention (prefill/train) kernel.

Tiling: grid = (B·Hk, n_q_blocks, n_kv_blocks), kv innermost (sequential on
TPU), online-softmax state in VMEM scratch.  GQA is handled by folding the
``G = H // Hk`` query-group dimension into the q rows of each block, so the
MXU sees (G·q_blk, Dh) x (Dh, kv_blk) matmuls — hardware-aligned when
``G·q_blk`` and ``kv_blk`` are multiples of 128 and Dh ∈ {64,128,256,512}.

Causality and sliding windows are enforced twice: whole out-of-span kv blocks
are skipped via ``pl.when`` (no FLOPs, no DMA waste — this is the exact-FLOPs
"blockpair" scheme of the jnp reference), and the diagonal blocks are masked
elementwise.

The pure-jnp oracle is ``repro.models.attention.flash_attention``
(re-exported in ``repro.kernels.ref``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, ml_ref, *,
                  q_blk: int, kv_blk: int, n_kv: int, g: int, causal: bool,
                  window: int, sq_real: int, skv_real: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ml_ref[:, 0] = jnp.full((g * q_blk,), NEG_INF, jnp.float32)
        ml_ref[:, 1] = jnp.zeros((g * q_blk,), jnp.float32)

    q_start = qi * q_blk
    kv_start = ki * kv_blk

    live = None
    if causal:
        live = kv_start <= q_start + q_blk - 1
    if window > 0:
        w_live = kv_start + kv_blk - 1 > q_start - window
        live = w_live if live is None else jnp.logical_and(live, w_live)

    def _compute():
        q = q_ref[0].reshape(g * q_blk, q_ref.shape[-1])       # (G·qb, Dh)
        k = k_ref[0]                                           # (kvb, Dh)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                # (G·qb, kvb)
        scale = 1.0 / (q_ref.shape[-1] ** 0.5)
        s = s * scale

        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, kv_blk), 0)
        kv_pos = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_blk, kv_blk), 1)
        mask = (kv_pos < skv_real) & (q_pos < sq_real)
        if causal:
            mask &= kv_pos <= q_pos
        if window > 0:
            mask &= kv_pos > q_pos - window
        mask = jnp.broadcast_to(mask[None], (g, q_blk, kv_blk)).reshape(
            g * q_blk, kv_blk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = ml_ref[:, 0]                                  # (G·qb,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        ml_ref[:, 1] = ml_ref[:, 1] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (G·qb, Dh)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        ml_ref[:, 0] = m_cur

    if live is None:
        _compute()
    else:
        pl.when(live)(_compute)

    # finalize on the last kv block this q block visits
    if causal:
        last_ki = jnp.minimum(n_kv - 1, (q_start + q_blk - 1) // kv_blk)
    else:
        last_ki = n_kv - 1

    @pl.when(ki == last_ki)
    def _finalize():
        l = jnp.maximum(ml_ref[:, 1], 1e-30)
        out = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[0] = out.reshape(g, q_blk, o_ref.shape[-1])


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_blk", "kv_blk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           q_blk: int = 128, kv_blk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q (B,Sq,H,Dh); k,v (B,Skv,Hk,Dh) -> (B,Sq,H,Dh)."""
    b, sq, h, dh = q.shape
    skv, hk = k.shape[1], k.shape[2]
    g = h // hk
    q_blk = min(q_blk, max(8, sq))
    kv_blk = min(kv_blk, max(8, skv))
    pq, pkv = (-sq) % q_blk, (-skv) % kv_blk

    # (B,S,H,Dh) -> (B·Hk, G, S, Dh)
    qr = q.transpose(0, 2, 1, 3).reshape(b, hk, g, sq, dh)
    qr = qr.reshape(b * hk, g, sq, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hk, skv, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hk, skv, dh)
    if pq:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        kr = jnp.pad(kr, ((0, 0), (0, pkv), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pkv), (0, 0)))
    n_q = (sq + pq) // q_blk
    n_kv = (skv + pkv) // kv_blk

    kernel = functools.partial(
        _flash_kernel, q_blk=q_blk, kv_blk=kv_blk, n_kv=n_kv, g=g,
        causal=causal, window=window, sq_real=sq, skv_real=skv)

    out = pl.pallas_call(
        kernel,
        grid=(b * hk, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, g, q_blk, dh), lambda bh, qi, ki: (bh, 0, qi, 0)),
            pl.BlockSpec((1, kv_blk, dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, kv_blk, dh), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, q_blk, dh),
                               lambda bh, qi, ki: (bh, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hk, g, sq + pq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * q_blk, dh), jnp.float32),
            pltpu.VMEM((g * q_blk, 2), jnp.float32),   # fused (m, l) stats
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = out[:, :, :sq].reshape(b, hk, g, sq, dh).reshape(b, h, sq, dh)
    return out.transpose(0, 2, 1, 3)


def vmem_bytes(q_blk: int, kv_blk: int, g: int, dh: int,
               dtype_bytes: int = 2) -> int:
    """Static VMEM footprint of one grid step (block inputs + scratch)."""
    blocks = (g * q_blk * dh + 2 * kv_blk * dh + g * q_blk * dh) * dtype_bytes
    scratch = (g * q_blk * dh + g * q_blk * 2) * 4   # acc + fused (m, l)
    return blocks + scratch


# Tuned (q_blk, kv_blk) choices keyed (Dh, G): bigger kv blocks when the
# per-row footprint is small, shrinking as G·Dh grows so q + kv blocks +
# scratch stay inside the ~12 MB VMEM budget (see vmem_bytes).
_TUNED_BLOCKS = {
    (64, 1): (128, 256), (64, 2): (128, 256), (64, 4): (128, 128),
    (64, 8): (64, 128), (128, 1): (128, 256), (128, 2): (128, 128),
    (128, 4): (64, 128), (128, 8): (32, 128), (256, 1): (64, 128),
    (256, 2): (32, 128), (256, 4): (32, 64),
}


def tuned_flash_blocks(dh: int, g: int) -> tuple:
    """(q_blk, kv_blk) for a (Dh, G) head layout (fallback: 128/128)."""
    return _TUNED_BLOCKS.get((dh, g), (128, 128))
