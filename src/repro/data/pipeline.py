"""Synthetic-token data pipeline: deterministic generation, document packing,
sharded per-host loading.

The framework trains on language-model token streams; without a licensed
corpus in the container we generate a *structured* synthetic stream (Zipfian
unigrams + a repeated-bigram process) — enough signal that the training loss
drops measurably, which the integration tests assert.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int                   # per-host batch
    accum_steps: int = 1
    seed: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 128
    bigram_repeat_p: float = 0.6      # P(copy a previously seen bigram)


class SyntheticTokens:
    """Deterministic document stream with learnable local structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.RandomState(cfg.seed)
        # fixed random bigram table: next(token) is predictable 60% of time
        self._next = self.rng.randint(1, cfg.vocab_size,
                                      size=(cfg.vocab_size,))

    def document(self) -> np.ndarray:
        c = self.cfg
        n = max(2, int(self.rng.exponential(c.mean_doc_len)))
        toks = np.empty((n,), np.int64)
        toks[0] = 1 + self.rng.zipf(c.zipf_a) % (c.vocab_size - 1)
        for i in range(1, n):
            if self.rng.rand() < c.bigram_repeat_p:
                toks[i] = self._next[toks[i - 1]]
            else:
                toks[i] = 1 + self.rng.zipf(c.zipf_a) % (c.vocab_size - 1)
        return toks

    def packed_stream(self) -> Iterator[np.ndarray]:
        """Pack documents into fixed seq_len rows, 0 as separator."""
        c = self.cfg
        buf = np.empty((0,), np.int64)
        while True:
            while buf.size < c.seq_len + 1:
                buf = np.concatenate([buf, [0], self.document()])
            yield buf[: c.seq_len + 1].copy()
            buf = buf[c.seq_len:]


def batches(cfg: DataConfig, *, host_index: int = 0,
            host_count: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    """Yield {"tokens","labels","loss_mask"} batches, disjoint across hosts
    (host h consumes rows h, h+H, h+2H, ... of the global stream)."""
    stream_cfg = dataclasses.replace(cfg, seed=cfg.seed)
    rows = SyntheticTokens(stream_cfg).packed_stream()

    def one_batch():
        out = np.empty((cfg.batch_size, cfg.seq_len + 1), np.int64)
        got = 0
        i = 0
        while got < cfg.batch_size:
            row = next(rows)
            if i % host_count == host_index:
                out[got] = row
                got += 1
            i += 1
        tokens = out[:, :-1].astype(np.int32)
        labels = out[:, 1:].astype(np.int32)
        mask = (labels != 0).astype(np.float32)
        return {"tokens": tokens, "labels": labels, "loss_mask": mask}

    while True:
        if cfg.accum_steps > 1:
            bs = [one_batch() for _ in range(cfg.accum_steps)]
            yield {k: np.stack([b[k] for b in bs]) for k in bs[0]}
        else:
            yield one_batch()
