"""Model assembly: init, train forward, prefill, decode — for every arch.

The network is described by ``cfg.block_pattern`` tiled over ``num_layers``
(see ``repro.config``).  Execution scans over *pattern periods* with weights
stacked over periods (HLO size O(period), not O(depth)); the remainder
("tail") layers are unrolled.  The same layer-apply code serves four modes:

  train    — full-sequence forward, no caches, chunked CE loss
  prefill  — full-sequence forward, caches written
  decode   — single-token step against caches
  stage    — a contiguous slice of periods (used by the PP pipeline)

Caches are plain pytrees:
  attention kinds:  {"k": (B,C,Hk,Dh), "v": ..., "pos": (B,C) int32 (-1 empty)}
  rglru:            {"h": (B,Dr) f32, "conv": (B,cw-1,Dr)}
  mlstm:            {"c": (B,H,dh,dh) f32, "n": (B,H,dh) f32, "m": (B,H) f32}
  slstm:            {"c","n","h","m": (B,Dr) f32}
arranged as {"scan": [per-period-position, leading axis n_periods], "tail": [...]}.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ATTN_KINDS, RECURRENT_KINDS, ModelConfig
from repro.models import attention as attn_lib
from repro.models import embedding as embed_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import (DEFAULT_RUNTIME, KeyGen, LayerPlan, Runtime,
                                 dense_init, make_layer_plan, patch_positions3,
                                 rms_norm, swiglu, text_positions3)

LOCAL_ROPE_THETA = 10000.0      # gemma3: local layers keep the small base


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_attn_layer(kg: KeyGen, cfg: ModelConfig, rt: Runtime) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, Hk, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = rt.param_dtype
    w = {
        "ln1": jnp.zeros((D,), pd),
        "wq": dense_init(kg(), (D, H * Dh), pd),
        "wk": dense_init(kg(), (D, Hk * Dh), pd),
        "wv": dense_init(kg(), (D, Hk * Dh), pd),
        "wo": dense_init(kg(), (H * Dh, D), pd, fan_in=H * Dh),
    }
    if cfg.use_qk_norm:
        w["q_norm"] = jnp.zeros((Dh,), pd)
        w["k_norm"] = jnp.zeros((Dh,), pd)
    if cfg.moe is not None:
        E, dE = cfg.moe.num_experts, cfg.moe.d_expert
        w["ln2"] = jnp.zeros((D,), pd)
        w["moe"] = {
            "router": dense_init(kg(), (D, E), jnp.float32),
            "wg": dense_init(kg(), (E, D, dE), pd, fan_in=D),
            "wu": dense_init(kg(), (E, D, dE), pd, fan_in=D),
            "wd": dense_init(kg(), (E, dE, D), pd, fan_in=dE),
        }
    elif F > 0:
        w["ln2"] = jnp.zeros((D,), pd)
        w["wg"] = dense_init(kg(), (D, F), pd)
        w["wu"] = dense_init(kg(), (D, F), pd)
        w["wd"] = dense_init(kg(), (F, D), pd, fan_in=F)
    return w


def _init_rglru_layer(kg: KeyGen, cfg: ModelConfig, rt: Runtime) -> dict:
    D, F, Dr = cfg.d_model, cfg.d_ff, cfg.d_rnn
    H = cfg.num_heads
    dh = Dr // H
    pd = rt.param_dtype
    # RG-LRU Lambda init: a in [0.9, 0.999] -> lam = softplus^{-1}(-log(a)/c)
    a = np.random.RandomState(0).uniform(0.9, 0.999, (Dr,))
    lam = np.log(np.expm1(-np.log(a) / rglru_lib.RGLRU_C))
    w = {
        "ln1": jnp.zeros((D,), pd),
        "wg": dense_init(kg(), (D, Dr), pd),
        "wx": dense_init(kg(), (D, Dr), pd),
        "conv_w": dense_init(kg(), (cfg.conv_width, Dr), pd, fan_in=cfg.conv_width),
        "conv_b": jnp.zeros((Dr,), pd),
        "gate_a_w": dense_init(kg(), (H, dh, dh), pd, fan_in=dh),
        "gate_a_b": jnp.zeros((Dr,), jnp.float32),
        "gate_x_w": dense_init(kg(), (H, dh, dh), pd, fan_in=dh),
        "gate_x_b": jnp.zeros((Dr,), jnp.float32),
        "lam": jnp.asarray(lam, jnp.float32),
        "wo": dense_init(kg(), (Dr, D), pd, fan_in=Dr),
    }
    if F > 0:
        w["ln2"] = jnp.zeros((D,), pd)
        w["wg_mlp"] = dense_init(kg(), (D, F), pd)
        w["wu"] = dense_init(kg(), (D, F), pd)
        w["wd"] = dense_init(kg(), (F, D), pd, fan_in=F)
    return w


def _init_mlstm_layer(kg: KeyGen, cfg: ModelConfig, rt: Runtime) -> dict:
    D, Dr, H = cfg.d_model, cfg.d_rnn, cfg.num_heads
    dh = Dr // H
    pd = rt.param_dtype
    return {
        "ln1": jnp.zeros((D,), pd),
        "wm": dense_init(kg(), (D, Dr), pd),
        "wz": dense_init(kg(), (D, Dr), pd),
        "wq": dense_init(kg(), (H, dh, dh), pd, fan_in=dh),
        "wk": dense_init(kg(), (H, dh, dh), pd, fan_in=dh),
        "wv": dense_init(kg(), (H, dh, dh), pd, fan_in=dh),
        "w_i": dense_init(kg(), (H, dh), jnp.float32, fan_in=dh),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": dense_init(kg(), (H, dh), jnp.float32, fan_in=dh),
        # positive forget bias: start remembering (xLSTM init, 3..6 per head)
        "b_f": jnp.linspace(3.0, 6.0, H, dtype=jnp.float32),
        "wo": dense_init(kg(), (Dr, D), pd, fan_in=Dr),
    }


def _init_slstm_layer(kg: KeyGen, cfg: ModelConfig, rt: Runtime) -> dict:
    D, Dr, H = cfg.d_model, cfg.d_rnn, cfg.num_heads
    dh = Dr // H
    pd = rt.param_dtype
    b_in = np.zeros((4, Dr), np.float32)
    b_in[1] = 3.0                       # forget-gate positive bias
    return {
        "ln1": jnp.zeros((D,), pd),
        "w_in": dense_init(kg(), (4, D, Dr), pd, fan_in=D),
        "b_in": jnp.asarray(b_in),
        "r": dense_init(kg(), (4, H, dh, dh), pd, fan_in=dh),
        "wo": dense_init(kg(), (Dr, D), pd, fan_in=Dr),
    }


_KIND_INIT = {
    "attn": _init_attn_layer, "local": _init_attn_layer,
    "global": _init_attn_layer, "rglru": _init_rglru_layer,
    "mlstm": _init_mlstm_layer, "slstm": _init_slstm_layer,
}


def _stack(trees: Sequence[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key: jax.Array,
                rt: Runtime = DEFAULT_RUNTIME) -> dict:
    kg = KeyGen(key)
    pd = rt.param_dtype
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    params: dict = {
        "embed": {"tok": dense_init(kg(), (cfg.vocab_size, cfg.d_model), pd,
                                    fan_in=cfg.d_model)},
        "final_norm": jnp.zeros((cfg.d_model,), pd),
    }
    if cfg.frontend == "audio_frames":
        params["embed"]["frame_proj"] = dense_init(
            kg(), (cfg.d_model, cfg.d_model), pd)
    elif cfg.frontend == "vision_patches":
        params["embed"]["patch_proj"] = dense_init(
            kg(), (cfg.d_model, cfg.d_model), pd)
    if not cfg.tie_embeddings:
        params["embed"]["untok"] = dense_init(
            kg(), (cfg.vocab_size, cfg.d_model), pd, fan_in=cfg.d_model)

    def init_period():
        return [_KIND_INIT[k](kg, cfg, rt) for k in plan.period_kinds]

    if plan.n_periods:
        periods = [init_period() for _ in range(plan.n_periods)]
        # list over period positions; each leaf stacked over n_periods
        params["scan"] = [_stack([p[i] for p in periods])
                         for i in range(len(plan.period_kinds))]
    else:
        params["scan"] = []
    params["tail"] = [_KIND_INIT[k](kg, cfg, rt) for k in plan.tail_kinds]
    return params


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _kind_cache(kind: str, cfg: ModelConfig, batch: int, capacity: int,
                rt: Runtime, lead: tuple = ()):
    cd = rt.compute_dtype
    Hk, Dh, Dr, H = cfg.num_kv_heads, cfg.head_dim, cfg.d_rnn, cfg.num_heads
    if kind in ATTN_KINDS:
        c = capacity if (kind != "local" or cfg.window_size == 0) else min(
            cfg.window_size, capacity)
        if rt.kv_dtype == "int8":
            # symmetric per-(token, head) quantization; halves the KV read
            # traffic that dominates the decode roofline (SPerf)
            return {
                "k": jnp.zeros(lead + (batch, c, Hk, Dh), jnp.int8),
                "v": jnp.zeros(lead + (batch, c, Hk, Dh), jnp.int8),
                "k_scale": jnp.zeros(lead + (batch, c, Hk), jnp.bfloat16),
                "v_scale": jnp.zeros(lead + (batch, c, Hk), jnp.bfloat16),
                "pos": jnp.full(lead + (batch, c), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros(lead + (batch, c, Hk, Dh), cd),
            "v": jnp.zeros(lead + (batch, c, Hk, Dh), cd),
            "pos": jnp.full(lead + (batch, c), -1, jnp.int32),
        }
    if kind == "rglru":
        return {
            "h": jnp.zeros(lead + (batch, Dr), jnp.float32),
            "conv": jnp.zeros(lead + (batch, cfg.conv_width - 1, Dr), cd),
        }
    if kind == "mlstm":
        dh = Dr // H
        return {
            "c": jnp.zeros(lead + (batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros(lead + (batch, H, dh), jnp.float32),
            "m": jnp.zeros(lead + (batch, H), jnp.float32),
        }
    if kind == "slstm":
        return {
            "c": jnp.zeros(lead + (batch, Dr), jnp.float32),
            "n": jnp.full(lead + (batch, Dr), 1e-6, jnp.float32),
            "h": jnp.zeros(lead + (batch, Dr), jnp.float32),
            "m": jnp.zeros(lead + (batch, Dr), jnp.float32),
        }
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, capacity: int,
                rt: Runtime = DEFAULT_RUNTIME) -> dict:
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    scan = [_kind_cache(k, cfg, batch, capacity, rt, lead=(plan.n_periods,))
            for k in plan.period_kinds] if plan.n_periods else []
    tail = [_kind_cache(k, cfg, batch, capacity, rt)
            for k in plan.tail_kinds]
    return {"scan": scan, "tail": tail}


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _apply_rope(q, k, positions, cfg: ModelConfig, theta: float):
    from repro.models.common import apply_mrope, apply_rope
    if positions.ndim == 3 and cfg.frontend == "vision_patches":
        return (apply_mrope(q, positions, theta),
                apply_mrope(k, positions, theta))
    pos = positions[0] if positions.ndim == 3 else positions
    return (apply_rope(q, pos, theta, cfg.rope_scaling),
            apply_rope(k, pos, theta, cfg.rope_scaling))


def _write_prefill_paged(cache, k, v, positions):
    """Scatter a prefill's k/v into the shared page pool.

    Positions marked ``-1`` (padding) are redirected to an out-of-bounds
    page index, so jit scatter semantics drop the write — pad tokens never
    touch a live page."""
    n_pages, page_size = cache["k_pages"].shape[:2]
    pos = positions.astype(jnp.int32)                     # (B, S)
    valid = pos >= 0
    logical = jnp.maximum(pos, 0) // page_size
    page = jnp.take_along_axis(cache["page_table"], logical, axis=1)
    page = jnp.where(valid, page, n_pages)                # OOB -> dropped
    off = jnp.maximum(pos, 0) % page_size
    return {
        **cache,
        "k_pages": cache["k_pages"].at[page, off].set(k),
        "v_pages": cache["v_pages"].at[page, off].set(v),
    }


def _quantize_kv(x):
    """(..., Hk, Dh) -> (int8 values, bf16 per-(...,Hk) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequant_kv(cache, dtype):
    k = cache["k"]
    if k.dtype != jnp.int8:
        return cache["k"], cache["v"]
    kf = k.astype(jnp.float32) * cache["k_scale"].astype(
        jnp.float32)[..., None]
    vf = cache["v"].astype(jnp.float32) * cache["v_scale"].astype(
        jnp.float32)[..., None]
    return kf.astype(dtype), vf.astype(dtype)


def _write_prefill_cache(cache, k, v, positions):
    """Write a full prefill's k/v into a (possibly smaller ring) cache."""
    if "k_pages" in cache:
        return _write_prefill_paged(cache, k, v, positions)
    quant = cache["k"].dtype == jnp.int8
    if quant:
        k, k_s = _quantize_kv(k)
        v, v_s = _quantize_kv(v)
    C = cache["k"].shape[1]
    S = k.shape[1]
    if S <= C:
        out = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
            "pos": jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], positions.astype(jnp.int32), 0, 1),
        }
        if quant:
            out["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], k_s, 0, 1)
            out["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], v_s, 0, 1)
        return out
    # ring: keep the last C tokens at slot = pos % C; pad positions (-1)
    # scatter out of bounds (dropped) instead of clobbering slot C-1
    b = k.shape[0]
    k_t, v_t = k[:, S - C:], v[:, S - C:]
    pos_t = positions[:, S - C:].astype(jnp.int32)
    slot = jnp.where(pos_t >= 0, jnp.maximum(pos_t, 0) % C, C)
    bidx = jnp.arange(b)[:, None]
    out = {
        "k": cache["k"].at[bidx, slot].set(k_t),
        "v": cache["v"].at[bidx, slot].set(v_t),
        "pos": cache["pos"].at[bidx, slot].set(pos_t),
    }
    if quant:
        out["k_scale"] = cache["k_scale"].at[bidx, slot].set(
            k_s[:, S - C:])
        out["v_scale"] = cache["v_scale"].at[bidx, slot].set(
            v_s[:, S - C:])
    return out


def _attn_layer(kind, w, x, cfg: ModelConfig, rt: Runtime, *, positions,
                mode, cache):
    B = x.shape[0]
    H, Hk, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    window = cfg.window_size if kind == "local" else 0
    theta = LOCAL_ROPE_THETA if (kind == "local" and cfg.window_size) else \
        cfg.rope_theta

    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    S = h.shape[1]
    q = jnp.einsum("bsd,de->bse", h, w["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", h, w["wk"]).reshape(B, S, Hk, Dh)
    v = jnp.einsum("bsd,de->bse", h, w["wv"]).reshape(B, S, Hk, Dh)
    if cfg.use_qk_norm:
        q = rms_norm(q, w["q_norm"], cfg.norm_eps)
        k = rms_norm(k, w["k_norm"], cfg.norm_eps)
    q, k = _apply_rope(q, k, positions, cfg, theta)

    new_cache = cache
    if mode == "decode":
        cur = positions[0] if positions.ndim == 3 else positions
        cur = cur[:, 0] if cur.ndim == 2 else cur          # (B,)
        if "k_pages" in cache:                             # paged pool path
            from repro.kernels import ops as kops
            page_size = cache["k_pages"].shape[1]
            pos = cur.astype(jnp.int32)
            logical = pos // page_size
            page = jnp.take_along_axis(cache["page_table"],
                                       logical[:, None], axis=1)[:, 0]
            off = pos % page_size
            kp = cache["k_pages"].at[page, off].set(k[:, 0])
            vp = cache["v_pages"].at[page, off].set(v[:, 0])
            new_cache = {**cache, "k_pages": kp, "v_pages": vp}
            out = kops.paged_decode_attention(
                q[:, 0], kp, vp, cache["page_table"], pos + 1, window=window,
                pages_per_block=rt.attn_pages_per_block)
        else:                                              # dense/ring path
            C = cache["k"].shape[1]
            slot = (cur % C).astype(jnp.int32)
            bidx = jnp.arange(B)
            quant = cache["k"].dtype == jnp.int8
            kw, vw = (k[:, 0], v[:, 0])
            if quant:
                kw, k_s = _quantize_kv(kw)
                vw, v_s = _quantize_kv(vw)
            kc = cache["k"].at[bidx, slot].set(kw)
            vc = cache["v"].at[bidx, slot].set(vw)
            pc = cache["pos"].at[bidx, slot].set(cur.astype(jnp.int32))
            new_cache = {"k": kc, "v": vc, "pos": pc}
            if quant:
                new_cache["k_scale"] = cache["k_scale"].at[bidx, slot].set(k_s)
                new_cache["v_scale"] = cache["v_scale"].at[bidx, slot].set(v_s)
            kf, vf = _dequant_kv(new_cache, q.dtype)
            out = attn_lib.decode_attention(q[:, 0], kf, vf, pc, cur,
                                            window=window)
        out = out[:, None]                                  # (B,1,H,Dh)
    elif mode == "chunk":
        # chunked prefill continuation: write this chunk's KV into the
        # shared pool, then attend the chunk's queries against the row's
        # whole gathered extent (earlier chunks + this one).  Positions
        # carry validity: -1 marks padded tokens (writes dropped, queries
        # fully masked).  Restricted to paged layers — ring/recurrent
        # kinds take the exact-length fallback path.
        if "k_pages" not in cache:
            raise NotImplementedError(
                "chunked prefill supports paged attention layers only; "
                "ring (sliding-window) layers must use exact-length prefill")
        pos2d = positions[0] if positions.ndim == 3 else positions
        new_cache = _write_prefill_paged(cache, k, v, pos2d)
        pt = new_cache["page_table"]                        # (B, P)
        n_ctx = pt.shape[1] * new_cache["k_pages"].shape[1]
        kg = new_cache["k_pages"][pt].reshape(B, n_ctx, Hk, Dh)
        vg = new_cache["v_pages"][pt].reshape(B, n_ctx, Hk, Dh)
        out = attn_lib.chunk_attention(q, kg, vg, jnp.arange(n_ctx), pos2d,
                                       window=window)
    else:
        if rt.use_pallas:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True, window=window,
                                       q_chunk=rt.q_chunk, kv_chunk=rt.kv_chunk)
        else:
            out = attn_lib.flash_attention(
                q, k, v, causal=True, window=window, q_chunk=rt.q_chunk,
                kv_chunk=rt.kv_chunk, scheme=rt.causal_scheme)
        if mode == "prefill":
            pos2d = positions[0] if positions.ndim == 3 else positions
            new_cache = _write_prefill_cache(cache, k, v, pos2d)

    out = jnp.einsum("bse,ed->bsd",
                     out.reshape(B, out.shape[1], H * Dh), w["wo"])
    x = x + out

    if cfg.moe is not None:
        h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
        n = h2.shape[0] * h2.shape[1]
        y = moe_lib.moe_ffn(h2.reshape(n, -1), w["moe"], cfg.moe,
                            token_chunk=rt.moe_chunk if mode == "train"
                            else 0)
        x = x + y.reshape(x.shape)
    elif cfg.d_ff > 0:
        h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, w["wg"], w["wu"], w["wd"])
    return x, new_cache


def _recurrent_valid(positions, mode):
    """Per-token validity mask for recurrent state updates.

    Prefill positions marked ``-1`` are right-padding (bucketed prefill):
    the recurrence must treat them as identity steps so the carried state
    equals the exact-length result.  Decode/train positions are always
    real — no mask, no masking cost."""
    if mode != "prefill":
        return None
    pos = positions[0] if positions.ndim == 3 else positions
    return pos >= 0


def _rglru_layer(kind, w, x, cfg, rt, *, positions, mode, cache):
    if mode == "chunk":
        raise NotImplementedError(
            "chunked prefill is not supported for recurrent layers")
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    y, new_state = rglru_lib.rglru_block(h, w, cfg.num_heads, mode=mode,
                                         state=cache,
                                         valid=_recurrent_valid(positions,
                                                                mode))
    x = x + y
    if cfg.d_ff > 0:
        h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, w["wg_mlp"], w["wu"], w["wd"])
    return x, new_state


def _mlstm_layer(kind, w, x, cfg, rt, *, positions, mode, cache):
    if mode == "chunk":
        raise NotImplementedError(
            "chunked prefill is not supported for recurrent layers")
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    y, new_state = xlstm_lib.mlstm_block(h, w, cfg.num_heads, mode=mode,
                                         state=cache, chunk=rt.mlstm_chunk,
                                         valid=_recurrent_valid(positions,
                                                                mode))
    return x + y, new_state


def _slstm_layer(kind, w, x, cfg, rt, *, positions, mode, cache):
    if mode == "chunk":
        raise NotImplementedError(
            "chunked prefill is not supported for recurrent layers")
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    y, new_state = xlstm_lib.slstm_block(h, w, cfg.num_heads, mode=mode,
                                         state=cache,
                                         valid=_recurrent_valid(positions,
                                                                mode))
    return x + y, new_state


_KIND_APPLY = {
    "attn": _attn_layer, "local": _attn_layer, "global": _attn_layer,
    "rglru": _rglru_layer, "mlstm": _mlstm_layer, "slstm": _slstm_layer,
}


def apply_layer(kind, w, x, cfg, rt, *, positions, mode, cache):
    return _KIND_APPLY[kind](kind, w, x, cfg, rt, positions=positions,
                             mode=mode, cache=cache)


# ---------------------------------------------------------------------------
# Layer stack execution
# ---------------------------------------------------------------------------


def run_periods(scan_params, x, cfg: ModelConfig, rt: Runtime, *,
                period_kinds, mode, scan_caches, positions):
    """Scan over stacked periods.  ``scan_params``/``scan_caches`` are lists
    over period positions with a leading period axis."""
    if not scan_params or scan_params[0] is None:
        return x, scan_caches
    have_cache = scan_caches is not None and mode != "train"

    def period_body(carry, xs):
        xc = carry
        if have_cache:
            pw, pc = xs
        else:
            pw, pc = xs, [None] * len(period_kinds)
        new_caches = []
        for i, kind in enumerate(period_kinds):
            xc, nc = apply_layer(kind, pw[i], xc, cfg, rt,
                                 positions=positions, mode=mode, cache=pc[i])
            new_caches.append(nc)
        if mode == "train":
            xc = constrain_activations(
                xc, sequence_parallel=rt.sequence_parallel,
                zero3=(rt.train_style == "zero3"))
        return xc, (new_caches if have_cache else None)

    body = period_body
    if rt.remat and mode == "train":
        body = jax.checkpoint(period_body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    xs = (scan_params, scan_caches) if have_cache else scan_params
    x, new_scan_caches = jax.lax.scan(body, x, xs)
    return x, new_scan_caches


def run_layers(params, x, cfg: ModelConfig, rt: Runtime, *, mode,
               caches, positions):
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    scan_caches = caches["scan"] if caches is not None else None
    x, new_scan = run_periods(params["scan"], x, cfg, rt,
                              period_kinds=plan.period_kinds, mode=mode,
                              scan_caches=scan_caches, positions=positions)
    new_tail = []
    for i, kind in enumerate(plan.tail_kinds):
        c = caches["tail"][i] if caches is not None else None
        x, nc = apply_layer(kind, params["tail"][i], x, cfg, rt,
                            positions=positions, mode=mode, cache=c)
        new_tail.append(nc)
    new_caches = None
    if caches is not None:
        new_caches = {"scan": new_scan, "tail": new_tail}
    return x, new_caches


# ---------------------------------------------------------------------------
# Inputs / embedding
# ---------------------------------------------------------------------------


def embed_inputs(params, inputs: dict, cfg: ModelConfig, rt: Runtime,
                 *, mode: str):
    """Returns (x (B,S,D), positions) from an input dict.

    inputs: {"tokens": (B,S)} or {"frames": (B,S,D)} (audio) or
    {"tokens": (B,S_text), "patches": (B,P,D)} (vlm).
    """
    cd = rt.compute_dtype
    if cfg.frontend == "audio_frames" and "frames" in inputs:
        x = embed_lib.embed_frames(params["embed"], inputs["frames"], cfg, cd)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return x, positions
    if cfg.frontend == "vision_patches" and "patches" in inputs:
        xp = embed_lib.embed_patches(params["embed"], inputs["patches"], cfg, cd)
        xt = embed_lib.embed_tokens(params["embed"], inputs["tokens"], cfg, cd)
        B, P = xp.shape[:2]
        St = xt.shape[1]
        x = jnp.concatenate([xp, xt], axis=1)
        p3_patch = patch_positions3(B, P)
        side = max(1, int(np.sqrt(P)))
        text_pos = side + jnp.arange(St)
        p3_text = text_positions3(jnp.broadcast_to(text_pos[None], (B, St)))
        positions = jnp.concatenate([p3_patch, p3_text], axis=2)  # (3,B,S)
        return x, positions
    tokens = inputs["tokens"]
    x = embed_lib.embed_tokens(params["embed"], tokens, cfg, cd)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.frontend == "vision_patches":
        positions = text_positions3(positions)
    return x, positions


# ---------------------------------------------------------------------------
# Train loss (chunked cross-entropy)
# ---------------------------------------------------------------------------


from repro.models.common import _mesh_axes, constrain_activations


def _logits_constraint(logits):
    """Pin the (B, c, V) loss logits to batch-over-DP, vocab-over-model —
    without this XLA can materialise replicated fp32 logits (hundreds of GB
    at 256k vocab)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return logits
        names = set(mesh.axis_names)
    except Exception:
        return logits
    bt = tuple(a for a in ("pod", "data") if a in names) or None
    v = "model" if "model" in names else None
    if bt is None and v is None:
        return logits
    spec = jax.sharding.PartitionSpec(bt if bt and len(bt) > 1 else
                                      (bt[0] if bt else None), None, v)
    return jax.lax.with_sharding_constraint(logits, spec)


def _ce_chunk(xc, labels_c, mask_c, params, cfg):
    logits = embed_lib.unembed(params["embed"], xc, cfg)        # (N,c,V) f32
    logits = _logits_constraint(logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # label gather as masked reduction: elementwise over the (possibly
    # vocab-sharded) V axis, so the partitioner never all-gathers logits
    viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                     logits.ndim - 1)
    ll = jnp.sum(jnp.where(viota == labels_c[..., None], logits, 0.0),
                 axis=-1)
    nll = (logz - ll) * mask_c
    return jnp.sum(nll), jnp.sum(mask_c)


def ce_loss(params, x, labels, cfg: ModelConfig, rt: Runtime,
            mask: Optional[jax.Array] = None):
    """Cross-entropy over (B,S,D) activations, chunked over tokens so the
    (tokens, V) fp32 logits tensor never materialises at full size."""
    B, S, D = x.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = rt.vocab_chunk
    if chunk <= 0 or S <= chunk:
        total, denom = _ce_chunk(x, labels, mask, params, cfg)
        return total / jnp.maximum(denom, 1.0)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (S + pad) // chunk

    def body(carry, xs):
        xc, lc, mc = xs
        t, d = jax.checkpoint(_ce_chunk, static_argnums=(4,))(
            xc, lc, mc, params, cfg)
        return (carry[0] + t, carry[1] + d), None

    xs = (x.reshape(B, n, chunk, D).swapaxes(0, 1),
          labels.reshape(B, n, chunk).swapaxes(0, 1),
          mask.reshape(B, n, chunk).swapaxes(0, 1))
    (total, denom), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs)
    return total / jnp.maximum(denom, 1.0)


def train_loss(params, batch: dict, cfg: ModelConfig,
               rt: Runtime = DEFAULT_RUNTIME):
    """batch: input dict + {"labels": (B,S), optional "loss_mask": (B,S)}."""
    x, positions = embed_inputs(params, batch, cfg, rt, mode="train")
    x = constrain_activations(x, zero3=(rt.train_style == "zero3"))
    x, _ = run_layers(params, x, cfg, rt, mode="train", caches=None,
                      positions=positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if x.shape[1] != labels.shape[1]:       # vlm: drop patch positions
        x = x[:, x.shape[1] - labels.shape[1]:]
    return ce_loss(params, x, labels, cfg, rt, mask)


# ---------------------------------------------------------------------------
# Serving entry points
# ---------------------------------------------------------------------------


def prefill(params, inputs: dict, cfg: ModelConfig, rt: Runtime,
            capacity: int, caches=None, last_index=None):
    """Full-sequence prefill.  Returns (last_logits (B,V) f32, caches).

    ``caches`` may be pre-built (e.g. the serving engine's paged pools);
    otherwise dense caches of ``capacity`` slots are created.  When the
    prompt is right-padded, ``last_index`` (B,) selects the true last
    position for the returned logits — and marks the pad positions with
    ``-1`` so cache writes drop them and recurrent layers freeze their
    state across them (bucketed prefill stays state-exact)."""
    x, positions = embed_inputs(params, inputs, cfg, rt, mode="prefill")
    B, S = x.shape[:2]
    if last_index is not None:
        li = jnp.asarray(last_index, jnp.int32).reshape(B)
        pad = jnp.arange(S)[None] > li[:, None]              # (B, S)
        positions = jnp.where(pad[None] if positions.ndim == 3 else pad,
                              -1, positions)
    if caches is None:
        caches = init_caches(cfg, B, capacity, rt)
    x, caches = run_layers(params, x, cfg, rt, mode="prefill", caches=caches,
                           positions=positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_index is None:
        x_last = x[:, -1]
    else:
        idx = jnp.asarray(last_index, jnp.int32).reshape(B, 1, 1)
        x_last = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (B, 1, x.shape[-1])), axis=1)[:, 0]
    logits = embed_lib.unembed(params["embed"], x_last, cfg)
    return logits, caches


def prefill_chunk(params, tokens: jax.Array, caches, offsets: jax.Array,
                  n_valid: jax.Array, last_in_chunk: jax.Array,
                  cfg: ModelConfig, rt: Runtime = DEFAULT_RUNTIME):
    """One chunk of a (batched) chunked prefill.

    tokens        (B, C) int32 — the next C prompt tokens of B sequences
    offsets       (B,)   int32 — tokens already prefilled per row
    n_valid       (B,)   int32 — real tokens in this chunk per row (0 = the
                                 row is padding; its writes are dropped)
    last_in_chunk (B,)   int32 — within-chunk index of the row's final
                                 prompt token; only meaningful for rows
                                 whose chunk is their last

    ``caches`` must be paged-attention caches whose ``page_table`` rows are
    the rows being prefilled (backends splice the per-request table rows
    in).  Requires every layer kind to be paged ("attn"/"global") — the
    engine gates recurrent / sliding-window archs to exact-length prefill.
    Returns (logits (B, V) f32 at ``last_in_chunk``, new_caches).
    """
    cd = rt.compute_dtype
    B, C = tokens.shape
    iota = jnp.arange(C)[None]
    pos = jnp.where(iota < n_valid[:, None], offsets[:, None] + iota, -1)
    x = embed_lib.embed_tokens(params["embed"], tokens, cfg, cd)
    positions = text_positions3(pos) if cfg.frontend == "vision_patches" \
        else pos
    x, caches = run_layers(params, x, cfg, rt, mode="chunk", caches=caches,
                           positions=positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    idx = jnp.clip(last_in_chunk, 0, C - 1).reshape(B, 1, 1)
    x_last = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (B, 1, x.shape[-1])), axis=1)[:, 0]
    logits = embed_lib.unembed(params["embed"], x_last, cfg)
    return logits, caches


def decode_step(params, tokens: jax.Array, caches, cur_pos: jax.Array,
                cfg: ModelConfig, rt: Runtime = DEFAULT_RUNTIME):
    """One decode step.  tokens (B,) int32; cur_pos (B,) absolute positions.

    Returns (logits (B,V) f32, new_caches)."""
    cd = rt.compute_dtype
    x = embed_lib.embed_tokens(params["embed"], tokens[:, None], cfg, cd)
    positions = cur_pos[:, None]
    if cfg.frontend == "vision_patches":
        positions = text_positions3(positions)
    x, caches = run_layers(params, x, cfg, rt, mode="decode", caches=caches,
                           positions=positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = embed_lib.unembed(params["embed"], x[:, 0], cfg)
    return logits, caches
