"""Mixture-of-experts FFN with capacity-based dispatch.

Expert parallelism has two equivalent expressions here (tests pin their
numerical identity):

* **pjit EP** (the production path): the dispatch buffers are pinned to
  expert-over-"model" shardings (``constrain_expert_dim``) and XLA
  partitions the scatter/FFN/gather; this is what the dry-run compiles.
* **manual EP** (``expert_shard=(e_start, e_count)``): each rank holds an
  expert slice and produces a *partial* output to be ``psum``-combined —
  the explicit form of the same math, used by tests and available for
  shard_map integration.  Routing is computed identically on every rank
  (deterministic in ``topi``), so combining needs one psum over the expert
  axis and **nothing crosses the high-latency pod boundary** but the usual
  activations (the paper's rule).

Capacity: each expert accepts at most ``C = ceil(N*k/E * capacity_factor)``
token-slots; overflow slots are dropped (combine weight zero), standard
GShard behaviour.  ``token_chunk`` bounds live dispatch memory (see SPerf).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.models.common import constrain_activations, constrain_expert_dim


def router_probs(x: jax.Array, w_router: jax.Array, moe: MoEConfig):
    """x (N, D) -> (topv, topi): (N, k) combine weights and expert ids."""
    logits = jnp.einsum("nd,de->ne", x, w_router,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, moe.experts_per_token)
    if moe.normalize_router_weights:
        topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)
    return topv, topi, probs


def expert_capacity(n_tokens: int, moe: MoEConfig) -> int:
    c = math.ceil(n_tokens * moe.experts_per_token / moe.num_experts
                  * moe.capacity_factor)
    return max(4, c)


def _positions_in_expert(topi: jax.Array, num_experts: int):
    """Slot position of each (token, k) pair within its destination expert.

    Deterministic given ``topi`` alone, so every replica computes identical
    placements (required by the replicated-routing EP path).
    """
    n, k = topi.shape
    flat_e = topi.reshape(-1)                                   # (N*k,)
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                        # (N*k, E)
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    return flat_e, pos_in_e


def _expert_ffn(buf: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array):
    """buf (E, C, D) x per-expert SwiGLU -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_ffn(x: jax.Array, w: dict, moe: MoEConfig, *,
            expert_shard: Optional[tuple] = None,
            token_chunk: int = 0) -> jax.Array:
    """Apply the MoE FFN to tokens ``x`` of shape (N, D).

    ``expert_shard``: None for the full-expert local path, or
    ``(e_start, e_count)`` when this replica owns only a slice of the expert
    weights (EP path; ``w['wg']`` etc. then have leading dim ``e_count``).
    In the EP case the return value is a *partial* output that the caller
    must ``psum`` over the expert-sharding axis.

    ``token_chunk`` > 0 scans the dispatch in token chunks: the (E, C, D)
    dispatch buffers (a ~k·capacity_factor× duplication of the tokens) then
    stay O(chunk) instead of O(N) — the difference between 43 GB and 5 GB of
    live dispatch state per layer on the train_4k workloads.  Exact: routing
    is per-token, and capacity scales with the chunk.
    """
    n, d = x.shape
    if token_chunk and n > token_chunk and n % token_chunk == 0:
        # NOTE: the nested while loop hides its trip count from XLA's HLO
        # FLOP counter (the roofline harness cross-checks against analytic
        # model FLOPs for exactly this reason); a python-unrolled variant
        # keeps the count but lets XLA keep every chunk's dispatch buffers
        # live at once (~5x worse peak memory), so scan wins.
        xs = x.reshape(n // token_chunk, token_chunk, d)

        def body(_, xc):
            return None, moe_ffn(xc, w, moe, expert_shard=expert_shard)

        _, ys = jax.lax.scan(body, None, xs)
        return ys.reshape(n, d)
    dtype = x.dtype
    topv, topi, _ = router_probs(x, w["router"], moe)
    cap = expert_capacity(n, moe)
    flat_e, pos_in_e = _positions_in_expert(topi, moe.num_experts)
    keep = pos_in_e < cap

    if expert_shard is None:
        e_start, e_count = 0, moe.num_experts
    else:
        e_start, e_count = expert_shard
        keep = keep & (flat_e >= e_start) & (flat_e < e_start + e_count)

    local_e = jnp.clip(flat_e - e_start, 0, e_count - 1)
    slot = jnp.where(keep, pos_in_e, cap - 1)

    # dispatch: (E_local, C, D).  Expert-major buffers are pinned to
    # expert-parallel over "model" — scatter/gather ops do not propagate
    # sharding, and replicated dispatch buffers are O(100 GB) at scale.
    x_rep = jnp.repeat(x, moe.experts_per_token, axis=0)        # (N*k, D)
    x_rep = constrain_activations(x_rep)
    contrib = jnp.where(keep[:, None], x_rep, 0).astype(dtype)
    buf = jnp.zeros((e_count, cap, d), dtype)
    buf = buf.at[local_e, slot].add(contrib, mode="drop")
    buf = constrain_expert_dim(buf)

    out_buf = _expert_ffn(buf, w["wg"], w["wu"], w["wd"])       # (E_l, C, D)
    out_buf = constrain_expert_dim(out_buf)

    # combine
    gathered = out_buf[local_e, slot]                           # (N*k, D)
    gathered = constrain_activations(gathered)
    weights = jnp.where(keep, topv.reshape(-1), 0.0)
    gathered = gathered.astype(jnp.float32) * weights[:, None]
    out = gathered.reshape(n, moe.experts_per_token, d).sum(axis=1)
    return out.astype(dtype)


def moe_load_balance_loss(probs: jax.Array, topi: jax.Array,
                          moe: MoEConfig) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e."""
    n = probs.shape[0]
    route_frac = jnp.mean(
        jax.nn.one_hot(topi, moe.num_experts, dtype=jnp.float32), axis=(0, 1))
    prob_frac = jnp.mean(probs, axis=0)
    return moe.num_experts * jnp.sum(route_frac * prob_frac)
