"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, strictly sequential) [arXiv:2405.04517].

mLSTM's gates depend only on the *input*, so the (C, n) recurrence is linear
time-varying and admits a chunkwise-parallel form (quadratic inside a chunk of
``L`` tokens, recurrent across chunks) — the same structure flash-linear-
attention kernels exploit.  ``mlstm_sequential`` is the O(S) exact oracle;
``mlstm_chunkwise`` is the production path (tested equivalent).

sLSTM's gates read the previous hidden state, so it is inherently sequential;
we run a fused ``lax.scan`` over time with block-diagonal (per-head) recurrent
weights.

State conventions (decode):
  mLSTM: {"c": (B, H, Dh, Dh) f32, "n": (B, H, Dh) f32, "m": (B, H) f32}
  sLSTM: {"c","n","h","m": (B, Dr) f32}
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_qkv_gates(xm: jax.Array, w: dict, num_heads: int):
    """Project the main branch into per-head q/k/v and scalar i/f gates.

    xm: (B, S, Dr).  Returns q,k,v (B,S,H,dh) and i_raw,f_raw (B,S,H) fp32.
    """
    b, s, dr = xm.shape
    dh = dr // num_heads
    xh = xm.reshape(b, s, num_heads, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, w["wq"])
    k = jnp.einsum("bshd,hde->bshe", xh, w["wk"]) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32)).astype(xm.dtype)
    v = jnp.einsum("bshd,hde->bshe", xh, w["wv"])
    i_raw = (jnp.einsum("bshd,hd->bsh", xh, w["w_i"]).astype(jnp.float32)
             + w["b_i"].astype(jnp.float32))
    f_raw = (jnp.einsum("bshd,hd->bsh", xh, w["w_f"]).astype(jnp.float32)
             + w["b_f"].astype(jnp.float32))
    return q, k, v, i_raw, f_raw


def mlstm_step(q, k, v, i_raw, f_raw, state):
    """One exact decode step.

    q,k,v: (B, H, dh); i_raw,f_raw: (B, H) fp32; state per module docstring.
    Returns (h (B,H,dh) f32, new_state).
    """
    c, n, m = state["c"], state["n"], state["m"]
    log_f = -jax.nn.softplus(-f_raw)              # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)                  # (B, H)
    f_g = jnp.exp(log_f + m - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = q.astype(jnp.float32)
    c_new = f_g[..., None, None] * c + i_g[..., None, None] * (
        vf[..., :, None] * kf[..., None, :])      # (B,H,dh_v,dh_k)
    n_new = f_g[..., None] * n + i_g[..., None] * kf
    num = jnp.einsum("bhvk,bhk->bhv", c_new, qf)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qf))
    den = jnp.maximum(den, jnp.exp(-m_new))
    h = num / den[..., None]
    return h, {"c": c_new, "n": n_new, "m": m_new}


def mlstm_sequential(q, k, v, i_raw, f_raw, state=None):
    """Exact O(S) recurrence; (B,S,H,dh) inputs.  Oracle for chunkwise."""
    b, s, hn, dh = q.shape
    if state is None:
        state = mlstm_zero_state(b, hn, dh)

    def body(st, xs):
        qt, kt, vt, it, ft = xs
        h, st = mlstm_step(qt, kt, vt, it, ft, st)
        return st, h

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_raw.swapaxes(0, 1), f_raw.swapaxes(0, 1))
    state, hs = jax.lax.scan(body, state, xs)
    return hs.swapaxes(0, 1), state               # (B,S,H,dh) f32


def mlstm_zero_state(batch: int, num_heads: int, head_dim: int) -> dict:
    return {
        "c": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
        "n": jnp.zeros((batch, num_heads, head_dim), jnp.float32),
        # m = 0 <=> no history yet (matches sequential init)
        "m": jnp.full((batch, num_heads), 0.0, jnp.float32),
    }


def mlstm_chunkwise(q, k, v, i_raw, f_raw, state=None, *, chunk: int = 64):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic attention-like term +
    inter-chunk recurrent state, numerically stabilised.

    q,k,v (B,S,H,dh); i_raw,f_raw (B,S,H) fp32.  Returns (h (B,S,H,dh) f32,
    final_state).
    """
    b, s, hn, dh = q.shape
    if state is None:
        state = mlstm_zero_state(b, hn, dh)
    chunk = max(1, min(chunk, s))
    pad = (-s) % chunk
    if pad:
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        i_raw = zpad(i_raw)
        # padded steps must not pollute the carried state: force f=1, i=-inf
        f_pad = jnp.concatenate(
            [f_raw, jnp.full((b, pad, hn), 40.0, f_raw.dtype)], axis=1)
        i_pad = jnp.concatenate(
            [i_raw[:, :s], jnp.full((b, pad, hn), -1e30, i_raw.dtype)], axis=1)
        f_raw, i_raw = f_pad, i_pad
    sp = s + pad
    nc = sp // chunk

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    is_, fs = to_chunks(i_raw), to_chunks(f_raw)

    def body(st, xs):
        qc, kc, vc, ic, fc = xs                   # (B,L,H,*) for this chunk
        c0, n0, m0 = st["c"], st["n"], st["m"]
        log_f = -jax.nn.softplus(-fc)             # (B,L,H)
        csum = jnp.cumsum(log_f, axis=1)          # F_t = sum_{u<=t} log f_u
        # decay from chunk start to position t (inclusive of t's forget gate)
        # "a_t" = prod_{u<=t} f_u ; inter-chunk term uses a_t * exp(m0)
        log_a = csum                              # (B,L,H)
        # log b_s = (decay from s+1..L applied later) ; source weight for
        # intra-chunk: D_{t,s} = exp(F_t - F_s + i_s) for s <= t
        log_i = ic                                # (B,L,H)
        # stabiliser per target position: m_t = max(m0 + F_t, max_{s<=t}(F_t - F_s + i_s))
        # note F_t - F_s + i_s = F_t + (i_s - F_s)
        g = log_i - csum                          # i_s - F_s  (B,L,H)
        g_run = jax.lax.cummax(g, axis=1)         # max_{s<=t}
        m_t = jnp.maximum(m0[:, None] + log_a, log_a + g_run)  # (B,L,H)
        # intra-chunk weights: logD[t,s] = F_t - F_s + i_s - m_t   (s <= t)
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        scores = jnp.einsum("blhd,buhd->bhlu", qf, kf)        # (B,H,t,s)
        F = csum                                   # (B,L,H)
        logD = (F[:, :, None, :] - F[:, None, :, :] + log_i[:, None, :, :]
                - m_t[:, :, None, :])              # (B,t,s,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        logD = jnp.where(tri[None, :, :, None], logD, -jnp.inf)
        D = jnp.moveaxis(jnp.exp(logD), 3, 1)      # (B,H,t,s)
        ds = D * scores
        intra_num = jnp.einsum("bhts,bshd->bthd", ds, vf)
        intra_den = jnp.moveaxis(jnp.sum(ds, axis=-1), 1, 2)  # (B,t,H)
        # inter-chunk contribution: decay a_t * exp(m0 - m_t)
        inter_w = jnp.exp(m0[:, None] + log_a - m_t)          # (B,L,H)
        inter_num = jnp.einsum("bhvk,blhk->blhv", c0, qf) * inter_w[..., None]
        inter_den = jnp.einsum("bhk,blhk->blh", n0, qf) * inter_w
        num = intra_num + inter_num
        den = jnp.abs(intra_den + inter_den)
        den = jnp.maximum(den, jnp.exp(-m_t))
        h = num / den[..., None]                   # (B,L,H,dh)

        # ---- carry state across the chunk boundary ----
        F_L = csum[:, -1]                          # (B,H) total decay
        m_next = jnp.maximum(m0 + F_L, F_L + g_run[:, -1])
        # per-source weight into the new state: exp(F_L - F_s + i_s - m_next)
        w_src = jnp.exp(F_L[:, None] + g - m_next[:, None])    # (B,L,H)
        c_new = (jnp.exp(m0 + F_L - m_next)[..., None, None] * c0
                 + jnp.einsum("blh,blhv,blhk->bhvk", w_src, vf, kf))
        n_new = (jnp.exp(m0 + F_L - m_next)[..., None] * n0
                 + jnp.einsum("blh,blhk->bhk", w_src, kf))
        return {"c": c_new, "n": n_new, "m": m_next}, h

    state, hs = jax.lax.scan(body, state, (qs, ks, vs, is_, fs))
    hs = hs.swapaxes(0, 1).reshape(b, sp, hn, dh)
    return hs[:, :s], state


def mlstm_block(x: jax.Array, w: dict, num_heads: int, *, mode: str,
                state: Optional[dict], chunk: int = 64,
                use_sequential: bool = False,
                valid: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    """Full mLSTM mixer: up-proj, per-head matrix-memory recurrence, gated
    output, down-proj.  x (B, S, D) normalised input.  ``valid`` (B, S)
    marks real tokens of a right-padded prefill: pad steps force f=1 /
    i=-inf (the same trick ``mlstm_chunkwise`` uses for its internal
    padding), so the carried state ignores them."""
    xm = jnp.einsum("bsd,de->bse", x, w["wm"])     # main branch (B,S,Dr)
    xz = jnp.einsum("bsd,de->bse", x, w["wz"])     # gate branch
    q, kk, v, i_raw, f_raw = mlstm_qkv_gates(xm, w, num_heads)
    if valid is not None and mode != "decode":
        i_raw = jnp.where(valid[..., None], i_raw, -1e30)
        f_raw = jnp.where(valid[..., None], f_raw, 40.0)
    if mode == "decode":
        h, new_state = mlstm_step(q[:, 0], kk[:, 0], v[:, 0],
                                  i_raw[:, 0], f_raw[:, 0], state)
        hs = h[:, None]
    elif use_sequential:
        hs, new_state = mlstm_sequential(q, kk, v, i_raw, f_raw, state)
    else:
        hs, new_state = mlstm_chunkwise(q, kk, v, i_raw, f_raw, state,
                                        chunk=chunk)
    b, s = x.shape[:2]
    hs = hs.reshape(b, s, -1)                      # (B,S,Dr) f32
    y = hs.astype(x.dtype) * jax.nn.silu(xz.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", y, w["wo"])
    return y, (new_state if state is not None or mode != "train" else None)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_zero_state(batch: int, d_rnn: int) -> dict:
    return {
        "c": jnp.zeros((batch, d_rnn), jnp.float32),
        "n": jnp.full((batch, d_rnn), 1e-6, jnp.float32),
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "m": jnp.zeros((batch, d_rnn), jnp.float32),
    }


def _slstm_cell(zx, st, r_w, num_heads):
    """One sLSTM time step.  zx: (B, 4, Dr) pre-computed input projections
    (i, f, z, o); st: state dict; r_w: (4, H, dh, dh) recurrent weights."""
    c, n, h, m = st["c"], st["n"], st["h"], st["m"]
    b, dr = h.shape
    dh = dr // num_heads
    hh = h.reshape(b, num_heads, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hh, r_w).reshape(4, b, dr)
    i_raw = zx[:, 0] + rec[0]
    f_raw = zx[:, 1] + rec[1]
    z_raw = zx[:, 2] + rec[2]
    o_raw = zx[:, 3] + rec[3]
    log_f = -jax.nn.softplus(-f_raw)               # exp-gate via log sigmoid
    m_new = jnp.maximum(log_f + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    c_new = f_g * c + i_g * z
    n_new = jnp.maximum(f_g * n + i_g, 1e-6)
    h_new = o * (c_new / n_new)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_block(x: jax.Array, w: dict, num_heads: int, *, mode: str,
                state: Optional[dict],
                valid: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    """sLSTM mixer: input projections + sequential recurrence + down-proj.

    x (B, S, D).  w: {"w_in": (4, D, Dr), "b_in": (4, Dr),
    "r": (4, H, dh, dh), "wo": (Dr, D)}.  ``valid`` (B, S) marks real
    tokens of a right-padded prefill: pad steps carry the state through
    unchanged (exact identity — the recurrence is sequential).
    """
    b, s, d = x.shape
    zx = (jnp.einsum("bsd,gde->bsge", x, w["w_in"]).astype(jnp.float32)
          + w["b_in"].astype(jnp.float32))         # (B,S,4,Dr)
    st = state if state is not None else slstm_zero_state(b, w["wo"].shape[0])

    if mode == "decode":
        st = _slstm_cell(zx[:, 0], st, w["r"].astype(jnp.float32), num_heads)
        hs = st["h"][:, None]
    else:
        def body(carry, xs):
            zt, vt = xs
            new = _slstm_cell(zt, carry, w["r"].astype(jnp.float32),
                              num_heads)
            if vt is not None:
                new = jax.tree.map(
                    lambda n, o: jnp.where(vt[:, None], n, o), new, carry)
            return new, new["h"]

        vxs = valid.swapaxes(0, 1) if valid is not None else None
        if vxs is None:
            st, hs = jax.lax.scan(lambda c, zt: body(c, (zt, None)), st,
                                  zx.swapaxes(0, 1))
        else:
            st, hs = jax.lax.scan(body, st, (zx.swapaxes(0, 1), vxs))
        hs = hs.swapaxes(0, 1)                     # (B,S,Dr)

    y = jnp.einsum("bse,ed->bsd", hs.astype(x.dtype), w["wo"])
    return y, (st if state is not None or mode != "train" else None)
