"""Input frontends: token embedding plus stub modality frontends.

Per the assignment, ``[audio]``/``[vlm]`` archs specify the transformer
*backbone* only — the modality frontend is a stub whose job is to accept
*precomputed* frame/patch embeddings (supplied by ``input_specs()``) and
project them into the backbone's residual stream.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig,
                 compute_dtype) -> jax.Array:
    """(..., S) int32 -> (..., S, D)."""
    x = jnp.take(params["tok"], tokens, axis=0).astype(compute_dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), compute_dtype)
    return x


def embed_frames(params: dict, frames: jax.Array, cfg: ModelConfig,
                 compute_dtype) -> jax.Array:
    """Audio stub: precomputed EnCodec frame embeddings (B, S, D_in) are
    projected into the residual stream."""
    return jnp.einsum("bsf,fd->bsd", frames.astype(compute_dtype),
                      params["frame_proj"].astype(compute_dtype))


def embed_patches(params: dict, patches: jax.Array, cfg: ModelConfig,
                  compute_dtype) -> jax.Array:
    """Vision stub: precomputed merged-patch embeddings (B, P, D_in) projected
    into the residual stream (the qwen2-vl `merger` MLP, single layer here)."""
    return jnp.einsum("bpf,fd->bpd", patches.astype(compute_dtype),
                      params["patch_proj"].astype(compute_dtype))


def unembed(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(..., D) -> (..., V) logits in fp32 (softcap applied if configured)."""
    table = params["tok"] if cfg.tie_embeddings else params["untok"]
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap > 0.0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits
