"""Shared model components: norms, RoPE (+M-RoPE), MLP, layer plans, init.

Everything is functional: params are plain pytrees of ``jnp`` arrays, and all
entry points are shape-polymorphic over batch/sequence so the same code path
serves smoke tests (tiny), real CPU runs (small) and the 512-device dry-run
(full scale, ``ShapeDtypeStruct`` only).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ATTN_KINDS, RECURRENT_KINDS, ModelConfig


# ---------------------------------------------------------------------------
# Runtime knobs (static; threaded through model functions)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Runtime:
    """Static execution knobs, hashable so it can be a jit static arg."""

    mesh_axes: tuple = ()             # () = single device / no SPMD hints
    use_ep_moe: bool = False          # shard_map all_to_all expert parallelism
    q_chunk: int = 512                # flash-attention query chunk
    kv_chunk: int = 512               # flash-attention kv chunk
    mlstm_chunk: int = 64             # chunkwise mLSTM chunk length
    remat: bool = False               # checkpoint each layer period in training
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    use_pallas: bool = False          # route attention through Pallas kernels
    causal_scheme: str = "masked"     # masked | blockpair (see kernels/ops.py)
    ep_axis: str = "model"            # mesh axis that shards experts
    vocab_chunk: int = 0              # 0 = unchunked loss; else chunk token dim
    sequence_parallel: bool = False   # Megatron-SP residual sharding (train)
    moe_chunk: int = 0                # token-chunked MoE dispatch (0 = off)
    train_style: str = "sp"           # sp (TP+seq-parallel) | zero3 (batch
                                      # over data+model, weights gathered)
    kv_dtype: str = "bf16"            # bf16 | int8 (quantized KV cache)
    attn_pages_per_block: int = 0     # paged-decode KV pages per grid step
                                      # (0 = autotuned per (page, Dh, G))

    def replace(self, **kw) -> "Runtime":
        return dataclasses.replace(self, **kw)


DEFAULT_RUNTIME = Runtime()


# ---------------------------------------------------------------------------
# Layer plan: scan over pattern periods + unrolled tail
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerPlan:
    period_kinds: tuple     # kinds within one period
    n_periods: int          # number of scanned periods
    tail_kinds: tuple       # remainder layers, unrolled

    @property
    def num_layers(self) -> int:
        return len(self.period_kinds) * self.n_periods + len(self.tail_kinds)

    def all_kinds(self) -> tuple:
        return self.period_kinds * self.n_periods + self.tail_kinds


def make_layer_plan(num_layers: int, pattern: tuple) -> LayerPlan:
    period = len(pattern)
    n_periods = num_layers // period
    tail = tuple(pattern[: num_layers % period])
    if n_periods == 0:
        # degenerate (fewer layers than one period): everything is tail
        return LayerPlan(period_kinds=(), n_periods=0, tail_kinds=tail)
    return LayerPlan(period_kinds=tuple(pattern), n_periods=n_periods,
                     tail_kinds=tail)


# ---------------------------------------------------------------------------
# Basic ops
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, wg)
    u = jnp.einsum("...d,df->...f", x, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, wd)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               scaling: float = 1.0) -> jax.Array:
    """Rotary embedding.

    x:        (..., S, H, Dh)
    positions (..., S) integer positions (broadcastable over leading dims)
    """
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dh, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * freqs / scaling  # (...,S,Dh/2)
    angles = angles[..., None, :]                                        # (...,S,1,Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# M-RoPE sections (pairs per positional component t/h/w), qwen2-vl style.
MROPE_SECTIONS = (16, 24, 24)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float) -> jax.Array:
    """Multimodal RoPE: ``positions3`` is (3, ..., S) for (t, h, w).

    Different contiguous sections of the rotation-frequency spectrum take
    their position from different components.
    """
    dh = x.shape[-1]
    half = dh // 2
    sections = np.asarray(MROPE_SECTIONS, dtype=np.int64)
    sections = (sections * half / sections.sum()).astype(np.int64)
    sections[-1] = half - sections[:-1].sum()
    comp = np.repeat(np.arange(3), sections)                 # (half,) component id
    freqs = jnp.asarray(rope_frequencies(dh, theta), jnp.float32)
    pos = positions3.astype(jnp.float32)                      # (3, ..., S)
    # select per-frequency component: (..., S, half)
    pos_per_freq = jnp.take(pos, jnp.asarray(comp), axis=0)   # (half, ..., S)
    pos_per_freq = jnp.moveaxis(pos_per_freq, 0, -1)          # (..., S, half)
    angles = pos_per_freq * freqs
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def text_positions3(positions: jax.Array) -> jax.Array:
    """Text tokens use identical (t, h, w) components."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


def patch_positions3(batch: int, n_patches: int) -> jax.Array:
    """A square patch grid at t=0 with (h, w) raster positions."""
    side = max(1, int(np.sqrt(n_patches)))
    idx = jnp.arange(n_patches)
    h = idx // side
    w = idx % side
    t = jnp.zeros_like(idx)
    p3 = jnp.stack([t, h, w])                                  # (3, P)
    return jnp.broadcast_to(p3[:, None, :], (3, batch, n_patches))


# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: tuple, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _mesh_axes(with_sizes: bool = False):
    """Non-manual axis names of the ambient mesh (empty outside any mesh
    context).  Manual axes (e.g. "pod" inside the pipeline's shard_map) must
    never appear in sharding constraints."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            from jax._src import mesh as mesh_lib
            mesh = mesh_lib.thread_resources.env.physical_mesh
            if mesh.empty:
                return {} if with_sizes else frozenset()
        usable = {
            n: s for n, s, t in zip(mesh.axis_names, mesh.axis_sizes,
                                    mesh.axis_types)
            if "anual" not in str(t)}
        return usable if with_sizes else frozenset(usable)
    except Exception:
        return {} if with_sizes else frozenset()


def constrain_activations(x, *, sequence_parallel: bool = False,
                          zero3: bool = False):
    """Pin (B, ..., D) activations to batch-over-DP — without this the
    partitioner can lose the data axis after vocab-sharded embedding gathers
    and replicate multi-GB activation tensors.

    ``sequence_parallel`` additionally shards the sequence dim over "model"
    (Megatron-SP): the layer-boundary residual stash the backward pass keeps
    per scanned period then shards over TP instead of being replicated —
    an O(model)x saving on the dominant training-memory term."""
    sizes = _mesh_axes(with_sizes=True)
    names = frozenset(sizes)
    bt = tuple(a for a in ("pod", "data") if a in names)
    if not bt:
        return x
    rest = [None] * (x.ndim - 1)
    full = 1
    for a in bt:
        full *= sizes[a]
    if zero3 and "model" in names and             x.shape[0] % (full * sizes["model"]) == 0:
        # ZeRO-3 style: batch over *every* axis; weights get gathered per
        # layer instead of activations moving (see EXPERIMENTS.md SPerf).
        # Guarded on divisibility: a 256-batch cannot shard 512 ways.
        bt = bt + ("model",)
    elif sequence_parallel and "model" in names and x.ndim >= 3 and \
            x.shape[1] % 16 == 0:
        rest[0] = "model"
    spec = jax.sharding.PartitionSpec(bt if len(bt) > 1 else bt[0], *rest)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_expert_dim(x):
    """Pin a (E, ...) expert-major buffer to expert-parallel over "model"."""
    names = _mesh_axes()
    if "model" not in names:
        return x
    spec = jax.sharding.PartitionSpec("model", *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


class KeyGen:
    """Sequential PRNG key dispenser."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub
