"""Attention: chunked (flash-style) prefill/train attention and cached decode.

Pure-jnp implementations live here; they are also the numerical oracles for
the Pallas kernels in ``repro/kernels``.  ``repro.kernels.ops`` routes to the
Pallas path when ``Runtime.use_pallas`` is set and shapes are TPU-aligned.

Layout conventions:
  q          (B, Sq, H,  Dh)
  k, v       (B, Skv, Hk, Dh)       Hk | H  (GQA group = H // Hk)
  decode q   (B, H, Dh)             single new token per sequence
  KV cache   (B, C, Hk, Dh) with a slot-position array (B, C) int32, -1=empty
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_split(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, H, D) -> (B, S, Hk, G, D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


# ---------------------------------------------------------------------------
# Chunked causal attention (train / prefill)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: int = 0,
                    q_offset=0,
                    q_chunk: int = 512,
                    kv_chunk: int = 512,
                    scheme: str = "masked") -> jax.Array:
    """Memory-O(chunk) causal attention with online softmax.

    window > 0 restricts each query to the last ``window`` keys (sliding
    window, inclusive of self).  ``q_offset`` is the absolute position of
    q[:, 0] relative to k[:, 0] (used when a prefill continues a cache).

    Differentiation goes through a custom VJP that *recomputes* block scores
    in the backward pass from (q, k, v, out, lse); without it the scan
    transpose stores the full S×S probability tensor per layer (hundreds of
    GB at 4k context — see EXPERIMENTS.md §Perf).

    scheme:
      "masked"    — every q chunk scans every kv chunk, causality by masking
                    (2x FLOP overhead on strictly-causal layers; simple).
      "blockpair" — q chunks only visit kv chunks that intersect their causal
                    span (exact lower-triangular FLOPs; see kernels/ops.py).
    """
    if isinstance(q_offset, int):
        static = (causal, window, q_offset, q_chunk, kv_chunk, scheme)
        return _flash_vjp(static, q, k, v)
    return _flash_impl(q, k, v, causal=causal, window=window,
                       q_offset=q_offset, q_chunk=q_chunk,
                       kv_chunk=kv_chunk, scheme=scheme)[0]


def _flash_impl(q: jax.Array, k: jax.Array, v: jax.Array, *,
                causal, window, q_offset, q_chunk, kv_chunk, scheme):
    """Returns (out (B,Sq,H,Dh), lse (B,Hk,G,Sq) fp32)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    hk = k.shape[2]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # pad seq lens up to chunk multiples
    pq = (-sq) % q_chunk
    pkv = (-skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq = (sq + pq) // q_chunk

    qg = _gqa_split(q, hk)                                   # (B,Sq,Hk,G,D)
    g = qg.shape[3]

    if window > 0:
        out, lse = _windowed_attention(qg, k, v, window=window,
                                       q_offset=q_offset, q_chunk=q_chunk,
                                       scale=scale, sq_real=sq, skv_real=skv)
    elif scheme == "blockpair" and causal:
        out, lse = _blockpair_attention(qg, k, v, q_offset=q_offset,
                                        q_chunk=q_chunk, kv_chunk=kv_chunk,
                                        scale=scale, sq_real=sq, skv_real=skv)
    else:
        out, lse = _masked_attention(qg, k, v, causal=causal,
                                     q_offset=q_offset, q_chunk=q_chunk,
                                     kv_chunk=kv_chunk, scale=scale,
                                     sq_real=sq, skv_real=skv)
    out = out.reshape(b, sq + pq, h, dh)
    return out[:, :sq], lse[..., :sq]


def _online_update(carry, s, v_chunk):
    """One online-softmax accumulation step.

    carry: (o (B,Hk,G,cq,D) f32, m (B,Hk,G,cq) f32, l like m)
    s:     (B,Hk,G,cq,ck) f32 scores (already masked with NEG_INF)
    """
    o, m, l = carry
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v_chunk.dtype), v_chunk,
                    preferred_element_type=jnp.float32)
    o = o * alpha[..., None] + pv
    return (o, m_new, l)


def _finish(o, m, l, dtype):
    """Normalise the online accumulator; also return log-sum-exp."""
    l = jnp.maximum(l, 1e-30)
    return (o / l[..., None]).astype(dtype), m + jnp.log(l)


def _masked_attention(qg, k, v, *, causal, q_offset, q_chunk, kv_chunk,
                      scale, sq_real, skv_real):
    b, sqp, hk, g, dh = qg.shape
    skvp = k.shape[1]
    nq = sqp // q_chunk
    nkv = skvp // kv_chunk
    dtype = qg.dtype

    kv_pos = jnp.arange(skvp).reshape(nkv, kv_chunk)

    def q_body(_, qi):
        q_c = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        q_c = jnp.moveaxis(q_c, 1, 3)                        # (B,Hk,G,cq,D)
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        def kv_body(carry, xs):
            k_c, v_c, pos_c = xs                             # (B,ck,Hk,D),(ck,)
            s = jnp.einsum("bkgqd,bckd->bkgqc", q_c, k_c,
                           preferred_element_type=jnp.float32) * scale
            mask = pos_c[None, :] <= q_pos[:, None] if causal else (
                jnp.ones((q_chunk, kv_chunk), bool))
            mask = mask & (pos_c[None, :] < skv_real) & (
                (q_pos[:, None] - q_offset) < sq_real)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            return _online_update(carry, s, v_c), None

        o0 = jnp.zeros((b, hk, g, q_chunk, dh), jnp.float32)
        m0 = jnp.full((b, hk, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, q_chunk), jnp.float32)
        ks = k.reshape(b, nkv, kv_chunk, hk, dh).swapaxes(0, 1)
        vs = v.reshape(b, nkv, kv_chunk, hk, dh).swapaxes(0, 1)
        (o, m, l), _ = jax.lax.scan(kv_body, (o0, m0, l0), (ks, vs, kv_pos))
        out, lse = _finish(o, m, l, dtype)                   # (B,Hk,G,cq,D)
        return None, (jnp.moveaxis(out, 3, 1), lse)          # (B,cq,Hk,G,D)

    _, (outs, lses) = jax.lax.scan(q_body, None, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sqp, hk, g, dh)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, hk, g, sqp)
    return out, lse


def _blockpair_attention(qg, k, v, *, q_offset, q_chunk, kv_chunk, scale,
                         sq_real, skv_real):
    """Exact-FLOPs causal attention: q chunk i only visits kv chunks
    j <= ceil((i*cq + offset + cq)/ckv).  Implemented as a scan over the
    packed list of (qi, kj) block pairs with segment accumulation.
    """
    b, sqp, hk, g, dh = qg.shape
    skvp = k.shape[1]
    nq = sqp // q_chunk
    nkv = skvp // kv_chunk
    dtype = qg.dtype

    # enumerate causal block pairs (static python; nq, nkv are static)
    pairs = [(qi, kj) for qi in range(nq)
             for kj in range(nkv)
             if kj * kv_chunk <= qi * q_chunk + q_offset + q_chunk - 1]
    qi_arr = jnp.asarray([p[0] for p in pairs])
    kj_arr = jnp.asarray([p[1] for p in pairs])

    def body(carry, pair):
        o, m, l = carry                                       # (B,Hk,G,Sq,*)
        qi, kj = pair
        q_c = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        q_c = jnp.moveaxis(q_c, 1, 3)
        k_c = jax.lax.dynamic_slice_in_dim(k, kj * kv_chunk, kv_chunk, axis=1)
        v_c = jax.lax.dynamic_slice_in_dim(v, kj * kv_chunk, kv_chunk, axis=1)
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset
        kv_pos = kj * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bkgqd,bckd->bkgqc", q_c, k_c,
                       preferred_element_type=jnp.float32) * scale
        mask = (kv_pos[None] <= q_pos[:, None]) & (kv_pos[None] < skv_real)
        s = jnp.where(mask[None, None, None], s, NEG_INF)

        m_blk = jax.lax.dynamic_slice_in_dim(m, qi * q_chunk, q_chunk, 3)
        l_blk = jax.lax.dynamic_slice_in_dim(l, qi * q_chunk, q_chunk, 3)
        o_blk = jax.lax.dynamic_slice_in_dim(o, qi * q_chunk, q_chunk, 3)
        (o_blk, m_blk, l_blk) = _online_update((o_blk, m_blk, l_blk), s, v_c)
        o = jax.lax.dynamic_update_slice_in_dim(o, o_blk, qi * q_chunk, 3)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_blk, qi * q_chunk, 3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_blk, qi * q_chunk, 3)
        return (o, m, l), None

    o0 = jnp.zeros((b, hk, g, sqp, dh), jnp.float32)
    m0 = jnp.full((b, hk, g, sqp), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, sqp), jnp.float32)
    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), (qi_arr, kj_arr))
    out, lse = _finish(o, m, l, dtype)                        # (B,Hk,G,Sq,D)
    return jnp.moveaxis(out, 3, 1), lse


def _windowed_attention(qg, k, v, *, window, q_offset, q_chunk, scale,
                        sq_real, skv_real):
    """Sliding-window attention: q chunk at qs attends kv[qs-window+1 : qs+cq].

    The kv slice has static size (window + q_chunk), so FLOPs scale with the
    window, not the sequence.
    """
    b, sqp, hk, g, dh = qg.shape
    skvp = k.shape[1]
    nq = sqp // q_chunk
    dtype = qg.dtype
    span = window + q_chunk

    # pad kv left by `window` and right enough that slices never clamp
    # (clamped dynamic_slice starts would desynchronise kv_pos bookkeeping)
    right = max(0, sqp + (q_offset if isinstance(q_offset, int) else 0)
                + q_chunk - skvp)
    kp = jnp.pad(k, ((0, 0), (window, right), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, right), (0, 0), (0, 0)))

    def q_body(_, qi):
        qs = qi * q_chunk
        q_c = jax.lax.dynamic_slice_in_dim(qg, qs, q_chunk, axis=1)
        q_c = jnp.moveaxis(q_c, 1, 3)
        q_pos = qs + jnp.arange(q_chunk) + q_offset
        # absolute kv positions covered by this slice
        start = qs + q_offset                                 # index into padded kv
        k_c = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        v_c = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        kv_pos = start - window + jnp.arange(span)            # absolute positions
        s = jnp.einsum("bkgqd,bckd->bkgqc", q_c, k_c,
                       preferred_element_type=jnp.float32) * scale
        mask = (kv_pos[None] <= q_pos[:, None]) \
            & (kv_pos[None] > q_pos[:, None] - window) \
            & (kv_pos[None] >= 0) & (kv_pos[None] < skv_real)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        o0 = jnp.zeros((b, hk, g, q_chunk, dh), jnp.float32)
        m0 = jnp.full((b, hk, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, q_chunk), jnp.float32)
        o, m, l = _online_update((o0, m0, l0), s, v_c)
        out, lse = _finish(o, m, l, dtype)
        return None, (jnp.moveaxis(out, 3, 1), lse)

    _, (outs, lses) = jax.lax.scan(q_body, None, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sqp, hk, g, dh)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, hk, g, sqp)
    return out, lse


# ---------------------------------------------------------------------------
# Flash-attention custom VJP (recompute backward)
# ---------------------------------------------------------------------------


def _band_pairs(nq, nkv, q_chunk, kv_chunk, *, causal, window, q_offset):
    """(qi, kj) block pairs whose mask support is non-empty."""
    pairs = []
    for qi in range(nq):
        q_lo = qi * q_chunk + q_offset
        q_hi = q_lo + q_chunk - 1
        for kj in range(nkv):
            k_lo = kj * kv_chunk
            k_hi = k_lo + kv_chunk - 1
            if causal and k_lo > q_hi:
                continue
            if window > 0 and k_hi <= q_lo - window:
                continue
            pairs.append((qi, kj))
    return pairs


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_vjp(static, q, k, v):
    causal, window, q_offset, q_chunk, kv_chunk, scheme = static
    return _flash_impl(q, k, v, causal=causal, window=window,
                       q_offset=q_offset, q_chunk=q_chunk, kv_chunk=kv_chunk,
                       scheme=scheme)[0]


def _flash_vjp_fwd(static, q, k, v):
    causal, window, q_offset, q_chunk, kv_chunk, scheme = static
    out, lse = _flash_impl(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, scheme=scheme)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(static, res, dout):
    """Chunked backward: recompute block scores from (q, k, lse); memory
    stays O(S·Dh) instead of O(S²)."""
    causal, window, q_offset, q_chunk, kv_chunk, scheme = static
    q, k, v, out, lse = res
    b, sq, h, dh = q.shape
    skv, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    pq, pkv = (-sq) % q_chunk, (-skv) % kv_chunk

    def padq(x):
        return jnp.pad(x, ((0, 0), (0, pq)) + ((0, 0),) * (x.ndim - 2)) \
            if pq else x

    def padkv(x):
        return jnp.pad(x, ((0, 0), (0, pkv)) + ((0, 0),) * (x.ndim - 2)) \
            if pkv else x

    qg = jnp.moveaxis(_gqa_split(padq(q), hk), 1, 3)      # (B,Hk,G,Sqp,D)
    og = jnp.moveaxis(_gqa_split(padq(out), hk), 1, 3)
    dg = jnp.moveaxis(_gqa_split(padq(dout), hk), 1, 3)
    kp = padkv(k)                                          # (B,Skvp,Hk,D)
    vp = padkv(v)
    lse_p = jnp.pad(lse, ((0, 0),) * 3 + ((0, pq),)) if pq else lse
    dvec = jnp.sum(dg.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)

    sqp, skvp = sq + pq, skv + pkv
    nq, nkv = sqp // q_chunk, skvp // kv_chunk
    pairs = _band_pairs(nq, nkv, q_chunk, kv_chunk, causal=causal,
                        window=window, q_offset=q_offset)
    qi_arr = jnp.asarray([p[0] for p in pairs])
    kj_arr = jnp.asarray([p[1] for p in pairs])

    def body(carry, pair):
        dq, dk, dv = carry
        qi, kj = pair
        qs, ks = qi * q_chunk, kj * kv_chunk
        q_c = jax.lax.dynamic_slice_in_dim(qg, qs, q_chunk, 3)
        o_dc = jax.lax.dynamic_slice_in_dim(dg, qs, q_chunk, 3)
        l_c = jax.lax.dynamic_slice_in_dim(lse_p, qs, q_chunk, 3)
        d_c = jax.lax.dynamic_slice_in_dim(dvec, qs, q_chunk, 3)
        k_c = jax.lax.dynamic_slice_in_dim(kp, ks, kv_chunk, 1)
        v_c = jax.lax.dynamic_slice_in_dim(vp, ks, kv_chunk, 1)

        s = jnp.einsum("bkgqd,bckd->bkgqc", q_c, k_c,
                       preferred_element_type=jnp.float32) * scale
        q_pos = qs + jnp.arange(q_chunk) + q_offset
        kv_pos = ks + jnp.arange(kv_chunk)
        # barrier: qi/kj are compile-time constants (scan xs), and without
        # it XLA constant-folds the masks of EVERY block pair into one
        # multi-GB pred tensor
        q_pos, kv_pos = jax.lax.optimization_barrier((q_pos, kv_pos))
        mask = (kv_pos[None] < skv) & ((q_pos[:, None] - q_offset) < sq)
        if causal:
            mask &= kv_pos[None] <= q_pos[:, None]
        if window > 0:
            mask &= kv_pos[None] > q_pos[:, None] - window
        p = jnp.where(mask[None, None, None],
                      jnp.exp(s - l_c[..., None]), 0.0)     # (B,Hk,G,cq,ck)

        dv_blk = jnp.einsum("bkgqc,bkgqd->bckd", p,
                            o_dc.astype(jnp.float32))
        dp = jnp.einsum("bkgqd,bckd->bkgqc", o_dc.astype(jnp.float32),
                        v_c.astype(jnp.float32))
        ds = p * (dp - d_c[..., None]) * scale
        dq_blk = jnp.einsum("bkgqc,bckd->bkgqd", ds,
                            k_c.astype(jnp.float32))
        dk_blk = jnp.einsum("bkgqc,bkgqd->bckd", ds,
                            q_c.astype(jnp.float32))

        dq_cur = jax.lax.dynamic_slice_in_dim(dq, qs, q_chunk, 3)
        dq = jax.lax.dynamic_update_slice_in_dim(dq, dq_cur + dq_blk, qs, 3)
        dk_cur = jax.lax.dynamic_slice_in_dim(dk, ks, kv_chunk, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(dk, dk_cur + dk_blk, ks, 1)
        dv_cur = jax.lax.dynamic_slice_in_dim(dv, ks, kv_chunk, 1)
        dv = jax.lax.dynamic_update_slice_in_dim(dv, dv_cur + dv_blk, ks, 1)
        return (dq, dk, dv), None

    dq0 = jnp.zeros((b, hk, g, sqp, dh), jnp.float32)
    dk0 = jnp.zeros((b, skvp, hk, dh), jnp.float32)
    dv0 = jnp.zeros((b, skvp, hk, dh), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), (qi_arr, kj_arr))
    dq = jnp.moveaxis(dq, 3, 1).reshape(b, sqp, h, dh)[:, :sq].astype(q.dtype)
    dk = dk[:, :skv].astype(k.dtype)
    dv = dv[:, :skv].astype(v.dtype)
    return dq, dk, dv


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# Chunk-continuation attention (chunked prefill)
# ---------------------------------------------------------------------------

def chunk_attention(q: jax.Array, k_ctx: jax.Array, v_ctx: jax.Array,
                    kv_pos: jax.Array, q_pos: jax.Array, *,
                    window: int = 0) -> jax.Array:
    """Attention of a prefill *chunk* against gathered cache context.

    q      (B, C, H, Dh)  — the chunk's queries
    k/v    (B, T, Hk, Dh) — cache context gathered in position order; the
                            chunk's own keys must already be written into it
    kv_pos (T,) or (B, T) — absolute position held by each context slot
    q_pos  (B, C)         — absolute query positions, -1 = padded query

    The mask is ``kv_pos <= q_pos`` (and the sliding window when given), so
    unwritten / future context slots are dropped.  The math deliberately
    mirrors one online-softmax step of ``_masked_attention`` (same score
    scale, same finite ``NEG_INF`` mask, same ``p·v`` then ``/l`` order):
    when the exact-length path runs a single kv chunk, chunked prefill is
    bit-identical to it, because the extra masked context slots contribute
    exact float zeros.  Fully-masked (padded) queries yield finite garbage,
    never NaN.
    """
    b, c, h, dh = q.shape
    hk = k_ctx.shape[2]
    g = h // hk
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qg = jnp.moveaxis(q.reshape(b, c, hk, g, dh), 1, 3)      # (B,Hk,G,C,D)

    s = jnp.einsum("bkgqd,btkd->bkgqt", qg, k_ctx,
                   preferred_element_type=jnp.float32) * scale
    if kv_pos.ndim == 1:
        kv_pos = kv_pos[None]
    mask = (kv_pos[:, None, :] <= q_pos[:, :, None]) & (kv_pos[:, None, :] >= 0)
    if window > 0:
        mask &= kv_pos[:, None, :] > (q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.maximum(jnp.max(s, axis=-1), NEG_INF)
    p = jnp.exp(s - m[..., None])
    l = jnp.maximum(jnp.sum(p, axis=-1), 1e-30)
    pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v_ctx.dtype), v_ctx,
                    preferred_element_type=jnp.float32)
    out = (pv / l[..., None]).astype(q.dtype)                # (B,Hk,G,C,D)
    return jnp.moveaxis(out, 3, 1).reshape(b, c, h, dh)


# ---------------------------------------------------------------------------
# Cached decode attention
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array, cur_pos: jax.Array, *,
                     window: int = 0) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    q         (B, H, Dh)
    k/v cache (B, C, Hk, Dh)
    slot_pos  (B, C) int32 absolute position stored in each slot (-1 empty)
    cur_pos   (B,)  int32 position of the query token
    """
    b, h, dh = q.shape
    hk = k_cache.shape[2]
    g = h // hk
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qg = q.reshape(b, hk, g, dh)

    s = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos[:, None])
    if window > 0:
        valid &= slot_pos > (cur_pos[:, None] - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgc,bckd->bkgd", (p / l).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, dh).astype(q.dtype)
