"""Griffin/RecurrentGemma recurrent block: causal conv + RG-LRU + gating.

The RG-LRU recurrence is linear in its hidden state,

    h_t = a_t * h_{t-1} + b_t,
    a_t = exp(-c * softplus(L) * sigmoid(r_t)),
    b_t = sqrt(1 - a_t^2) * (i_t * x_t),

so prefill/training use ``jax.lax.associative_scan`` (parallel prefix, depth
O(log S)) while decode is a single fused elementwise step.  The Pallas kernel
in ``repro/kernels/rglru_scan.py`` implements the same recurrence with VMEM
block tiling; ``repro/kernels/ref.py`` points back at the functions here.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

RGLRU_C = 8.0      # the paper's fixed decay sharpness constant


def rglru_gates(x: jax.Array, w: dict, num_heads: int):
    """Compute (a, b) coefficients of the linear recurrence.

    x: (B, S, Dr) post-conv activations (fp32 recommended).
    Returns a, b with shape (B, S, Dr), fp32.
    """
    b_, s, dr = x.shape
    dh = dr // num_heads
    xh = x.reshape(b_, s, num_heads, dh)
    # block-diagonal gate projections (per head)
    r = jnp.einsum("bshd,hde->bshe", xh, w["gate_a_w"]).reshape(b_, s, dr)
    i = jnp.einsum("bshd,hde->bshe", xh, w["gate_x_w"]).reshape(b_, s, dr)
    r = jax.nn.sigmoid(r.astype(jnp.float32) + w["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(i.astype(jnp.float32) + w["gate_x_b"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(w["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1: 1 - exp(2 log a)
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = mult * (i * x.astype(jnp.float32))
    return a, b


def rglru_scan(a: jax.Array, b: jax.Array,
               h0: Optional[jax.Array] = None) -> jax.Array:
    """Parallel linear recurrence over axis 1 (time). Returns all h_t (fp32)."""
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_step(a: jax.Array, b: jax.Array, h: jax.Array) -> jax.Array:
    """Single decode step: (B, Dr) each."""
    return a * h + b


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: Optional[jax.Array] = None,
                  valid: Optional[jax.Array] = None):
    """Depthwise causal temporal conv.

    x: (B, S, Dr); w: (cw, Dr); state: (B, cw-1, Dr) trailing inputs of the
    previous segment (decode / chunked prefill).  ``valid`` (B, S) marks
    real tokens when the segment is right-padded: the carried state is then
    the window ending at each row's last *valid* input, not the pad tail.
    Returns (y, new_state).
    """
    cw = w.shape[0]
    bsz, s, dr = x.shape
    if state is None:
        state = jnp.zeros((bsz, cw - 1, dr), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)   # (B, S+cw-1, Dr)
    y = jnp.zeros((bsz, s, dr), jnp.float32)
    for i in range(cw):
        y = y + xp[:, i:i + s].astype(jnp.float32) * w[cw - 1 - i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    if cw == 1:
        new_state = jnp.zeros((bsz, 0, dr), x.dtype)
    elif valid is None:
        new_state = xp[:, -(cw - 1):]
    else:
        # xp index of token j is j + cw - 1; a fully-padded row (last = -1)
        # lands on xp[:cw-1], i.e. the previous state — unchanged.
        last = jnp.sum(valid.astype(jnp.int32), axis=1) - 1    # (B,)
        idx = last[:, None] + 1 + jnp.arange(cw - 1)[None]     # (B, cw-1)
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return y.astype(x.dtype), new_state


def rglru_block(x: jax.Array, w: dict, num_heads: int, *,
                mode: str, state: Optional[dict],
                valid: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    """Full Griffin recurrent mixer (everything between the residual adds).

    x: (B, S, D) normalised input.  state: {"h": (B, Dr) fp32,
    "conv": (B, cw-1, Dr)} or None (train).  ``valid`` (B, S) marks real
    tokens of a right-padded prefill: pad steps become recurrence
    identities (a=1, b=0), so the carried state is that of the last valid
    token — bucketed prefill stays state-exact.
    """
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, w["wg"]).astype(jnp.float32))
    main = jnp.einsum("bsd,de->bse", x, w["wx"])                # (B, S, Dr)

    conv_state = state["conv"] if state is not None else None
    main, new_conv = causal_conv1d(main, w["conv_w"], w["conv_b"], conv_state,
                                   valid=valid)

    a, b = rglru_gates(main, w, num_heads)
    if valid is not None and mode != "decode":
        a = jnp.where(valid[..., None], a, 1.0)
        b = jnp.where(valid[..., None], b, 0.0)
    if mode == "decode":
        h = rglru_step(a[:, 0], b[:, 0], state["h"])            # (B, Dr)
        hs = h[:, None]
    else:
        h0 = state["h"] if state is not None else None
        hs = rglru_scan(a, b, h0)                               # (B, S, Dr)
        if valid is None:
            h = hs[:, -1]
        else:
            # carry the state of the last *valid* step: prefix values of the
            # identity-padded scan are bit-exact, but the pad tail is
            # combined through a different tree — reading hs[:, -1] would
            # lose bit-equality with the exact-length scan
            last = jnp.sum(valid.astype(jnp.int32), axis=1) - 1
            h = jnp.take_along_axis(
                hs, jnp.maximum(last, 0)[:, None, None].astype(jnp.int32),
                axis=1)[:, 0]
            if h0 is not None:
                h = jnp.where((last >= 0)[:, None], h, h0)

    y = hs * gate                                               # fp32
    y = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), w["wo"])
    new_state = None
    if state is not None:
        new_state = {"h": h, "conv": new_conv}
    return y, new_state
