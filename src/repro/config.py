"""Configuration system for the DeServe reproduction framework.

Every model architecture is described by a :class:`ModelConfig`; every
benchmark/dry-run workload by a :class:`ShapeConfig`; every device topology by a
:class:`MeshConfig`.  Configs are frozen dataclasses so they can be hashed and
used as static arguments to ``jax.jit``.

Layer heterogeneity (local/global attention, recurrent/attention hybrids,
mLSTM/sLSTM mixes) is expressed with a *block pattern*: a tuple of layer-kind
strings that repeats over the depth of the network.  The model runtime scans
over whole pattern periods (weights stacked over periods) and unrolls the
remainder ("tail") layers, which keeps HLO size O(period) instead of O(depth).
"""

from __future__ import annotations

import dataclasses
import importlib
import pkgutil
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------

# Attention-family kinds (consume/produce KV cache):
ATTN_KINDS = ("attn", "local", "global")
# Recurrent-family kinds (carry O(1) state per sequence):
RECURRENT_KINDS = ("rglru", "mlstm", "slstm")

ALL_KINDS = ATTN_KINDS + RECURRENT_KINDS


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts configuration (GShard/Switch-style top-k routing)."""

    num_experts: int
    experts_per_token: int          # top-k
    d_expert: int                   # per-expert FFN hidden size
    capacity_factor: float = 1.25   # per-expert buffer slack for dropless-ish dispatch
    router_jitter: float = 0.0
    normalize_router_weights: bool = True  # qwen3 renormalizes top-k probs


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  All sizes are in units of elements, not bytes."""

    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    block_pattern: tuple = ("attn",)
    window_size: int = 0             # sliding-window size for "local" layers
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10000.0
    rope_scaling: float = 1.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    use_qk_norm: bool = False        # qwen3-style RMSNorm on q/k heads
    frontend: str = "token"          # token | audio_frames | vision_patches
    num_patch_tokens: int = 0        # vlm: patch tokens prepended to the text
    d_rnn: int = 0                   # recurrent width (0 -> d_model)
    conv_width: int = 4              # temporal-conv width in recurrent blocks
    logit_softcap: float = 0.0       # gemma-style tanh soft-capping (0 = off)
    scale_embeddings: bool = False   # gemma-style sqrt(d_model) embedding scale
    max_position_embeddings: int = 131072
    source: str = ""                 # provenance note ([arXiv:...; tier])

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.d_rnn == 0:
            object.__setattr__(self, "d_rnn", self.d_model)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: q heads {self.num_heads} not divisible by kv heads "
            f"{self.num_kv_heads}")
        for k in self.block_pattern:
            assert k in ALL_KINDS, f"unknown layer kind {k!r}"

    # -- derived quantities -------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> tuple:
        """Layer kind for each of the ``num_layers`` layers (pattern tiled)."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def attention_layer_count(self) -> int:
        return sum(1 for k in self.layer_kinds() if k in ATTN_KINDS)

    def recurrent_layer_count(self) -> int:
        return sum(1 for k in self.layer_kinds() if k in RECURRENT_KINDS)

    def is_subquadratic(self) -> bool:
        """True if long-context decode memory does not grow ~linearly with
        full-attention KV for every layer (SSM / hybrid / sliding-window)."""
        kinds = self.layer_kinds()
        full = sum(1 for k in kinds if k in ("attn", "global"))
        return full == 0 or (full / len(kinds)) <= 0.34

    # -- parameter counting (used by the cost model / roofline) -------------

    def param_count(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D                      # embedding
        if not self.tie_embeddings:
            total += D * V                 # unembedding
        total += D                         # final norm
        for kind in self.layer_kinds():
            total += self._layer_params(kind)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE activates only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        D = self.d_model
        total = self.param_count()
        # subtract inactive experts
        per_expert = 3 * D * self.moe.d_expert
        inactive = self.moe.num_experts - self.moe.experts_per_token
        total -= inactive * per_expert * self.num_layers_with_moe()
        return total

    def num_layers_with_moe(self) -> int:
        return self.num_layers if self.moe is not None else 0

    def _layer_params(self, kind: str) -> int:
        D, F = self.d_model, self.d_ff
        Dr = self.d_rnn
        H, Hk, Dh = self.num_heads, self.num_kv_heads, self.head_dim
        n = 0
        if kind in ATTN_KINDS:
            n += D * (H * Dh) + 2 * D * (Hk * Dh) + (H * Dh) * D   # qkvo
            n += 2 * D                                             # ln1, ln2
            if self.use_qk_norm:
                n += 2 * Dh
            if self.moe is not None:
                n += D * self.moe.num_experts                       # router
                n += self.moe.num_experts * 3 * D * self.moe.d_expert
            elif F > 0:
                n += 3 * D * F                                      # swiglu
        elif kind == "rglru":
            # gated linear recurrent block (Griffin): two in-proj branches,
            # temporal conv, block-diagonal gate projections, out proj, + mlp
            n += 2 * D * Dr + self.conv_width * Dr
            n += 2 * (Dr * Dr // max(H, 1)) + 2 * Dr               # gates (blockdiag) + Lambda + bias
            n += Dr * D + 2 * D
            if F > 0:
                n += 3 * D * F
        elif kind == "mlstm":
            # up-proj (2x), q/k/v projections in expanded space, gates, down
            n += 2 * D * Dr + 3 * Dr * Dr // max(H, 1) + 3 * Dr + Dr * D + D
        elif kind == "slstm":
            n += 4 * D * Dr + 4 * (Dr * Dr // max(H, 1)) + 4 * Dr + Dr * D + D
        return n

    def kv_bytes_per_token_per_layer(self, dtype_bytes: int = 2) -> int:
        return 2 * self.num_kv_heads * self.head_dim * dtype_bytes

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV bytes/token across layers, honouring sliding windows (a local
        layer's cache never exceeds its window)."""
        return self.attention_layer_count() * self.kv_bytes_per_token_per_layer(dtype_bytes)


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / parallelism
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    name: str
    shape: tuple
    axes: tuple

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig("single_pod", (16, 16), ("data", "model"))
MULTI_POD = MeshConfig("multi_pod", (2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all_configs() -> None:
    from repro import configs as _pkg
    for m in pkgutil.iter_modules(_pkg.__path__):
        importlib.import_module(f"repro.configs.{m.name}")


def get_arch(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all_configs()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _load_all_configs()
    return sorted(_REGISTRY)


def reduced_config(cfg: ModelConfig, *, num_layers: int = 0,
                   d_model: int = 64, vocab: int = 128) -> ModelConfig:
    """A tiny config of the same family for CPU smoke tests.

    Keeps the block pattern (one full period + tail behaviour) and head
    structure ratios, shrinks widths/verbosity.
    """
    period = len(cfg.block_pattern)
    if num_layers == 0:
        num_layers = period + max(1, period // 2)   # one period + a tail
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(heads, cfg.num_kv_heads))
    while heads % kv:
        kv -= 1
    head_dim = max(8, d_model // heads)
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=4,
                        experts_per_token=min(2, cfg.moe.experts_per_token),
                        d_expert=2 * d_model,
                        capacity_factor=2.0,
                        normalize_router_weights=cfg.moe.normalize_router_weights)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=0 if cfg.d_ff == 0 else 4 * d_model,
        vocab_size=vocab,
        window_size=min(cfg.window_size, 32) if cfg.window_size else 0,
        moe=moe,
        d_rnn=d_model,
        num_patch_tokens=min(cfg.num_patch_tokens, 4),
        max_position_embeddings=4096,
    )
