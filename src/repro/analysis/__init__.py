"""repro-audit: JAX-aware static analysis + runtime invariant auditing.

DeServe's throughput story rests on invariants the serving core upholds
only by convention — no host syncs inside the persistent pipe tick loop,
fixed-shape jits that never retrace mid-serve, ``(seed, request_id,
token_idx)`` PRNG key discipline, monotonic per-link virtual clocks with
conserved wire-byte books.  This package machine-checks them:

:mod:`repro.analysis.lint`
    Repo-specific AST passes (host-sync detector, retrace-hazard
    detector, PRNG-hygiene pass) with a
    ``# repro-audit: allow(<rule>) — <reason>`` suppression syntax.
    Runnable as ``python -m repro.analysis [paths] [--strict-suppressions]``.

:mod:`repro.analysis.invariants`
    The runtime :class:`EngineAuditor`, enabled via
    ``EngineConfig(strict=True)`` or ``REPRO_STRICT=1`` (tests default it
    on): page-table refcount/leak audits after every admission/eviction/
    reshard replay, ``Status`` lifecycle FSM checks, ``VirtualClock``
    monotonicity + wire-byte book conservation across ``Transport``
    crossings, and jit cache-size probes asserting the serve-loop jits
    compile exactly once per (shape, wire_dtype) config.
"""

from repro.analysis.invariants import (EngineAuditor, InvariantViolation,
                                       jit_cache_size)
from repro.analysis.lint import (AuditConfig, Violation, load_config,
                                 run_lint)

__all__ = [
    "AuditConfig", "EngineAuditor", "InvariantViolation", "Violation",
    "jit_cache_size", "load_config", "run_lint",
]
