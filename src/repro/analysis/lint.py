"""Repo-specific AST lint passes for the DeServe serving core.

Three pass families, all pure-AST (no imports of the analysed code):

**host-sync** — device→host materialisations reachable from the serve
hot path (``Engine.step``, both backends' ``decode``/``prefill_step``,
and both persistent tick jits).  Flags ``.item()`` / ``.tolist()`` /
``jax.device_get`` / ``block_until_ready`` anywhere in the reachable
set, and ``np.array``/``np.asarray``/``int()``/``float()``/``bool()``
applied to *device-tracked* values — names bound from jit entry points
(any ``*jit*`` attribute call), ``jnp.*``/``jax.*`` producers, or the
sampler helpers.  One accidental sync per tick is a WAN-scale stall.

**offload-sync** — blocking host materialisations inside the KV
offloader's *engaged window* (``DoubleBufferOffloader.ensure_resident``
by default; ``offload_windows`` in config).  The double-buffer schedule
only hides swap cost if the swap-out is an enqueued async copy — a
``np.asarray`` / ``jax.device_get`` / ``block_until_ready`` there
serialises the D2H behind the tick and re-opens the very stall the
offloader exists to hide.  The deliberate sync fallback
(``async_swap=False``) carries a reasoned suppression.

**obs-hot-path** — flight-recorder discipline for ``repro.obs``: a
recording call (any dotted call routed through a recorder name —
``self.recorder.span(...)``, ``rec.link_send(...)``; ``obs_roots`` in
config) is flagged inside a tick-jit body (the recorder is host-side
only — a call under tracing either fails or bakes one trace's stamps
into the compiled graph), and, anywhere in the reachable hot set, when
an argument references a *device-tracked* value — recording must read
only host scalars the engine already materialised, or it re-opens the
very sync the flight recorder is designed never to add.

**retrace hazards** —
  * ``retrace-jit``: ``jax.jit`` / ``shard_map`` constructed inside a
    hot-path function (recompiles or re-caches per call);
  * ``retrace-branch``: a Python ``if``/``while`` on a traced value
    inside a tick-jit body (shape/ndim/dtype attribute access is static
    and allowed) — branches on traced data either fail to trace or bake
    in one trace's path;
  * ``retrace-nonhashable``: ``jax.jit(functools.partial(f, kw=[...]))``
    with a mutable-literal kwarg — unhashable partial state defeats the
    jit cache and retraces every call.
  Host-materialisation of traced values inside a tick-jit body is
  reported as ``host-sync`` (it is also a concretization error).

**PRNG hygiene** —
  * ``prng-reuse``: one key name consumed by two or more ``jax.random``
    sampling calls without re-binding (identical streams);
  * ``prng-fold-drop``: a sampling call keyed by a raw ``PRNGKey`` or a
    single-level ``fold_in`` chain — the serving discipline is
    ``fold_in(fold_in(PRNGKey(seed), request_id), token_idx)``; a
    shorter chain drops ``request_id`` or ``token_idx`` and collapses
    streams across requests or positions.

Suppressions: ``# repro-audit: allow(<rule>[, <rule>...]) — <reason>``
on the offending line or the line above.  Under
``--strict-suppressions`` every suppression must carry a non-empty
reason and must actually suppress something (``bad-suppression`` /
``unused-suppression``).

Configuration lives in ``[tool.repro-audit]`` of ``pyproject.toml``
(hot-path roots, traced tick functions, device-typed parameter names);
the baked-in defaults below mirror it so the tool runs on a bare tree.
"""

from __future__ import annotations

import argparse
import ast
import io
import re
import sys
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

# mirrors [tool.repro-audit] in pyproject.toml (pyproject wins when found)
DEFAULT_HOT_ROOTS = [
    "serving.engine:OfflineEngine.step",
    "serving.backend:LocalBackend.decode",
    "serving.backend:LocalBackend.prefill_step",
    "serving.backend:PipelinedBackend.decode",
    "serving.backend:PipelinedBackend.prefill_step",
    "core.pipeline:pipeline_decode_tick",
    "core.pipeline:pipeline_prefill_chunk_tick",
]
DEFAULT_TRACED_FNS = [
    "core.pipeline:pipeline_decode_tick",
    "core.pipeline:pipeline_prefill_chunk_tick",
    "core.pipeline:_pipeline_pass",
    "serving.backend:LocalBackend._decode_fn",
    "serving.backend:_SlotCacheBackend._chunk_fn",
    "serving.backend:_SlotCacheBackend._prefill_fn",
]
# function parameters that carry device arrays into hot-path helpers
# (pure AST cannot see types; the serve seam passes logits rows around)
DEFAULT_DEVICE_PARAMS = ["logits", "logits_row"]
# the offloader's engaged window: functions that run between ticks and
# must only *enqueue* copies, never block on them
DEFAULT_OFFLOAD_WINDOWS = [
    "core.offload:DoubleBufferOffloader.ensure_resident",
    "core.offload:DoubleBufferOffloader._stage_out",
]

# names a flight-recorder handle travels under: a call whose dotted path
# routes through one of these (``self.recorder.span``, ``rec.fault``) is
# an obs recording call for the obs-hot-path pass
DEFAULT_OBS_ROOTS = ["recorder", "rec"]

RULES = ("host-sync", "offload-sync", "obs-hot-path", "retrace-jit",
         "retrace-branch", "retrace-nonhashable", "prng-reuse",
         "prng-fold-drop", "bad-suppression", "unused-suppression")

# calls that force a device→host sync wherever they appear in the hot set
ALWAYS_SYNC = {"jax.device_get", "jax.block_until_ready"}
SYNC_METHODS = {"item", "block_until_ready"}
# host materialisers: flagged only when fed a device-tracked value
HOST_CASTS = {"int", "float", "bool"}
HOST_NP = {"np.array", "np.asarray", "np.copy", "numpy.array",
           "numpy.asarray", "numpy.copy"}
# attribute reads that are static under tracing (never a concretization)
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "sharding"}
# call-name prefixes/names whose results live on device
DEVICE_PREFIXES = ("jnp.", "jax.")
DEVICE_NAMES = {"sample_batched", "fold_in_steps", "token_logprobs",
                "_sample_first", "slot_view", "slot_merge"}
# jax/jnp calls whose results are NOT device arrays
DEVICE_EXCEPTIONS = {"jnp.dtype", "jax.device_get", "jax.devices",
                     "jax.local_devices", "jax.device_count",
                     "jax.tree.map", "jax.tree_util.tree_map",
                     "jax.sharding.Mesh", "jax.block_until_ready"}

SAMPLER_KEY_ARG = {"jax.random.categorical": 0, "sample_batched": 1,
                   "jax.random.gumbel": 0}
RANDOM_CONSUMERS = {"categorical", "normal", "uniform", "bernoulli",
                    "gumbel", "randint", "truncated_normal", "permutation",
                    "choice", "bits"}
KEY_MAKERS = {"jax.random.PRNGKey", "jax.random.key"}


@dataclass
class AuditConfig:
    hot_roots: List[str] = field(default_factory=lambda:
                                 list(DEFAULT_HOT_ROOTS))
    traced_fns: List[str] = field(default_factory=lambda:
                                  list(DEFAULT_TRACED_FNS))
    device_params: List[str] = field(default_factory=lambda:
                                     list(DEFAULT_DEVICE_PARAMS))
    offload_windows: List[str] = field(default_factory=lambda:
                                       list(DEFAULT_OFFLOAD_WINDOWS))
    obs_roots: List[str] = field(default_factory=lambda:
                                 list(DEFAULT_OBS_ROOTS))


def _parse_toml_section(text: str, section: str) -> Dict[str, List[str]]:
    """Minimal TOML-subset reader for ``key = ["a", "b", ...]`` entries of
    one section — python3.10 has no tomllib and the audit config needs
    nothing richer."""
    out: Dict[str, List[str]] = {}
    in_section = False
    key: Optional[str] = None
    buf = ""
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].rstrip()
        if re.match(r"\s*\[", line):
            in_section = line.strip() == f"[{section}]"
            key = None
            continue
        if not in_section or not line.strip():
            continue
        if key is None:
            m = re.match(r"\s*([\w-]+)\s*=\s*(.*)", line)
            if not m:
                continue
            key, buf = m.group(1), m.group(2)
        else:
            buf += " " + line.strip()
        if buf.count("[") and buf.count("]") >= buf.count("["):
            out[key] = re.findall(r"\"([^\"]*)\"|'([^']*)'", buf)
            out[key] = [a or b for a, b in out[key]]
            key, buf = None, ""
    return out


def load_config(start: Path) -> AuditConfig:
    """Read ``[tool.repro-audit]`` from the nearest ``pyproject.toml`` at
    or above ``start``; fall back to the baked-in defaults."""
    cfg = AuditConfig()
    p = start if start.is_dir() else start.parent
    for d in [p, *p.resolve().parents]:
        pj = d / "pyproject.toml"
        if pj.is_file():
            try:
                sect = _parse_toml_section(pj.read_text(),
                                           "tool.repro-audit")
            except OSError:
                break
            if sect.get("hot_roots"):
                cfg.hot_roots = sect["hot_roots"]
            if sect.get("traced_fns"):
                cfg.traced_fns = sect["traced_fns"]
            if sect.get("device_params"):
                cfg.device_params = sect["device_params"]
            if sect.get("offload_windows"):
                cfg.offload_windows = sect["offload_windows"]
            if sect.get("obs_roots"):
                cfg.obs_roots = sect["obs_roots"]
            break
    return cfg


# ---------------------------------------------------------------------------
# Indexing: functions, calls, suppressions
# ---------------------------------------------------------------------------


@dataclass
class Violation:
    rule: str
    path: str
    line: int
    msg: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.msg}"


@dataclass
class Suppression:
    path: str
    line: int
    rules: Set[str]
    reason: str
    used: bool = False


@dataclass
class FuncInfo:
    module: str                   # dotted module ("repro.serving.backend")
    qual: str                     # "Class.method" or "func"
    path: str
    node: ast.AST                 # FunctionDef / AsyncFunctionDef

    @property
    def full(self) -> str:
        return f"{self.module}:{self.qual}"

    @property
    def bare(self) -> str:
        return self.qual.rsplit(".", 1)[-1]


_SUPPRESS_RE = re.compile(
    r"#\s*repro-audit:\s*allow\(([^)]*)\)\s*(?:[-—–]+\s*(\S.*))?")


def _collect_suppressions(path: str, source: str) -> List[Suppression]:
    # real COMMENT tokens only — the syntax quoted in a docstring or
    # string literal is documentation, not a suppression
    out = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except tokenize.TokenError:
        comments = [(i, line) for i, line in
                    enumerate(source.splitlines(), start=1)
                    if line.lstrip().startswith("#")]
    for i, text in comments:
        m = _SUPPRESS_RE.search(text)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out.append(Suppression(path=path, line=i, rules=rules,
                                   reason=(m.group(2) or "").strip()))
    return out


def _dotted(node: ast.AST) -> Optional[str]:
    """Best-effort dotted name of a Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    if parts:                       # e.g. x[0].foo → ".foo" tail only
        return "." + ".".join(reversed(parts))
    return None


class _FuncCollector(ast.NodeVisitor):
    def __init__(self, module: str, path: str):
        self.module = module
        self.path = path
        self.stack: List[str] = []
        self.funcs: List[FuncInfo] = []

    def visit_ClassDef(self, node: ast.ClassDef):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def _func(self, node):
        qual = ".".join(self.stack + [node.name])
        self.funcs.append(FuncInfo(self.module, qual, self.path, node))
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _func
    visit_AsyncFunctionDef = _func


@dataclass
class FileIndex:
    path: str
    module: str
    tree: ast.AST
    funcs: List[FuncInfo]
    suppressions: List[Suppression]


def _module_name(path: Path, root: Path) -> str:
    try:
        rel = path.resolve().relative_to(root.resolve())
    except ValueError:
        rel = Path(path.name)
    parts = list(rel.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) or path.stem


def index_paths(paths: Sequence[Path]) -> Tuple[List[FileIndex],
                                                List[Violation]]:
    files: List[FileIndex] = []
    errors: List[Violation] = []
    for root in paths:
        py_files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        base = root.parent if root.is_file() else root
        for f in py_files:
            try:
                src = f.read_text()
                tree = ast.parse(src, filename=str(f))
            except (OSError, SyntaxError) as e:
                errors.append(Violation("parse-error", str(f),
                                        getattr(e, "lineno", 0) or 0,
                                        str(e)))
                continue
            mod = _module_name(f, base)
            col = _FuncCollector(mod, str(f))
            col.visit(tree)
            files.append(FileIndex(str(f), mod, tree, col.funcs,
                                   _collect_suppressions(str(f), src)))
    return files, errors


# ---------------------------------------------------------------------------
# Call graph + reachability
# ---------------------------------------------------------------------------


def _calls_of(fn: FuncInfo) -> List[Tuple[str, ast.Call]]:
    out = []
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            if name:
                out.append((name, node))
    return out


def _match_spec(fn: FuncInfo, spec: str) -> bool:
    return fn.full.endswith(spec) or fn.qual == spec


def reachable_functions(files: Sequence[FileIndex],
                        roots: Sequence[str]) -> Set[str]:
    """Transitive closure of the hot roots over a name-based call graph:
    a call ``a.b.c(...)`` edges to every function named ``c`` anywhere in
    the indexed tree.  Deliberately an over-approximation — reachability
    gates *reporting*, and a missed edge hides a real sync while a
    spurious edge only asks for one explained suppression."""
    by_bare: Dict[str, List[FuncInfo]] = {}
    by_full: Dict[str, FuncInfo] = {}
    for fi in files:
        for fn in fi.funcs:
            by_bare.setdefault(fn.bare, []).append(fn)
            by_full[fn.full] = fn
    work = [fn.full for fi in files for fn in fi.funcs
            if any(_match_spec(fn, r) for r in roots)]
    seen: Set[str] = set(work)
    while work:
        fn = by_full[work.pop()]
        for name, _ in _calls_of(fn):
            bare = name.rsplit(".", 1)[-1]
            for callee in by_bare.get(bare, ()):
                if callee.full not in seen:
                    seen.add(callee.full)
                    work.append(callee.full)
    return seen


# ---------------------------------------------------------------------------
# Dataflow-lite helpers
# ---------------------------------------------------------------------------


def _is_device_call(name: str) -> bool:
    if name in DEVICE_EXCEPTIONS:
        return False
    bare = name.rsplit(".", 1)[-1]
    if "jit" in bare:
        return True
    if bare in DEVICE_NAMES:
        return True
    return name.startswith(DEVICE_PREFIXES) and name not in DEVICE_EXCEPTIONS


def _refs_tracked(node: ast.AST, tracked: Set[str]) -> bool:
    """Does ``node`` read a tracked name other than through a static
    attribute (``.shape`` / ``.ndim`` / ``.dtype`` ...)?"""
    if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
        return False
    if isinstance(node, ast.Name):
        return isinstance(node.ctx, ast.Load) and node.id in tracked
    return any(_refs_tracked(c, tracked) for c in ast.iter_child_nodes(node))


def _expr_is_device(node: ast.AST, tracked: Set[str]) -> bool:
    if isinstance(node, ast.Call):
        name = _dotted(node.func)
        if name and _is_device_call(name):
            return True
    return _refs_tracked(node, tracked)


def _target_names(target: ast.AST) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out = []
        for el in target.elts:
            out.extend(_target_names(el))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []                       # attribute / subscript targets: skip


def _tracked_names(fn: ast.AST, seed: Set[str]) -> Set[str]:
    """Fixpoint taint: names bound (directly or transitively) to device
    values inside ``fn``.  Loop targets and nested-function parameters are
    NOT tainted: iterating a pytree walks static container structure, and
    closures are usually invoked with static arguments."""
    tracked = set(seed)
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                if node.value is None:
                    continue
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.NamedExpr):
                value, targets = node.value, [node.target]
            else:
                continue
            if _expr_is_device(value, tracked):
                for t in targets:
                    for name in _target_names(t):
                        if name not in tracked:
                            tracked.add(name)
                            changed = True
    return tracked


def _pos_params(node) -> List[str]:
    a = node.args
    return [p.arg for p in [*a.posonlyargs, *a.args]
            if p.arg not in ("self", "cls")]


# ---------------------------------------------------------------------------
# Pass 1: host-sync detector
# ---------------------------------------------------------------------------


def _host_sync_pass(files: Sequence[FileIndex], cfg: AuditConfig,
                    reachable: Set[str]) -> List[Violation]:
    out: List[Violation] = []
    for fi in files:
        for fn in fi.funcs:
            if fn.full not in reachable:
                continue
            seed = {p for p in _pos_params(fn.node)
                    if p in cfg.device_params}
            tracked = _tracked_names(fn.node, seed)
            for name, call in _calls_of(fn):
                tag = None
                if name in ALWAYS_SYNC:
                    tag = (f"`{name}` blocks on device work — one call "
                           "per tick is a WAN-scale stall in the serve "
                           "loop")
                elif name.rsplit(".", 1)[-1] in SYNC_METHODS and \
                        "." in name:
                    tag = (f"`.{name.rsplit('.', 1)[-1]}()` synchronously "
                           "materialises a device value on host")
                elif name.endswith(".tolist") and _refs_tracked(
                        call.func, tracked):
                    tag = "`.tolist()` on a device value syncs the stream"
                elif name in HOST_NP and any(
                        _refs_tracked(a, tracked) for a in call.args):
                    tag = (f"`{name}` on a device value forces a "
                           "device→host copy inside the tick loop")
                elif name in HOST_CASTS and call.args and _refs_tracked(
                        call.args[0], tracked):
                    tag = (f"`{name}()` on a traced/device value blocks "
                           "until the device catches up (and fails under "
                           "jit tracing)")
                if tag:
                    out.append(Violation(
                        "host-sync", fi.path, call.lineno,
                        f"{fn.qual}: {tag}"))
    return out


# ---------------------------------------------------------------------------
# Pass 1b: offload-sync detector
# ---------------------------------------------------------------------------


def _offload_sync_pass(files: Sequence[FileIndex],
                       cfg: AuditConfig) -> List[Violation]:
    """Any blocking host materialisation inside the offloader's engaged
    window.  Unlike ``host-sync`` this does not gate on a device-tracked
    dataflow: the window functions exist solely to move pool slices, so
    *every* ``np.asarray``/``device_get``/``block_until_ready`` there is
    a copy that should have been an enqueued async one."""
    out: List[Violation] = []
    stall = ("serialises the D2H swap behind the tick — store the "
             "enqueued jax copy (async_swap) so the transfer hides "
             "under the next tick's compute")
    for fi in files:
        for fn in fi.funcs:
            if not any(_match_spec(fn, w) for w in cfg.offload_windows):
                continue
            for name, call in _calls_of(fn):
                bare = name.rsplit(".", 1)[-1]
                tag = None
                if name in ALWAYS_SYNC:
                    tag = f"`{name}` in the offload window {stall}"
                elif bare in SYNC_METHODS and "." in name:
                    tag = f"`.{bare}()` in the offload window {stall}"
                elif name.endswith(".tolist"):
                    tag = f"`.tolist()` in the offload window {stall}"
                elif name in HOST_NP:
                    tag = f"`{name}` in the offload window {stall}"
                if tag:
                    out.append(Violation("offload-sync", fi.path,
                                         call.lineno, f"{fn.qual}: {tag}"))
    return out


# ---------------------------------------------------------------------------
# Pass 1c: obs-hot-path detector
# ---------------------------------------------------------------------------


def _obs_pass(files: Sequence[FileIndex], cfg: AuditConfig,
              reachable: Set[str]) -> List[Violation]:
    """Flight-recorder discipline (``repro.obs``).  A recording call is
    any dotted call whose path routes through an ``obs_roots`` name
    (``self.recorder.span``, ``rec.link_send``).  Two failure modes:

    * inside a tick-jit body (``traced_fns``) *every* recording call is
      flagged — the recorder is host-side; under tracing the call either
      fails or bakes one trace's stamps into the compiled graph;
    * in a reachable hot-path function, a recording call whose arguments
      reference a device-tracked value is flagged — materialising it for
      the trace adds the very device→host sync the recorder's contract
      ("record only values the engine already holds") forbids.
    """
    out: List[Violation] = []
    roots = set(cfg.obs_roots)

    def _is_obs(name: str) -> bool:
        parts = name.split(".")
        # the final component is the method; any earlier component being
        # a recorder name makes this a recording call
        return len(parts) >= 2 and any(p in roots for p in parts[:-1])

    for fi in files:
        for fn in fi.funcs:
            in_jit = any(_match_spec(fn, t) for t in cfg.traced_fns)
            if not in_jit and fn.full not in reachable:
                continue
            tracked: Optional[Set[str]] = None
            for name, call in _calls_of(fn):
                if not _is_obs(name):
                    continue
                if in_jit:
                    out.append(Violation(
                        "obs-hot-path", fi.path, call.lineno,
                        f"{fn.qual}: recording call `{name}` inside a "
                        "tick-jit body — the flight recorder is "
                        "host-side only; record after the jit returns"))
                    continue
                if tracked is None:     # computed once per function
                    seed = {p for p in _pos_params(fn.node)
                            if p in cfg.device_params}
                    tracked = _tracked_names(fn.node, seed)
                vals = [*call.args, *(k.value for k in call.keywords)]
                if any(_refs_tracked(a, tracked) for a in vals):
                    out.append(Violation(
                        "obs-hot-path", fi.path, call.lineno,
                        f"{fn.qual}: recording call `{name}` "
                        "materialises a traced/device value — record "
                        "only host scalars the engine already holds"))
    return out


# ---------------------------------------------------------------------------
# Pass 2: retrace hazards
# ---------------------------------------------------------------------------


def _retrace_pass(files: Sequence[FileIndex], cfg: AuditConfig,
                  reachable: Set[str]) -> List[Violation]:
    out: List[Violation] = []
    for fi in files:
        for fn in fi.funcs:
            # (a) jit/shard_map built inside the hot path
            if fn.full in reachable:
                for name, call in _calls_of(fn):
                    bare = name.rsplit(".", 1)[-1]
                    if bare in ("jit", "shard_map") and (
                            name.startswith(("jax.", "jjit."))
                            or bare == "shard_map" or name == "jit"):
                        out.append(Violation(
                            "retrace-jit", fi.path, call.lineno,
                            f"{fn.qual}: `{name}` constructed inside the "
                            "serve hot path — a fresh jit wrapper "
                            "compiles (or re-hashes) per call; hoist to "
                            "__init__ or module scope"))
            # (b) non-hashable static args anywhere
            for name, call in _calls_of(fn):
                if name.rsplit(".", 1)[-1] != "jit":
                    continue
                for arg in call.args:
                    if not (isinstance(arg, ast.Call) and
                            (_dotted(arg.func) or "").endswith("partial")):
                        continue
                    for kw in arg.keywords:
                        if isinstance(kw.value, (ast.List, ast.Dict,
                                                 ast.Set, ast.ListComp,
                                                 ast.DictComp,
                                                 ast.SetComp)):
                            out.append(Violation(
                                "retrace-nonhashable", fi.path,
                                kw.value.lineno,
                                f"{fn.qual}: `functools.partial` kwarg "
                                f"`{kw.arg}` is a mutable literal — "
                                "unhashable partial state defeats the "
                                "jit cache and retraces every call"))
            # (c) Python branches on traced values inside tick jits
            if not any(_match_spec(fn, t) for t in cfg.traced_fns):
                continue
            node = fn.node
            kwonly = {p.arg for p in node.args.kwonlyargs}
            traced = _tracked_names(node, set(_pos_params(node)) - kwonly)
            nested_params: Set[str] = set()
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)) and sub is not \
                        node:
                    nested_params |= set(_pos_params(sub))
            traced -= nested_params     # closures get static call args
            for sub in ast.walk(node):
                if isinstance(sub, (ast.If, ast.While)) and _refs_tracked(
                        sub.test, traced):
                    out.append(Violation(
                        "retrace-branch", fi.path, sub.lineno,
                        f"{fn.qual}: Python branch on a traced value — "
                        "use lax.cond/jnp.where, or mark the argument "
                        "static (shape/ndim/dtype reads are fine)"))
                elif isinstance(sub, ast.Call):
                    name = _dotted(sub.func)
                    if name in HOST_NP and any(
                            _refs_tracked(a, traced) for a in sub.args):
                        out.append(Violation(
                            "host-sync", fi.path, sub.lineno,
                            f"{fn.qual}: `{name}` on a traced value "
                            "inside a tick jit — concretization error "
                            "under tracing"))
                    elif name in HOST_CASTS and sub.args and _refs_tracked(
                            sub.args[0], traced):
                        out.append(Violation(
                            "host-sync", fi.path, sub.lineno,
                            f"{fn.qual}: `{name}()` on a traced value "
                            "inside a tick jit — concretization error "
                            "under tracing"))
    return out


# ---------------------------------------------------------------------------
# Pass 3: PRNG hygiene
# ---------------------------------------------------------------------------


def _key_depth(node: ast.AST, env: Dict[str, Optional[int]]
               ) -> Optional[int]:
    """Fold-chain depth of a key expression: ``PRNGKey(s)`` is 0,
    ``fold_in(k, x)`` is depth(k)+1, a name looks up its binding; anything
    else (params, splits, helper results) is unknown → None (never
    flagged)."""
    if isinstance(node, ast.Call):
        name = _dotted(node.func) or ""
        if name in KEY_MAKERS:
            return 0
        if name.endswith("fold_in") and node.args:
            inner = _key_depth(node.args[0], env)
            return None if inner is None else inner + 1
        return None
    if isinstance(node, ast.Name):
        return env.get(node.id)
    return None


def _prng_pass(files: Sequence[FileIndex]) -> List[Violation]:
    out: List[Violation] = []
    for fi in files:
        for fn in fi.funcs:
            # bindings of key-producing expressions (single-assignment only:
            # re-bound names drop out of both rules)
            bound: Dict[str, List[Tuple[Optional[int], int]]] = {}
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Assign) and len(node.targets) == 1:
                    names = _target_names(node.targets[0])
                    if len(names) != 1:
                        continue
                    d = _key_depth(node.value, {
                        k: v[0][0] for k, v in bound.items()
                        if len(v) == 1})
                    if d is not None or (isinstance(node.value, ast.Call)
                                         and (_dotted(node.value.func) or ""
                                              ).endswith(("fold_in",
                                                          "split"))):
                        bound.setdefault(names[0], []).append(
                            (d, node.lineno))
            env: Dict[str, Optional[int]] = {
                k: v[0][0] for k, v in bound.items() if len(v) == 1}
            key_names = {k for k, v in bound.items() if len(v) == 1}

            uses: Dict[str, List[int]] = {}
            for name, call in _calls_of(fn):
                bare = name.rsplit(".", 1)[-1]
                # fold-drop: sampling keyed below the (seed, request_id,
                # token_idx) discipline
                if name in SAMPLER_KEY_ARG:
                    idx = SAMPLER_KEY_ARG[name]
                    if idx < len(call.args):
                        d = _key_depth(call.args[idx], env)
                        if d is not None and d < 2:
                            what = ("raw PRNGKey — request_id AND "
                                    "token_idx dropped" if d == 0 else
                                    "single fold_in — token_idx (or "
                                    "request_id) dropped")
                            out.append(Violation(
                                "prng-fold-drop", fi.path, call.lineno,
                                f"{fn.qual}: sampling keyed by a "
                                f"{what}; derive keys as fold_in("
                                "fold_in(PRNGKey(seed), request_id), "
                                "token_idx)"))
                # reuse: the same key name feeding >= 2 sampling calls
                if bare in RANDOM_CONSUMERS and (
                        name.startswith("jax.random.")
                        or name.startswith("random.")):
                    for arg in call.args:
                        if isinstance(arg, ast.Name) and \
                                arg.id in key_names:
                            uses.setdefault(arg.id, []).append(call.lineno)
            for key, lines in uses.items():
                if len(lines) >= 2:
                    out.append(Violation(
                        "prng-reuse", fi.path, sorted(lines)[1],
                        f"{fn.qual}: key `{key}` consumed by "
                        f"{len(lines)} jax.random calls (lines "
                        f"{sorted(lines)}) without re-binding — "
                        "identical streams; split or fold_in per use"))
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def _apply_suppressions(violations: List[Violation],
                        files: Sequence[FileIndex],
                        strict: bool) -> List[Violation]:
    sup_by_file: Dict[str, List[Suppression]] = {
        fi.path: fi.suppressions for fi in files}
    kept: List[Violation] = []
    for v in violations:
        hit = None
        for s in sup_by_file.get(v.path, ()):
            if v.rule in s.rules and s.line in (v.line, v.line - 1):
                hit = s
                break
        if hit is None:
            kept.append(v)
        else:
            hit.used = True
    if strict:
        for fi in files:
            for s in fi.suppressions:
                bad = s.rules - set(RULES)
                if bad:
                    kept.append(Violation(
                        "bad-suppression", s.path, s.line,
                        f"unknown rule(s) {sorted(bad)} — valid: "
                        f"{', '.join(RULES)}"))
                if not s.reason:
                    kept.append(Violation(
                        "bad-suppression", s.path, s.line,
                        "suppression without a written reason — every "
                        "exemption must explain itself: "
                        "# repro-audit: allow(<rule>) — <why>"))
                elif not s.used and not bad:
                    kept.append(Violation(
                        "unused-suppression", s.path, s.line,
                        f"allow({', '.join(sorted(s.rules))}) suppresses "
                        "nothing on this or the next line — stale after "
                        "a fix; delete it"))
    return kept


def run_lint(paths: Sequence[Path], config: Optional[AuditConfig] = None,
             *, strict_suppressions: bool = False,
             rules: Optional[Sequence[str]] = None) -> List[Violation]:
    """Run every pass over ``paths`` (files or directories), apply
    suppressions, return surviving violations sorted by location."""
    cfg = config or load_config(Path(paths[0]) if paths else Path("."))
    files, violations = index_paths([Path(p) for p in paths])
    reachable = reachable_functions(files, cfg.hot_roots)
    violations += _host_sync_pass(files, cfg, reachable)
    violations += _offload_sync_pass(files, cfg)
    violations += _obs_pass(files, cfg, reachable)
    violations += _retrace_pass(files, cfg, reachable)
    violations += _prng_pass(files)
    if rules:
        want = set(rules) | {"parse-error"}
        violations = [v for v in violations if v.rule in want]
    violations = _apply_suppressions(violations, files,
                                     strict_suppressions)
    return sorted(violations, key=lambda v: (v.path, v.line, v.rule))


def _default_root() -> Path:
    # the src/ tree the installed repro package lives in
    return Path(__file__).resolve().parents[2]


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-audit static analysis for the serving core")
    ap.add_argument("paths", nargs="*", help="files or directories "
                    "(default: the src tree of the installed package)")
    ap.add_argument("--strict-suppressions", action="store_true",
                    help="require a written reason on every suppression "
                         "and flag suppressions that match nothing")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rules to report "
                         f"(all: {', '.join(RULES)})")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for r in RULES:
            print(r)
        return 0
    paths = [Path(p) for p in args.paths] or [_default_root()]
    for p in paths:
        if not p.exists():
            print(f"repro-audit: no such path: {p}", file=sys.stderr)
            return 2
    rules = [r.strip() for r in args.rules.split(",")] if args.rules \
        else None
    violations = run_lint(paths, strict_suppressions=args.strict_suppressions,
                          rules=rules)
    for v in violations:
        print(v.format())
    n = len(violations)
    print(f"repro-audit: {n} violation(s)" if n else
          "repro-audit: clean")
    return 1 if violations else 0
