"""Runtime invariant auditor for the serving engine (strict mode).

Enabled via ``EngineConfig(strict=True)``, ``OfflineEngine(...,
strict=True)``, or the ``REPRO_STRICT=1`` environment variable (the test
suite defaults it on).  The engine calls the three hooks after every
admission (``submit``), tick (``step``), and elastic rebuild
(``reshard``); each hook re-checks the full invariant set and raises
:class:`InvariantViolation` at the first breach — the point of strict
mode is to fail at the tick that corrupted state, not at the assert that
happened to read it later.

Checked invariants:

* **page accounting** — the allocator's free lists plus the owned pages
  exactly partition the non-scratch page universe (no leak, no
  double-grant, scratch page 0 never owned); every page's refcount
  equals its ownership multiplicity (slots listing it + prefix-cache
  retains), shared pages are local-only (global pools parity-swap),
  owned pages belong only to occupied slots or the prefix cache, and
  the engine's published page table matches the allocator's view
  row-for-row;
* **Status lifecycle** — per-sequence transitions follow the FSM
  QUEUED → PREFILLING → DECODING → FINISHED, with the single legal
  back-edge PREFILLING → QUEUED (admission rollback on page
  exhaustion); container placement matches status (queue holds QUEUED,
  slots hold PREFILLING/DECODING at slot == seq.slot, finished holds
  FINISHED);
* **transport books** — ``VirtualClock`` time and the wire-byte /
  send / stall books are monotone non-decreasing across every
  ``Transport`` crossing *including reshard* (``for_stages`` must carry
  the books — a reset-to-zero after a rebuild is a conservation bug),
  and bytes only move with a send;
* **offload double-buffer parity** — each offloader's resident map binds
  global pool parity ``p`` only to microbatches with ``mb % 2 == p``, the
  host store never keys a currently-resident microbatch (its content
  would be stale the moment the pool mutates), and the swap counters are
  monotone non-decreasing for the offloader's lifetime (reset only when
  reshard rebuilds the backend with fresh offloaders);
* **jit cache sizes** — every serve-loop jit the backend exposes via
  ``jit_entries()`` (``_tick_jit`` / ``_pf_tick_jit`` / ``_decode_jit``
  / ``_chunk_jit`` / the per-length prefill jits) has compiled at most
  once: a second cache entry mid-serve is a silent retrace (shape leak,
  weak-type flip, or non-hashable static arg).

The audit is pure host-side bookkeeping over state the engine already
holds on host (numpy page table, python free lists, transport counters)
— it never touches device arrays, so strict mode adds no device syncs;
cost is O(pages + slots) per step.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["EngineAuditor", "InvariantViolation", "jit_cache_size"]


class InvariantViolation(AssertionError):
    """A strict-mode engine invariant was broken."""


def jit_cache_size(fn) -> Optional[int]:
    """Number of compiled entries in a ``jax.jit`` callable's cache, or
    ``None`` when the wrapper doesn't expose one (non-jit callables,
    future jax versions renaming the probe).  ``None`` means "cannot
    check", never "violation"."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


def _fail(where: str, msg: str) -> None:
    raise InvariantViolation(f"[{where}] {msg}")


class EngineAuditor:
    """Attached to one engine; re-entrant across reshard (the engine
    object survives a rebuild, only its backend is replaced)."""

    def __init__(self, engine) -> None:
        self._engine = engine
        # id(seq) -> (status, request_id); seqs are retained by the
        # engine's queue/slots/finished lists, so ids stay stable for
        # the sequences we still track
        self._last_status: Dict[int, Tuple[object, int]] = {}
        self._books: Dict[str, float] = {}
        self._off_books: Dict[str, Tuple[int, int]] = {}
        self.checks = 0

    # ---- hooks the engine calls ------------------------------------

    def after_submit(self) -> None:
        self._audit("submit", resharded=False)

    def after_step(self) -> None:
        self._audit("step", resharded=False)

    def after_reshard(self) -> None:
        self._audit("reshard", resharded=True)

    # ---- audit passes ----------------------------------------------

    def _audit(self, where: str, *, resharded: bool) -> None:
        self.checks += 1
        self._audit_pages(where)
        self._audit_fsm(where)
        self._audit_transport(where, resharded=resharded)
        self._audit_offload(where, resharded=resharded)
        self._audit_jits(where)

    def _audit_pages(self, where: str) -> None:
        eng = self._engine
        alloc, pool = eng.alloc, eng.pool
        universe = set(range(1, pool.n_local_pages))
        universe |= set(pool.global_range(0)) | set(pool.global_range(1))

        free: List[int] = list(alloc._free_local)
        for gp in alloc._free_global.values():
            free.extend(gp)
        # multiplicity of ownership: how many slots list each page, plus
        # one per prefix-cache retain — must equal the allocator refcount
        owner_count: Dict[int, int] = {}
        for slot, pages in alloc._seq_pages.items():
            if len(pages) != len(set(pages)):
                _fail(where, f"page audit: slot {slot} lists a page "
                             f"twice (pages={pages})")
            for p in pages:
                owner_count[p] = owner_count.get(p, 0) + 1
        cache = getattr(eng, "prefix_cache", None)
        if cache is not None:
            retained = cache.pages_retained()
            if len(retained) != len(set(retained)):
                _fail(where, "page audit: prefix cache retains a page "
                             f"under two entries ({sorted(retained)})")
            for p in retained:
                owner_count[p] = owner_count.get(p, 0) + 1
        owned = set(owner_count)

        if len(free) != len(set(free)):
            _fail(where, "page audit: duplicate page in the free lists "
                         f"(free={sorted(free)})")
        overlap = set(free) & owned
        if overlap:
            _fail(where, f"page audit: pages {sorted(overlap)} are both "
                         "free and owned")
        if 0 in free or 0 in owned:
            _fail(where, "page audit: scratch page 0 entered the "
                         "allocator (it must stay reserved)")
        seen = set(free) | owned
        if seen != universe:
            leaked = sorted(universe - seen)
            conjured = sorted(seen - universe)
            _fail(where, "page audit: free+owned does not partition the "
                         f"page universe (leaked={leaked}, "
                         f"out-of-range={conjured})")

        # refcounts are the ownership multiplicity, exactly
        refs = dict(getattr(alloc, "_refs", {}))
        if refs != owner_count:
            diff = {p: (owner_count.get(p, 0), refs.get(p, 0))
                    for p in set(refs) | set(owner_count)
                    if refs.get(p, 0) != owner_count.get(p, 0)}
            _fail(where, "page audit: allocator refcounts disagree with "
                         f"ownership (page -> (owners, refcount)): {diff}")
        # sharing is legal only for local pages — global-pool content is
        # parity-swapped per microbatch by the offloader
        shared_global = sorted(p for p, n in owner_count.items()
                               if n > 1 and p >= pool.n_local_pages)
        if shared_global:
            _fail(where, f"page audit: global pages {shared_global} are "
                         "shared — offload parity swaps would clobber "
                         "one owner's view")

        occupied = {slot for slot, seq in enumerate(eng.slots)
                    if seq is not None}
        stray = set(alloc._seq_pages) - occupied
        if stray:
            _fail(where, f"page audit: slots {sorted(stray)} own pages "
                         "but hold no sequence (release missed on "
                         "finish/evict)")

        # published table vs allocator truth: a slot's row is either the
        # allocator's view or still all-zero (admitted this step, first
        # chunk not yet published) — anything else serves stale pages
        for slot in sorted(alloc._seq_pages):
            row = np.asarray(eng.table[slot])
            want = alloc.table_row(slot)
            if row.any() and not np.array_equal(row, want):
                _fail(where, f"page audit: published table row for slot "
                             f"{slot} is {row.tolist()} but the "
                             f"allocator owns {want.tolist()}")
        for slot in sorted(occupied - set(alloc._seq_pages)):
            # a slot without pages must be parked on scratch
            if np.asarray(eng.table[slot]).any():
                _fail(where, f"page audit: slot {slot} owns no pages "
                             "but its table row is non-zero")

    def _audit_fsm(self, where: str) -> None:
        from repro.serving.request import Status
        eng = self._engine
        rank = {Status.QUEUED: 0, Status.PREFILLING: 1,
                Status.DECODING: 2, Status.FINISHED: 3}

        def check(seq, container: str, allowed, slot=None):
            rid = seq.request.request_id
            if seq.status not in allowed:
                _fail(where, f"fsm: request {rid} has status "
                             f"{seq.status.name} inside {container} "
                             f"(allowed: "
                             f"{'/'.join(s.name for s in allowed)})")
            if slot is not None and seq.slot != slot:
                _fail(where, f"fsm: request {rid} sits in slot {slot} "
                             f"but records seq.slot={seq.slot}")
            prev = self._last_status.get(id(seq))
            if prev is not None:
                old, old_rid = prev
                backward = rank[seq.status] < rank[old]
                requeue = (old is Status.PREFILLING
                           and seq.status is Status.QUEUED)
                if old_rid == rid and backward and not requeue:
                    _fail(where, f"fsm: request {rid} moved backward "
                                 f"{old.name} -> {seq.status.name} "
                                 "(only PREFILLING -> QUEUED may "
                                 "rewind, on admission rollback)")
            return id(seq), (seq.status, rid)

        fresh: Dict[int, Tuple[object, int]] = {}
        for seq in eng.queue:
            k, v = check(seq, "queue", (Status.QUEUED,))
            fresh[k] = v
        for slot, seq in enumerate(eng.slots):
            if seq is None:
                continue
            k, v = check(seq, "slots",
                         (Status.PREFILLING, Status.DECODING), slot=slot)
            fresh[k] = v
        for seq in eng.finished:
            k, v = check(seq, "finished", (Status.FINISHED,))
            fresh[k] = v
        # forget sequences no longer held by the engine so recycled
        # object ids can't alias into stale entries
        self._last_status = fresh

    def _audit_transport(self, where: str, *, resharded: bool) -> None:
        transport = getattr(self._engine.backend, "transport", None)
        if transport is None:
            return
        try:
            stats = transport.stats() or {}
        except Exception:
            return
        monotone = ("virtual_time_s", "wire_bytes", "link_sends",
                    "link_stall_s", "raw_bytes")
        prev = self._books
        for key in monotone:
            if key not in stats:
                continue
            now = float(stats[key])
            if now < 0:
                _fail(where, f"transport: {key} is negative ({now})")
            before = prev.get(key)
            if before is not None and now < before - 1e-9:
                carry = (" — for_stages() dropped the books across "
                         "reshard" if resharded else "")
                _fail(where, f"transport: {key} went backward "
                             f"{before} -> {now}{carry}")
        d_wire = float(stats.get("wire_bytes", 0)) - prev.get(
            "wire_bytes", 0.0)
        d_sends = float(stats.get("link_sends", 0)) - prev.get(
            "link_sends", 0.0)
        if d_wire > 0 and d_sends <= 0:
            _fail(where, f"transport: {d_wire:.0f} wire bytes booked "
                         "with no send recorded (byte conservation)")
        clock = getattr(transport, "clock", None)
        if clock is not None and float(getattr(clock, "now", 0.0)) < 0:
            _fail(where, "transport: virtual clock is negative")
        audit = getattr(transport, "audit", None)
        if audit is not None:
            try:
                audit()
            except AssertionError as e:
                _fail(where, f"transport: {e}")
        self._books = {k: float(stats[k]) for k in monotone
                       if k in stats}

    def _audit_offload(self, where: str, *, resharded: bool) -> None:
        backend = self._engine.backend
        offs: List[Tuple[str, object]] = []
        local = getattr(backend, "offloader", None)
        if local is not None:
            offs.append(("offloader", local))
        for i, o in enumerate(getattr(backend, "_stage_off", ()) or ()):
            offs.append((f"_stage_off[{i}]", o))
        epi = getattr(backend, "_epi_off", None)
        if epi is not None:
            offs.append(("_epi_off", epi))
        if resharded:
            # reshard rebuilds the backend with fresh offloaders — their
            # counters legitimately restart from zero
            self._off_books = {}
        for name, off in offs:
            resident = getattr(off, "resident", None)
            if not isinstance(resident, dict):
                continue
            held = set()
            for parity, mb in resident.items():
                if mb is None:
                    continue
                held.add(mb)
                if mb % 2 != parity:
                    _fail(where, f"offload: {name} binds microbatch {mb} "
                                 f"to global pool parity {parity} — the "
                                 "double-buffer schedule requires "
                                 "mb % 2 == parity")
            stale = held & set(getattr(off, "_host", {}))
            if stale:
                _fail(where, f"offload: {name} keeps host-store copies "
                             f"for resident microbatch(es) {sorted(stale)}"
                             " — those bytes go stale the moment the "
                             "pool mutates (swap-in must pop)")
            swaps = int(getattr(off, "swap_count", 0))
            moved = int(getattr(off, "bytes_swapped", 0))
            if swaps < 0 or moved < 0:
                _fail(where, f"offload: {name} swap counters are "
                             f"negative (swaps={swaps}, bytes={moved})")
            prev = self._off_books.get(name)
            if prev is not None and (swaps < prev[0] or moved < prev[1]):
                _fail(where, f"offload: {name} counters went backward "
                             f"(swaps {prev[0]} -> {swaps}, bytes "
                             f"{prev[1]} -> {moved})")
            self._off_books[name] = (swaps, moved)

    def _audit_jits(self, where: str) -> None:
        entries = getattr(self._engine.backend, "jit_entries", None)
        if entries is None:
            return
        for name, fn in entries().items():
            n = jit_cache_size(fn)
            if n is not None and n > 1:
                _fail(where, f"jit: {name} holds {n} compiled traces — "
                             "it retraced mid-serve (shape leak, "
                             "weak-type flip, or non-hashable static "
                             "arg); one (shape, wire_dtype) config must "
                             "compile exactly once")
