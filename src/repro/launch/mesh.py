"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; the multi-pod mesh prepends a 2-pod axis.

    Axis semantics:
      pod   — the high-latency decentralized boundary (pipeline stages for
              serving, folded into DP for training)
      data  — batch/FSDP axis (fast ICI)
      model — tensor/expert parallel axis (fast ICI)
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices: int, *, model_parallel: int = 16):
    """Elastic variant: the largest (data, model) mesh for ``devices``."""
    from repro.distributed.elastic import ElasticPlanner
    plan = ElasticPlanner(model_parallel=model_parallel).plan(devices)
    return jax.make_mesh(plan.shape, plan.axes)
