"""Offline serving driver: the ``LLM`` front end over a pluggable backend.

Runs the full serving stack end-to-end on a *reduced* config (CPU-sized) or
any registered arch: paged KV cache with local+global pools, double-buffer
offloading, microbatch round-robin, continuous batching, and the §3 profit
accounting on the measured throughput.  Requests carry *per-request*
sampling params — ``--mixed`` serves greedy and sampled requests through
the same engine in one run.

``--backend local`` is the single-device path; ``--backend pipelined``
drives the same engine through the ``--stages``-stage SPMD pipeline (on a
CPU host the pod axis is emulated with forced host devices).  ``--plan``
derives (N_B, per-microbatch batch, pool split) from a *measured* stage
time plus ``--latency`` via the §4.3 planner (``EngineConfig.plan``)
instead of the hand-set flags.

Networked serving (pipelined backend): ``--link-latency 0.064`` puts a
uniform simulated WAN (one-way seconds) on every inter-stage link;
``--deployment us-west,us-west,us-east`` places one stage per region and
derives per-link latencies from the registry's region table
(``DeploymentPlan``) — with ``--plan`` the §4.3 planner then consumes the
plan's **max link latency** instead of the scalar ``--latency`` guess.
Links are accounted on a virtual clock (outputs stay bit-identical; the
report gains ``virtual_decode_tok_per_s``).  ``--schedule round_flush``
runs the vLLM-PP baseline schedule for comparison;
``--transport-compress int8`` turns on the REAL in-jit wire codec
(``EngineConfig(wire_dtype="int8")``: per-row quantization inside both
tick jits, wire accounting equal to the packed payload — outputs shift
within the int8 logit tolerance), while ``--transport-compress topk``
remains wire-byte accounting only (no in-jit top-k path).

Resilience drills (pipelined backend): ``--inject-fault
kind@plane:tick:stage[:delay_s]`` (repeatable) drops or delays a stage
tick mid-run — the engine re-injects the lost work and outputs stay
bit-identical; ``--reshard-at STEP:STAGES`` tears the backend down at
engine step STEP and rebuilds it with STAGES pipeline stages, replaying
the page table so in-flight requests resume without recompute.
``--detect-failures TIMEOUT`` instead drives ``Engine.reshard`` from a
live :class:`~repro.distributed.elastic.FailureDetector` loop — one
heartbeat per stage per engine step, ``--kill-device STEP:DEVICE``
silences a device mid-run and the loop reshards when the detector
declares it dead (no explicit stage target needed).

Online serving: ``--online`` switches the batch ``generate()`` call for a
live loop — seeded Poisson arrivals (``--arrival-rate``) are submitted
into the running engine via :class:`~repro.serving.online.OnlineLLM` and
tokens stream out per tick; the run reports p50/p99 TTFT and inter-token
latency.  ``--prefix-cache`` shares fully-prefilled prompt blocks across
requests with a common prefix (pair with ``--system-prompt N`` to give
every request an N-token shared head); ``--slo-ttft`` / ``--slo-itl``
engage the latency-SLO admission policy that shrinks the per-tick prefill
budget when decode latency drifts past target.  Token streams stay
bit-identical to the offline path in all of these modes.

Observability: ``--trace out.json`` turns on the flight recorder
(``EngineConfig(trace=True)``) and writes a Perfetto-loadable
Chrome-trace timeline at exit — engine step phases, per-microbatch stage
occupancy, per-link transfers on the virtual clock, offload swaps,
prefix-cache and SLO events; ``--metrics`` keeps a
counter/gauge/histogram registry over the run, printing a one-line stats
banner every ``--metrics-every`` engine steps and the full Prometheus
exposition text at exit.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 16 \\
      --backend pipelined --stages 2 --max-new 24 [--plan] [--mixed] \\
      [--link-latency 0.064 | --deployment us-west,us-east] \\
      [--schedule round_flush] [--inject-fault drop@decode:12:1] \\
      [--reshard-at 20:1 | --detect-failures 2 --kill-device 6:1] \\
      [--online --arrival-rate 8 --system-prompt 32 --prefix-cache \\
       --slo-ttft 0.5 --slo-itl 0.05]
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time


def _with_max_new(sp, max_new: int):
    return dataclasses.replace(sp, max_new_tokens=max_new)


def _ensure_host_devices(n: int) -> None:
    """Force >= ``n`` host devices for the pod axis — must run before jax
    initialises its backend (real accelerators ignore the flag)."""
    import re
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m and int(m.group(1)) >= n:
        return
    if m:                               # present but too small: raise it
        flags = flags.replace(
            m.group(0), f"--xla_force_host_platform_device_count={n}")
        os.environ["XLA_FLAGS"] = flags
        return
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}").strip()


def measure_stage_time(cfg, params, rt, n_stages: int) -> float:
    """Wall-time one single-sequence decode step (compile excluded) and
    attribute 1/n_stages of it to each stage — the measurement the §4.3
    planner consumes."""
    import jax
    import jax.numpy as jnp
    from repro.models import model as model_lib

    caches = model_lib.init_caches(cfg, 1, 64, rt)
    fn = jax.jit(lambda p, t, c, cp: model_lib.decode_step(p, t, c, cp,
                                                           cfg, rt))
    tok = jnp.zeros((1,), jnp.int32)
    cur = jnp.ones((1,), jnp.int32)
    logits, caches = fn(params, tok, caches, cur)        # compile + warm
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    logits, caches = fn(params, tok, caches, cur)
    jax.block_until_ready(logits)
    return max(1e-4, (time.perf_counter() - t0) / n_stages)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--backend", default="local",
                    choices=["local", "pipelined"])
    ap.add_argument("--stages", type=int, default=2,
                    help="pipeline stages (pipelined backend / --plan)")
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--mb-size", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="tokens per prefill chunk (0 = auto: 32, or the "
                         "planned per-microbatch batch under --plan)")
    ap.add_argument("--max-prefill-tokens", type=int, default=0,
                    help="prefill token budget per engine tick (0 = one "
                         "chunk); rows per chunk = budget // chunk")
    ap.add_argument("--prefill-mode", default="auto",
                    choices=["auto", "chunked", "exact"],
                    help="chunked admission (fully-paged archs) vs the "
                         "exact-length fallback; auto picks per arch")
    ap.add_argument("--mixed", action="store_true",
                    help="serve a mixed workload: greedy, temperature, "
                         "top-k, and top-p requests through one engine")
    ap.add_argument("--inject-fault", action="append", default=[],
                    metavar="kind@plane:tick:stage[:delay_s]",
                    help="drop/delay a pipeline stage tick (repeatable; "
                         "pipelined backend), e.g. drop@decode:12:1 or "
                         "delay@prefill:3:0:0.25 — lost work is "
                         "re-injected, outputs stay bit-identical")
    ap.add_argument("--reshard-at", default="",
                    metavar="STEP:STAGES",
                    help="tear down and rebuild the pipelined backend "
                         "with STAGES stages after engine step STEP "
                         "(page table replayed, no token recomputed)")
    ap.add_argument("--detect-failures", type=float, default=0.0,
                    metavar="TIMEOUT",
                    help="drive Engine.reshard from a live "
                         "FailureDetector loop: one heartbeat per stage "
                         "per engine step, reshard when a device misses "
                         "TIMEOUT steps (pipelined backend)")
    ap.add_argument("--kill-device", action="append", default=[],
                    metavar="STEP:DEVICE",
                    help="stop heartbeating DEVICE after engine step "
                         "STEP (repeatable; the --detect-failures drill "
                         "signal)")
    ap.add_argument("--link-latency", type=float, default=None,
                    help="uniform simulated one-way latency (seconds) on "
                         "every inter-stage link, accounted on a virtual "
                         "clock (pipelined backend); an explicit 0 is a "
                         "zero-cost simulated link, not 'unset'")
    ap.add_argument("--deployment", default="",
                    metavar="REGION[,REGION...]",
                    help="one pipeline stage per region (e.g. "
                         "us-west,us-west,us-east): per-link latencies "
                         "from the registry's region table; overrides "
                         "--stages and, under --plan, --latency")
    ap.add_argument("--schedule", default="circular",
                    choices=["circular", "round_flush"],
                    help="circular = DeServe §4.3 (default); round_flush "
                         "= the vLLM-PP baseline (pipe drained every "
                         "token round) for latency comparisons")
    ap.add_argument("--transport-compress", default="none",
                    choices=["none", "int8", "topk"],
                    help="int8: REAL in-jit activation compression on "
                         "every inter-stage link (wire_dtype='int8' — "
                         "per-row quantize inside the tick jits, "
                         "accounting matches the packed payload); topk: "
                         "wire-byte accounting only (simulated links)")
    ap.add_argument("--heartbeat-clock", default="monotonic",
                    choices=["monotonic", "steps"],
                    help="clock for --detect-failures heartbeats: "
                         "monotonic wall seconds (default; TIMEOUT is in "
                         "seconds) or the engine step index (the "
                         "deterministic shim drills/tests pin — TIMEOUT "
                         "counts steps)")
    ap.add_argument("--online", action="store_true",
                    help="online serving drill: Poisson arrivals submitted "
                         "into a LIVE engine loop (OnlineLLM), tokens "
                         "streamed per tick; reports p50/p99 TTFT and "
                         "inter-token latency")
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    help="mean Poisson arrival rate for --online, "
                         "requests/second (seeded, deterministic)")
    ap.add_argument("--system-prompt", type=int, default=0,
                    metavar="TOKENS",
                    help="prepend a shared TOKENS-long system prompt to "
                         "every request (the prefix-cache workload shape)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share fully-prefilled prompt blocks across "
                         "requests with a common prefix (refcounted "
                         "paged-KV sharing; needs chunked prefill)")
    ap.add_argument("--slo-ttft", type=float, default=0.0,
                    help="TTFT target (seconds) for the latency-SLO "
                         "admission policy (0 = off)")
    ap.add_argument("--slo-itl", type=float, default=0.0,
                    help="inter-token (per-tick) target (seconds) for the "
                         "latency-SLO admission policy (0 = off)")
    ap.add_argument("--plan", action="store_true",
                    help="derive N_B / batch / pools from measured stage "
                         "time + --latency (OfflineEngine.from_plan)")
    ap.add_argument("--kv-budget-mb", type=float, default=4.0,
                    help="per-stage KV byte budget for --plan")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (needs real accelerators)")
    ap.add_argument("--latency", type=float, default=0.064,
                    help="assumed one-way link latency (schedule + --plan)")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="flight recorder: record engine/transport/request "
                         "spans and write a Chrome-trace (Perfetto) "
                         "timeline to OUT.json at exit")
    ap.add_argument("--metrics", action="store_true",
                    help="metrics registry over the run: a one-line stats "
                         "banner every --metrics-every engine steps plus "
                         "Prometheus exposition text at exit")
    ap.add_argument("--metrics-every", type=int, default=50,
                    metavar="STEPS",
                    help="engine steps between --metrics banners")
    ap.add_argument("--strict", action="store_true",
                    help="enable the runtime invariant auditor "
                         "(repro.analysis.invariants): page/FSM/transport/"
                         "jit-cache audits after every step, failing at "
                         "the tick that corrupted state")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    deployment = None
    if args.deployment:
        if args.backend != "pipelined":
            raise SystemExit("--deployment requires --backend pipelined")
        from repro.distributed.transport import DeploymentPlan
        deployment = DeploymentPlan.from_regions(
            [r.strip() for r in args.deployment.split(",") if r.strip()])
        args.stages = deployment.n_stages
    if args.backend != "pipelined" and (
            args.link_latency is not None or args.schedule != "circular"
            or args.transport_compress != "none"):
        raise SystemExit("--link-latency / --schedule / "
                         "--transport-compress require --backend pipelined")
    if args.transport_compress == "topk" and deployment is None \
            and args.link_latency is None:
        raise SystemExit("--transport-compress topk is accounting only — "
                         "it needs a simulated link (--link-latency or "
                         "--deployment) to account on")
    detect = args.detect_failures > 0
    if detect and args.backend != "pipelined":
        raise SystemExit("--detect-failures requires --backend pipelined")
    kills = {}
    for spec in args.kill_device:
        try:
            step_s, dev_s = spec.split(":")
            kills[int(dev_s)] = int(step_s)
        except ValueError:
            raise SystemExit(f"--kill-device wants STEP:DEVICE, got {spec!r}")
    if kills and not detect:
        raise SystemExit("--kill-device only matters under "
                         "--detect-failures (nobody is listening for "
                         "missed heartbeats)")

    reshard_at, reshard_stages = 0, 0
    if args.reshard_at:
        try:
            step_s, stages_s = args.reshard_at.split(":")
            reshard_at, reshard_stages = int(step_s), int(stages_s)
        except ValueError:
            raise SystemExit(f"--reshard-at wants STEP:STAGES, "
                             f"got {args.reshard_at!r}")
        if reshard_at < 1 or reshard_stages < 1:
            raise SystemExit("--reshard-at wants STEP >= 1 and "
                             f"STAGES >= 1, got {args.reshard_at!r}")
        if args.backend != "pipelined":
            raise SystemExit("--reshard-at requires --backend pipelined")
    if args.inject_fault and args.backend != "pipelined":
        raise SystemExit("--inject-fault requires --backend pipelined")
    if args.online and (reshard_at or detect):
        raise SystemExit("--online runs its own live loop — it composes "
                         "with faults/SLO/prefix caching but not with the "
                         "--reshard-at / --detect-failures drill loops")
    if args.arrival_rate <= 0:
        raise SystemExit(f"--arrival-rate must be > 0, "
                         f"got {args.arrival_rate}")
    if args.prefix_cache and args.prefill_mode == "exact":
        raise SystemExit("--prefix-cache needs chunked prefill (prefix "
                         "hits resume mid-prompt); drop "
                         "--prefill-mode exact")

    if args.backend == "pipelined":
        _ensure_host_devices(max(args.stages, reshard_stages))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_arch, reduced_config
    from repro.core.cost_model import PLATFORMS, min_throughput, \
        profit_per_hour
    from repro.core.scheduler import optimal_microbatches
    from repro.models import model as model_lib
    from repro.models.common import Runtime
    from repro.serving.kv_cache import PoolConfig
    from repro.serving.llm import LLM, EngineConfig
    from repro.serving.request import SamplingParams

    from repro.distributed.elastic import FaultPlan
    fault_plan = FaultPlan.parse(args.inject_fault) if args.inject_fault \
        else None

    slo = None
    if args.slo_ttft > 0 or args.slo_itl > 0:
        from repro.serving.engine import SLOConfig
        slo = SLOConfig(ttft_target_s=args.slo_ttft,
                        itl_target_s=args.slo_itl)
        print(f"SLO admission: ttft_target={args.slo_ttft:.3f}s "
              f"itl_target={args.slo_itl:.3f}s (prefill budget shaped "
              "per tick)")

    # int8 is the real in-jit codec: EngineConfig(wire_dtype=) drives the
    # tick jits AND the backend's transport wrap, so the books equal the
    # packed payload.  top-k stays an accounting wrapper built here.
    wire_dtype = "int8" if args.transport_compress == "int8" else "fp32"
    compress = "topk" if args.transport_compress == "topk" else None
    transport = None
    if deployment is not None:
        transport = deployment.transport(compress=compress)
        print(deployment.describe())
    elif args.link_latency is not None:
        from repro.distributed.transport import (CompressedTransport,
                                                 SimulatedLinkTransport)
        transport = SimulatedLinkTransport.uniform(args.stages,
                                                   args.link_latency)
        if compress:
            transport = CompressedTransport(transport, method=compress)
        print(f"links: uniform {args.link_latency * 1000:.0f}ms one-way "
              f"x{args.stages} (virtual clock)"
              + (", topk wire accounting (accounting only)"
                 if compress else ""))
    if wire_dtype == "int8":
        print("wire codec: int8 per-row, in-jit — the ppermute payload "
              "IS the packed payload on every inter-stage link")

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = reduced_config(cfg)
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    print(f"arch={cfg.name} layers={cfg.num_layers} d_model={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M backend={args.backend}")

    params = model_lib.init_params(cfg, jax.random.PRNGKey(args.seed), rt)

    if args.plan:
        t_s = measure_stage_time(cfg, params, rt, args.stages)
        # planner latency input: the deployment plan's max ring-link
        # latency (the slowest link sets the bubble budget) beats a
        # uniform --link-latency (an explicit 0 is honoured as a
        # zero-cost link) beats the bare --latency guess
        plan_latency = None if deployment is not None else (
            args.link_latency if args.link_latency is not None
            else args.latency)
        eff_latency = deployment.max_link_latency if deployment is not None \
            else plan_latency
        print(f"planned: measured stage_time={t_s*1000:.1f}ms "
              f"latency={eff_latency*1000:.0f}ms"
              f"{' (deployment max link)' if deployment else ''} "
              f"kv_budget={args.kv_budget_mb:.1f}MB")
        econfig = EngineConfig.plan(
            n_stages=args.stages, stage_time=t_s, latency=plan_latency,
            deployment=deployment, transport=transport,
            schedule=args.schedule,
            m_kv_bytes=args.kv_budget_mb * 1e6, page_size=args.page_size,
            max_pages_per_seq=16, max_microbatches=16, mb_size_cap=4,
            backend=args.backend, seed=args.seed,
            prefill_chunk=args.prefill_chunk,
            max_prefill_tokens_per_tick=args.max_prefill_tokens,
            prefill_mode=args.prefill_mode, fault_plan=fault_plan,
            wire_dtype=wire_dtype, prefix_cache=args.prefix_cache,
            slo=slo, trace=bool(args.trace) or None,
            strict=args.strict or None)
    else:
        pool = PoolConfig(page_size=args.page_size, n_local_pages=64,
                          n_global_pages=16, max_pages_per_seq=16)
        econfig = EngineConfig(mb_size=args.mb_size,
                               num_microbatches=args.microbatches, pool=pool,
                               offload=True, backend=args.backend,
                               n_stages=args.stages, seed=args.seed,
                               prefill_chunk=args.prefill_chunk,
                               max_prefill_tokens_per_tick=args.max_prefill_tokens,
                               prefill_mode=args.prefill_mode,
                               fault_plan=fault_plan, transport=transport,
                               schedule=args.schedule,
                               wire_dtype=wire_dtype,
                               prefix_cache=args.prefix_cache, slo=slo,
                               trace=bool(args.trace) or None,
                               strict=args.strict or None)

    llm = LLM(cfg, config=econfig, params=params, rt=rt)
    engine = llm.engine
    if args.plan:
        print(f"planned: N_B={engine.num_microbatches} "
              f"mb_size={engine.mb_size} pool=(local={engine.pool.n_local_pages}, "
              f"global=2x{engine.pool.n_global_pages}) "
              f"util={engine.schedule_choice.utilisation:.2f}")
    print(f"prefill: {'chunked' if engine.chunked_prefill else 'exact'} "
          f"(chunk={engine.prefill_chunk} tokens, "
          f"budget={engine.max_prefill_tokens_per_tick} tokens/tick, "
          f"rows={engine.prefill_rows})")

    metrics = None
    if args.metrics:
        from repro.obs.metrics import Metrics, update_from_engine
        metrics = Metrics()
        _prev_snap: dict = {}

        def _metrics_banner() -> None:
            snap = update_from_engine(metrics, engine)
            d = Metrics.delta(_prev_snap, snap)
            _prev_snap.clear()
            _prev_snap.update(snap)
            print(f"[metrics] step={engine.stats.steps} "
                  f"tokens+={d.get('repro_tokens_total', 0.0):.0f} "
                  f"finished="
                  f"{snap.get('repro_requests_finished_total', 0.0):.0f}"
                  f"/{args.requests} "
                  f"queue={snap.get('repro_queue_depth', 0.0):.0f} "
                  f"decode tok/s="
                  f"{snap.get('repro_decode_tok_per_s', 0.0):.1f}")

    rng = np.random.RandomState(args.seed)
    system = list(rng.randint(1, cfg.vocab_size, args.system_prompt)) \
        if args.system_prompt > 0 else []
    prompts = [system + list(rng.randint(1, cfg.vocab_size,
                                         rng.randint(4, 24)))
               for _ in range(args.requests)]
    if args.mixed:
        policies = [SamplingParams(temperature=0.0),
                    SamplingParams(temperature=0.8),
                    SamplingParams(temperature=1.0, top_k=20),
                    SamplingParams(temperature=0.9, top_p=0.92)]
        sps = [_with_max_new(policies[i % len(policies)], args.max_new)
               for i in range(args.requests)]
    else:
        sps = SamplingParams(temperature=args.temperature,
                             max_new_tokens=args.max_new)

    if args.online:
        # Online serving drill: seeded Poisson arrivals submitted into a
        # LIVE loop — the engine keeps decoding earlier requests while new
        # ones are admitted; tokens stream out per tick.  Cooperative
        # pump (no thread) so the run is deterministic given the seed.
        from repro.serving.online import OnlineLLM
        online = OnlineLLM(llm=llm)
        gaps = rng.exponential(1.0 / args.arrival_rate,
                               size=args.requests)
        arrivals = np.cumsum(gaps)
        sps_list = sps if isinstance(sps, list) else \
            [sps] * args.requests
        streams = []
        nxt = 0
        _next_banner = [args.metrics_every]
        t0 = time.perf_counter()
        while True:
            now = time.perf_counter() - t0
            while nxt < args.requests and arrivals[nxt] <= now:
                streams.append(online.submit(prompts[nxt],
                                             sps_list[nxt]))
                nxt += 1
            busy = online.step()
            if metrics is not None and engine.stats.steps >= \
                    _next_banner[0]:
                _metrics_banner()
                _next_banner[0] = engine.stats.steps + args.metrics_every
            if not busy:
                if nxt >= args.requests:
                    break
                # engine idle before the next arrival: sleep up to it
                time.sleep(min(0.005, max(
                    0.0, arrivals[nxt] - (time.perf_counter() - t0))))
        outs = [s.result() for s in streams]

        def _pct(vals, q):
            return float(np.percentile(vals, q)) if vals else 0.0
        ttfts = [s.ttft_s for s in streams if s.ttft_s is not None]
        itls = [d for s in streams for d in s.inter_token_s()]
        print(f"online: {args.requests} requests over "
              f"{time.perf_counter() - t0:.2f}s (Poisson "
              f"{args.arrival_rate:.1f} req/s); "
              f"TTFT p50={_pct(ttfts, 50)*1e3:.1f}ms "
              f"p99={_pct(ttfts, 99)*1e3:.1f}ms; "
              f"ITL p50={_pct(itls, 50)*1e3:.1f}ms "
              f"p99={_pct(itls, 99)*1e3:.1f}ms")
    elif reshard_at or detect:
        step = 0
        resharded = False
        detector = None
        hb_t0 = time.monotonic()
        if detect:
            from repro.distributed.elastic import FailureDetector
            detector = FailureDetector(timeout=args.detect_failures)
            for d in range(args.stages):        # one device per stage
                detector.beat(d, 0.0)
        for outs in llm.generate_iter(prompts, sps):
            step += 1
            if metrics is not None and step % args.metrics_every == 0:
                _metrics_banner()
            if reshard_at and step == reshard_at:
                rplan = engine.reshard(n_stages=reshard_stages)
                resharded = True
                print(f"resharded at step {step}: {args.stages} -> "
                      f"{reshard_stages} stages "
                      f"(params_move={rplan['params_move']}, "
                      f"batch_reshard={rplan['batch_reshard']})")
            if detect:
                # the live loop: one heartbeat per stage per engine step;
                # a killed device goes silent and the detector — not a
                # drill flag — decides when to reshard and to how many
                # stages.  The default clock is wall (monotonic) seconds,
                # so --detect-failures is a real timeout; --heartbeat-clock
                # steps keeps the old step-index clock for deterministic
                # drills and tests.
                now = (float(step) if args.heartbeat_clock == "steps"
                       else time.monotonic() - hb_t0)
                for d in range(args.stages):
                    if d not in kills or step <= kills[d]:
                        detector.beat(d, now)
                dead = detector.dead(now)
                if dead and not resharded:
                    old = engine.n_stages
                    engine.reshard(detector=detector, now=now)
                    resharded = True
                    print(f"failure detected at step {step} (dead "
                          f"devices {dead}): resharded {old} -> "
                          f"{engine.n_stages} stage(s)")
        if reshard_at and not resharded:
            raise SystemExit(
                f"--reshard-at {args.reshard_at}: the workload finished "
                f"after {step} step(s), before step {reshard_at} — the "
                "drill never resharded; lower STEP or grow the workload")
        if detect and kills and not resharded:
            raise SystemExit(
                f"--detect-failures: the workload finished after {step} "
                "step(s) before any killed device missed its timeout — "
                "kill earlier, shorten the timeout, or grow the workload")
    elif metrics is not None:
        # the banner needs a live loop: step the same workload through
        # generate_iter (the final snapshot carries the request traces)
        step = 0
        for outs in llm.generate_iter(prompts, sps):
            step += 1
            if step % args.metrics_every == 0:
                _metrics_banner()
    else:
        outs = llm.generate(prompts, sps)
    rep = llm.stats()
    if fault_plan is not None:
        print(f"faults: {len(fault_plan.triggered)} triggered, "
              f"{fault_plan.pending()} never reached "
              f"(decode ticks lost {rep['decode_ticks_lost']}, "
              f"prefill chunks lost {rep['prefill_chunks_lost']}, "
              "all re-injected)")
    if "transport" in rep:
        t = rep["transport"]
        line = (f"transport: {t.get('transport')} "
                f"virtual_time={t.get('virtual_time_s', 0.0):.2f}s "
                f"virtual decode tok/s="
                f"{rep.get('virtual_decode_tok_per_s', 0.0):.1f} "
                f"wire={t.get('wire_bytes', 0)}B "
                f"link_stall={t.get('link_stall_s', 0.0):.2f}s")
        if "compression_ratio" in t:
            line += (f" (raw {t['raw_bytes']}B, "
                     f"{t['compression_ratio']:.1f}x on the wire)")
        print(line)
    done = [o for o in outs if o.finished]
    print(f"finished {len(done)}/{args.requests} requests in "
          f"{rep['wall_time_s']:.2f}s "
          f"({rep['decode_tok_per_s']:.1f} decode tok/s, "
          f"{rep['prefill_tok_per_s']:.1f} prefill tok/s on this host; "
          f"mean latency {rep['mean_latency_steps']:.1f} steps / "
          f"{rep['mean_latency_s']:.2f}s)")
    if args.prefix_cache:
        print(f"prefix cache: {rep.get('prefix_hits', 0)} hits, "
              f"{rep.get('prefix_hit_tokens', 0)} prompt tokens served "
              f"from shared blocks (hit rate "
              f"{rep.get('prefix_hit_rate', 0.0):.2f}, "
              f"{rep.get('prefix_cache_pages', 0)} pages retained)")
    reasons = {}
    for o in outs:
        reasons[o.finish_reason] = reasons.get(o.finish_reason, 0) + 1
    print(f"finish reasons: {reasons}")
    print(f"report: {rep}")
    if metrics is not None:
        _metrics_banner()
        print("metrics (Prometheus exposition):")
        print(metrics.prometheus_text(), end="")
    if args.trace:
        from repro.obs.timeline import write_chrome_trace
        trace = write_chrome_trace(engine.recorder, args.trace)
        od = trace["otherData"]
        print(f"trace: wrote {len(trace['traceEvents'])} timeline events "
              f"({od['recorder_events']} recorded, "
              f"{od['recorder_dropped']} dropped) to {args.trace} — open "
              "in https://ui.perfetto.dev")

    n_b = optimal_microbatches(8, 0.08, args.latency)
    print(f"\nschedule report (8-stage pipeline, T_S=80ms, "
          f"L={args.latency*1000:.0f}ms): N_B* = {n_b}")
    for name in ("mining", "ionet", "cloud"):
        p = PLATFORMS[name]
        print(f"  {name:8s} break-even {min_throughput(p.cost_per_hour):8.1f}"
              f" tok/s; at 450 tok/s profit/h = "
              f"${profit_per_hour(450, p.cost_per_hour):+.2f}")


if __name__ == "__main__":
    main()
