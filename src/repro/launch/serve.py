"""Offline serving driver: DeServe engine on the local device.

Runs the full serving stack end-to-end on a *reduced* config (CPU-sized) or
any registered arch: paged KV cache with local+global pools, double-buffer
offloading, microbatch round-robin, continuous batching, and the §3 profit
accounting on the measured throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 16 \\
      --microbatches 2 --mb-size 2 --max-new 24 [--full-size]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced_config
from repro.core.cost_model import PLATFORMS, min_throughput, profit_per_hour
from repro.core.offload import DoubleBufferOffloader
from repro.core.scheduler import optimal_microbatches
from repro.models import model as model_lib
from repro.models.common import Runtime
from repro.serving.engine import OfflineEngine
from repro.serving.kv_cache import PoolConfig
from repro.serving.request import Request, SamplingParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--mb-size", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (needs real accelerators)")
    ap.add_argument("--latency", type=float, default=0.064,
                    help="assumed link latency for the schedule report")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = reduced_config(cfg)
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    print(f"arch={cfg.name} layers={cfg.num_layers} d_model={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M")

    params = model_lib.init_params(cfg, jax.random.PRNGKey(args.seed), rt)
    pool = PoolConfig(page_size=args.page_size, n_local_pages=64,
                      n_global_pages=16, max_pages_per_seq=16)
    off = DoubleBufferOffloader(pool, num_microbatches=args.microbatches)
    sp = SamplingParams(temperature=args.temperature,
                        max_new_tokens=args.max_new)
    engine = OfflineEngine(cfg, params, rt, mb_size=args.mb_size,
                           num_microbatches=args.microbatches, pool=pool,
                           sampling=sp, offloader=off, seed=args.seed)

    rng = np.random.RandomState(args.seed)
    reqs = [Request(i, list(rng.randint(1, cfg.vocab_size,
                                        rng.randint(4, 24))), sp)
            for i in range(args.requests)]
    engine.submit(reqs)

    t0 = time.perf_counter()
    done = engine.run(max_steps=100_000)
    dt = time.perf_counter() - t0
    rep = engine.throughput_report()
    tps = rep["total_tokens"] / dt
    print(f"finished {len(done)}/{args.requests} requests in {dt:.2f}s "
          f"({tps:.1f} tok/s on this host)")
    print(f"report: {rep}")

    n_b = optimal_microbatches(8, 0.08, args.latency)
    print(f"\nschedule report (8-stage pipeline, T_S=80ms, "
          f"L={args.latency*1000:.0f}ms): N_B* = {n_b}")
    for name in ("mining", "ionet", "cloud"):
        p = PLATFORMS[name]
        print(f"  {name:8s} break-even {min_throughput(p.cost_per_hour):8.1f}"
              f" tok/s; at 450 tok/s profit/h = "
              f"${profit_per_hour(450, p.cost_per_hour):+.2f}")


if __name__ == "__main__":
    main()
