import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ must precede every other import: jax locks the device count at first init
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds ShapeDtypeStruct stand-ins (weak-type-correct,
sharded, zero allocation) for params / optimizer state / caches / batch,
jits the right step function against the production mesh, runs
``.lower().compile()``, and records:

  * ``memory_analysis()``  — per-device bytes (proves it fits 16 GB v5e HBM)
  * ``cost_analysis()``    — per-device HLO FLOPs / bytes for §Roofline
  * collective bytes       — parsed from the post-SPMD HLO text, per
                             collective kind, wire-byte convention:
                             all-gather/reduce-scatter (g−1)/g·size,
                             all-reduce 2(g−1)/g·size, all-to-all
                             (g−1)/g·size, collective-permute size.

Shape kinds map to programs:  train_* → ``train_step`` (loss+grads+AdamW);
prefill_* → ``prefill``; decode_* / long_* → ``serve_step`` (one token
against a seq_len KV cache).  On the multi-pod mesh, serving programs run
the DeServe pipeline (pod = stage axis); training folds pod into DP.

Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json``;
``benchmarks.bench_roofline`` turns them into the roofline table.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape decode_32k --mesh multi
  python -m repro.launch.dryrun --all [--timeout 900]
"""

import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import MULTI_POD, SHAPES, SINGLE_POD, get_arch, list_archs
from repro.core import pipeline as pipe_lib
from repro.distributed import sharding as shard_lib
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.models.common import Runtime
from repro.training import optimizer as opt_lib
from repro.training.train_loop import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# TPU v5e hardware constants (per chip) for §Roofline
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


# --variant transforms for SPerf hillclimb iterations; "" = baseline
VARIANTS = {
    "": {},
    "zero3": dict(train_style="zero3", sequence_parallel=False),
    "blockpair": dict(causal_scheme="blockpair"),
    "int8kv": dict(kv_dtype="int8"),
    "nb8": dict(),                       # serve: 8 microbatches (see below)
    "nb8_int8": dict(kv_dtype="int8"),   # combined serve hillclimb
    "zero3_accum2": dict(train_style="zero3", sequence_parallel=False),
    "zero3_blockpair": dict(train_style="zero3", sequence_parallel=False,
                            causal_scheme="blockpair"),
    "rounds8": dict(kv_dtype="int8"),    # multi-round circular decode, R=8
    # the beyond-paper optimized configuration (per shape kind):
    #   train -> ZeRO-3 weight-gathered DP over all 256 intra-pod chips
    #   serve -> int8 KV cache + 8 in-flight microbatches on the pipeline
    "opt": dict(),
}


def runtime_for(kind: str, variant: str = "") -> Runtime:
    rt = Runtime(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
                 remat=(kind == "train"), vocab_chunk=512,
                 sequence_parallel=(kind == "train"),
                 moe_chunk=65536,
                 q_chunk=512, kv_chunk=512)
    kw = dict(VARIANTS[variant])
    if variant == "opt":
        kw = (dict(train_style="zero3", sequence_parallel=False)
              if kind == "train" else dict(kv_dtype="int8"))
        kw["causal_scheme"] = "blockpair"    # exact causal FLOPs (SPerf D)
    kw = {k: v for k, v in kw.items() if hasattr(rt, k)}
    return rt.replace(**kw) if kw else rt


def serve_pipeline_config(shape, n_stages: int = 2, variant: str = ""):
    gb = shape.global_batch
    cap = 8 if variant in ("nb8", "nb8_int8", "opt") else 4
    n_mb = min(cap, gb) if gb >= n_stages else 1
    while gb % n_mb:
        n_mb -= 1
    # prefer a 16-divisible microbatch so activations shard over "data"
    # (a replicated (mb, 32k, D) prefill queue is GBs per chip)
    while n_mb > 1 and (gb // n_mb) % 16 != 0 and gb % (n_mb - 1) == 0:
        n_mb -= 1
    if (gb // n_mb) % 16 != 0 and gb >= 16 * n_stages:
        n_mb = gb // 16
    return pipe_lib.PipelineConfig(n_stages=n_stages, n_microbatches=n_mb,
                                   mb_size=gb // n_mb)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct construction
# ---------------------------------------------------------------------------


def _sds(tree, specs, mesh):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)


def batch_inputs(cfg, shape, *, include_labels: bool):
    """Abstract input dict for one arch × shape."""
    B, S = shape.global_batch, shape.seq_len
    out = {}
    if cfg.frontend == "audio_frames":
        out["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                             jnp.bfloat16)
        label_len = S
    elif cfg.frontend == "vision_patches":
        Pk = cfg.num_patch_tokens
        st = max(8, S - Pk)
        out["tokens"] = jax.ShapeDtypeStruct((B, st), jnp.int32)
        out["patches"] = jax.ShapeDtypeStruct((B, Pk, cfg.d_model),
                                              jnp.bfloat16)
        label_len = st
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        label_len = S
    if include_labels:
        out["labels"] = jax.ShapeDtypeStruct((B, label_len), jnp.int32)
    return out


def build_cell(arch: str, shape_name: str, mesh_name: str,
               variant: str = ""):
    """Returns (fn, args_sds, meta) ready for jit(...).lower(*args)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    multi = mesh_name == "multi_pod"
    mesh = make_production_mesh(multi_pod=multi)
    rt = runtime_for(shape.kind, variant)

    if shape.kind == "train":
        # bf16 moments for the MoE giants (918M params/chip at 256 chips —
        # fp32 moments alone are 7.3 GB); dense archs keep fp32
        ocfg = opt_lib.AdamWConfig(
            moment_dtype=jnp.bfloat16 if cfg.moe is not None
            else jnp.float32)
        params = jax.eval_shape(
            lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0), rt))
        opt_state = jax.eval_shape(lambda: opt_lib.init(ocfg, params))
        # gradient accumulation for the MoE giants: global batch unchanged,
        # per-microbatch activation/dispatch state 4x smaller
        accum = 4 if cfg.moe is not None else 1
        if variant == "zero3_accum2":
            accum = max(accum, 2)
        sub = dataclasses.replace(shape, global_batch=shape.global_batch
                                  // accum)
        batch = batch_inputs(cfg, sub, include_labels=True)
        bspecs = shard_lib.batch_specs(batch, mesh)
        if accum > 1:
            batch = {k: jax.ShapeDtypeStruct((accum,) + v.shape, v.dtype)
                     for k, v in batch.items()}
            bspecs = {k: P(*((None,) + tuple(sp)))
                      for k, sp in bspecs.items()}
        pspecs = shard_lib.param_specs(params, cfg, mesh, fsdp=True)
        ospecs = shard_lib.opt_state_specs(pspecs)
        step = make_train_step(cfg, rt, ocfg, accum_steps=accum)
        args = (_sds(params, pspecs, mesh), _sds(opt_state, ospecs, mesh),
                _sds(batch, bspecs, mesh))
        donate = (0, 1)
        return step, args, mesh, donate

    capacity = shape.seq_len
    # single-pod serving of the giants: TP-only weights exceed HBM
    # (qwen3-moe: 235e9*2/16 = 29 GB/chip), so shard the second weight dim
    # over "data" too and let XLA gather per layer — the roofline then shows
    # the collective cost, which is precisely the paper's argument for
    # pipelining across pods instead.
    serve_2d = cfg.param_count() * 2 / 16 > 10e9
    if shape.kind == "prefill":
        if not multi:
            params = jax.eval_shape(
                lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0), rt))
            inputs = batch_inputs(cfg, shape, include_labels=False)
            pspecs = shard_lib.param_specs(params, cfg, mesh, fsdp=serve_2d)
            bspecs = shard_lib.batch_specs(inputs, mesh)
            fn = lambda p, b: model_lib.prefill(p, b, cfg, rt, capacity)
            args = (_sds(params, pspecs, mesh), _sds(inputs, bspecs, mesh))
            return fn, args, mesh, ()
        pcfg = serve_pipeline_config(shape, variant=variant)
        params = jax.eval_shape(
            lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0), rt))
        flat_inputs = batch_inputs(cfg, shape, include_labels=False)
        inputs = {k: jax.ShapeDtypeStruct(
            (pcfg.n_microbatches, pcfg.mb_size) + v.shape[1:], v.dtype)
            for k, v in flat_inputs.items()}
        caches = jax.eval_shape(
            lambda: pipe_lib.init_pipeline_caches(cfg, pcfg, capacity, rt))
        pspecs = shard_lib.param_specs(params, cfg, mesh, fsdp=False)
        cspecs = shard_lib.cache_specs(caches, cfg, mesh, pipeline=True)
        ispecs = {k: P(None, "data", *([None] * (v.ndim - 2)))
                  if pcfg.mb_size % 16 == 0 else
                  P(*([None] * v.ndim)) for k, v in inputs.items()}
        fn = lambda p, b, c: pipe_lib.pipeline_prefill(p, b, c, cfg, rt, pcfg)
        args = (_sds(params, pspecs, mesh), _sds(inputs, ispecs, mesh),
                _sds(caches, cspecs, mesh))
        return fn, args, mesh, (2,)

    # decode / long-context decode: serve_step
    B = shape.global_batch
    params = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0), rt))
    pspecs = shard_lib.param_specs(params, cfg, mesh,
                                   fsdp=serve_2d and not multi)
    if not multi:
        caches = jax.eval_shape(
            lambda: model_lib.init_caches(cfg, B, capacity, rt))
        cspecs = shard_lib.cache_specs(caches, cfg, mesh)
        tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
        cur = jax.ShapeDtypeStruct((B,), jnp.int32)
        tspec = P("data") if B % 16 == 0 else P(None)
        fn = lambda p, t, c, cp: model_lib.decode_step(p, t, c, cp, cfg, rt)
        args = (_sds(params, pspecs, mesh),
                jax.ShapeDtypeStruct(tokens.shape, tokens.dtype,
                                     sharding=NamedSharding(mesh, tspec)),
                _sds(caches, cspecs, mesh),
                jax.ShapeDtypeStruct(cur.shape, cur.dtype,
                                     sharding=NamedSharding(mesh, tspec)))
        return fn, args, mesh, (2,)
    pcfg = serve_pipeline_config(shape, variant=variant)
    caches = jax.eval_shape(
        lambda: pipe_lib.init_pipeline_caches(cfg, pcfg, capacity, rt))
    cspecs = shard_lib.cache_specs(caches, cfg, mesh, pipeline=True)
    tspec = P(None, "data") if pcfg.mb_size % 16 == 0 else P(None, None)
    tok = jax.ShapeDtypeStruct((pcfg.n_microbatches, pcfg.mb_size), jnp.int32,
                               sharding=NamedSharding(mesh, tspec))
    cur = jax.ShapeDtypeStruct((pcfg.n_microbatches, pcfg.mb_size), jnp.int32,
                               sharding=NamedSharding(mesh, tspec))
    if variant == "rounds8" and pcfg.n_microbatches >= 2:
        fn = lambda p, t, c, cp: pipe_lib.pipeline_decode_rounds(
            p, t, c, cp, cfg, rt, pcfg, rounds=8)
    else:
        fn = lambda p, t, c, cp: pipe_lib.pipeline_decode_step(p, t, c, cp,
                                                               cfg, rt, pcfg)
    args = (_sds(params, pspecs, mesh), tok, _sds(caches, cspecs, mesh), cur)
    return fn, args, mesh, (2,)


# ---------------------------------------------------------------------------
# Collective parsing
# ---------------------------------------------------------------------------


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes per collective kind, from post-SPMD HLO."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in DTYPE_BYTES:
            continue
        size = DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                size *= int(d)
        g = _group_size(line)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            wire = 2.0 * frac * size
        elif kind == "collective-permute":
            wire = float(size)
        else:
            wire = frac * size
        out[kind] += wire
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    if _PAIRS_RE.search(line):
        return 2
    return 1


def pod_boundary_bytes(hlo_text: str, n_devices: int) -> float:
    """Bytes crossing the pod (slow-link) boundary: collective-permutes whose
    source/target differ by half the device count (the pod stride), plus
    any collective whose replica group spans both pods."""
    half = n_devices // 2
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in DTYPE_BYTES:
            continue
        size = DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                size *= int(d)
        if kind == "collective-permute":
            pairs = re.search(r"source_target_pairs=\{([^}]*)\}", line)
            if pairs:
                cross = re.findall(r"\{(\d+),(\d+)\}", "{" + pairs.group(1) + "}")
                if any(abs(int(a) - int(b)) >= half for a, b in cross):
                    total += size
        else:
            m2 = _GROUPS_RE.search(line)
            if m2 and int(m2.group(2)) > half:
                total += size
    return total


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: Optional[str] = None, variant: str = "") -> dict:
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "ok": False, "skipped": False}
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.is_subquadratic():
        rec.update(skipped=True, ok=True,
                   reason="pure full-attention arch: 500k KV decode is "
                          "intentionally out of scope (see DESIGN.md)")
        _write(rec, out_dir)
        return rec
    t0 = time.time()
    try:
        fn, args, mesh, donate = build_cell(arch, shape_name, mesh_name,
                                            variant)
        with mesh:
            jitted = jax.jit(fn, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):   # older jax: list per device
                ca = ca[0] if ca else {}
            txt = compiled.as_text()
        n_dev = mesh.devices.size
        coll = collective_bytes(txt)
        rec.update(
            ok=True,
            n_devices=int(n_dev),
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            flops_per_device=float(ca.get("flops", 0.0)),
            bytes_per_device=float(ca.get("bytes accessed", 0.0)),
            memory={
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "code_bytes": int(ma.generated_code_size_in_bytes),
                "peak_per_device": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
            },
            collectives=coll,
            kv_dtype=runtime_for(shape.kind, rec.get("variant", "")).kv_dtype,
            pod_boundary_bytes=float(
                pod_boundary_bytes(txt, n_dev)) if mesh_name == "multi_pod"
            else 0.0,
            tokens_per_step=shape.tokens_per_step * (
                8 if rec.get("variant") == "rounds8" and
                shape.kind == "decode" else 1),
        )
        rec["roofline"] = roofline_terms(rec)
    except Exception as e:  # noqa: BLE001 — every failure is a bug report
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    _write(rec, out_dir)
    return rec


def roofline_terms(rec: dict) -> dict:
    """compute/memory/collective terms (seconds) per §ROOFLINE."""
    flops = rec["flops_per_device"]
    byts = rec["bytes_per_device"]
    coll = rec["collectives"]["total"]
    terms = {
        "compute_s": flops / HW["peak_flops"],
        "memory_s": byts / HW["hbm_bw"],
        "collective_s": coll / HW["ici_bw"],
    }
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["bound_step_s"] = total
    terms["compute_fraction_of_bound"] = (
        terms["compute_s"] / total if total > 0 else 0.0)
    return terms


def _write(rec: dict, out_dir: Optional[str]) -> None:
    d = out_dir or OUT_DIR
    os.makedirs(d, exist_ok=True)
    v = f"__{rec['variant']}" if rec.get("variant") else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{v}.json"
    with open(os.path.join(d, name), "w") as f:
        json.dump(rec, f, indent=1)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--timeout", type=int, default=1200)
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="", choices=sorted(VARIANTS))
    args = ap.parse_args()

    if args.all:
        return _run_all(args)

    mesh_name = "multi_pod" if args.mesh == "multi" else "single_pod"
    rec = run_cell(args.arch, args.shape, mesh_name, args.out, args.variant)
    dump = {k: v for k, v in rec.items() if k != "traceback"}
    print(json.dumps(dump, indent=1))
    return 0 if rec["ok"] else 1


def _run_all(args) -> int:
    archs = [a for a in list_archs() if a != "llama3-70b"] + ["llama3-70b"]
    failures = []
    for mesh in ("single", "multi"):
        for arch in archs:
            for shape in SHAPES:
                mesh_name = "multi_pod" if mesh == "multi" else "single_pod"
                out = args.out or OUT_DIR
                v = f"__{args.variant}" if args.variant else ""
                path = os.path.join(out,
                                    f"{arch}__{shape}__{mesh_name}{v}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh]
                if args.variant:
                    cmd += ["--variant", args.variant]
                if args.out:
                    cmd += ["--out", args.out]
                t0 = time.time()
                try:
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=args.timeout)
                    ok = r.returncode == 0
                except subprocess.TimeoutExpired:
                    ok = False
                    _write({"arch": arch, "shape": shape, "mesh": mesh_name,
                            "ok": False, "skipped": False,
                            "error": f"compile timeout > {args.timeout}s"},
                           args.out)
                status = "ok" if ok else "FAIL"
                print(f"[{status}] {arch} × {shape} × {mesh} "
                      f"({time.time()-t0:.0f}s)", flush=True)
                if not ok:
                    failures.append((arch, shape, mesh))
    if failures:
        print(f"{len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
