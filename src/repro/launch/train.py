"""Training driver: train a reduced (or full, on real hardware) arch on the
synthetic pipeline with checkpoint/restart fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch minitron-4b \\
      --steps 200 --batch 8 --seq 64 [--resume] [--compress int8]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.config import get_arch, reduced_config
from repro.data.pipeline import DataConfig, batches
from repro.distributed.compression import Compressor
from repro.models import model as model_lib
from repro.models.common import Runtime
from repro.training import optimizer as opt_lib
from repro.training import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress", choices=["none", "int8", "topk"],
                    default="none")
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = reduced_config(cfg)
    rt = Runtime(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    ocfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps
                                                            // 20),
                               total_steps=args.steps)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_size=args.batch, accum_steps=args.accum,
                      seed=args.seed)
    data = batches(dcfg)

    params = model_lib.init_params(cfg, jax.random.PRNGKey(args.seed), rt)
    opt_state = opt_lib.init(ocfg, params)
    mgr = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name), keep=3)
    if args.resume and mgr.latest_step() is not None:
        (restored, _) = mgr.restore({"params": params,
                                     "opt_state": opt_state})
        params, opt_state = restored["params"], restored["opt_state"]
        print(f"resumed from step {int(opt_state.step)}")

    comp = None
    if args.compress != "none":
        comp = Compressor(method=args.compress)

    params, opt_state, res = train_loop.train(
        cfg, rt, ocfg, data, steps=args.steps, params=params,
        opt_state=opt_state, accum_steps=args.accum, compressor=comp,
        checkpoint_mgr=mgr, checkpoint_every=args.ckpt_every,
        log_every=args.log_every)
    print(f"done: loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}, "
          f"{res.tokens_per_second:.0f} tok/s")
    mgr.save(int(opt_state.step), {"params": params, "opt_state": opt_state},
             {"final_loss": res.losses[-1]})


if __name__ == "__main__":
    main()
