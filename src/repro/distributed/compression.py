"""Gradient compression for high-latency data parallelism (beyond-paper).

DeServe's decentralized substrate makes DP training across pods painful:
an all-reduce of full bf16 gradients over ~50 ms links dominates step time.
Two standard compressors, both with error feedback so compression noise is
O(1) over training rather than O(steps):

  * int8 — per-tensor symmetric quantization (4x over bf16 wire bytes, 2x
    over fp32 accumulators).
  * top-k — magnitude sparsification to fraction ``k`` (wire bytes ≈
    k·(4+4) of values+indices) with residual accumulation.

``roundtrip`` = compress → (wire) → decompress, which is exactly what the
train step applies before the optimizer; on a real deployment the compressed
representation is what crosses the pod axis (the all-reduce then runs on the
quantized payloads).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp


def int8_compress(x: jax.Array):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_compress_rows(x: jax.Array):
    """Per-row symmetric int8 quantization — the in-jit wire codec.

    One f32 scale per row of the trailing axis travels with the payload
    (``pipeline_decode_tick`` / ``pipeline_prefill_chunk_tick`` ppermute
    both).  Non-finite inputs are clamped first so a single NaN/inf row
    cannot poison the scale and the round trip stays finite everywhere.
    """
    # cap below float32 max: 127 * (amax/127) can round one ulp past
    # amax, so amax = finfo.max would decompress to inf
    xf = jnp.nan_to_num(x.astype(jnp.float32), posinf=3.0e38,
                        neginf=-3.0e38)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress_rows(q: jax.Array, scale: jax.Array,
                         dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_wire_bytes(n_elems: int, n_rows: int) -> int:
    """Bytes of the packed per-row payload: 1 B/element + one f32 scale
    per row.  This is the *actual* on-wire size of what the pipeline jits
    ship — ``CompressedTransport`` prices with the same formula so
    accounting and reality agree."""
    return int(n_elems) + 4 * int(n_rows)


def topk_compress(x: jax.Array, frac: float):
    xf = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(xf.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(xf), k)
    sel = xf[idx]
    return sel, idx, x.shape


def topk_decompress(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    out = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    out = out.at[idx].set(vals)
    return out.reshape(shape)


@dataclass
class Compressor:
    """Error-feedback compressor over gradient pytrees."""
    method: str = "int8"              # int8 | topk | none
    topk_frac: float = 0.01
    error_feedback: bool = True
    _residual: Any = None

    def wire_bytes(self, grads) -> int:
        total = 0
        for leaf in jax.tree.leaves(grads):
            n = leaf.size
            if self.method == "int8":
                total += n + 4
            elif self.method == "topk":
                k = max(1, int(n * self.topk_frac))
                total += k * 8
            else:
                total += n * leaf.dtype.itemsize
        return total

    def roundtrip(self, grads):
        if self.method == "none":
            return grads
        if self._residual is None and self.error_feedback:
            self._residual = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        def one(g, r):
            gf = g.astype(jnp.float32) + (r if r is not None else 0.0)
            if self.method == "int8":
                q, s = int8_compress(gf)
                out = int8_decompress(q, s)
            else:
                vals, idx, shape = topk_compress(gf, self.topk_frac)
                out = topk_decompress(vals, idx, shape)
            new_r = gf - out
            return out.astype(g.dtype), new_r

        if self.error_feedback:
            pairs = jax.tree.map(one, grads, self._residual)
            out = jax.tree.map(lambda t: t[0], pairs,
                               is_leaf=lambda t: isinstance(t, tuple))
            self._residual = jax.tree.map(
                lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
            return out
        return jax.tree.map(lambda g: one(g, None)[0], grads)

    def compression_ratio(self, grads) -> float:
        raw = sum(l.size * 4 for l in jax.tree.leaves(grads))
        return raw / max(self.wire_bytes(grads), 1)
