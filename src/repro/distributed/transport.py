"""Networked stage transport: the per-link seam between pipeline stages.

DeServe's headline claim (6.7x–12.6x over baselines *in high-latency
networks*) lives or dies on what happens at the stage boundary: every
engine tick, the shift-register entries of both planes — decode
microbatches and prefill chunks — cross one inter-stage link each.  The
real ``PipelinedBackend`` runs all stages inside one ``shard_map`` with
zero-latency boundaries, so this module makes the link a first-class,
*pluggable* object:

``InProcessTransport``
    Today's zero-copy behaviour: activations move through ``ppermute``
    inside the jit, the link costs nothing, no clock is kept.

``SimulatedLinkTransport``
    Per-link one-way latency + bandwidth + deterministic jitter applied
    to the activation payload crossing each boundary, accounted on a
    **virtual clock** — the computation is untouched (outputs stay
    bit-identical to ``InProcessTransport``), but every stage carries a
    virtual timeline: a stage's tick starts when both its previous tick
    finished *and* its input activation arrived over the link.  Tests
    and the ``latency_curve`` benchmark read throughput off this clock,
    so a 64 ms WAN run finishes in CPU-milliseconds of wall time.

``CompressedTransport``
    Wire-byte pricing for activation compression: wraps another
    transport and re-prices each payload through the int8 / top-k codecs
    of :mod:`repro.distributed.compression` before the link sees it.
    Under ``EngineConfig(wire_dtype="int8")`` the jits really do ship
    the per-row packed int8 payload and the backend wraps its transport
    here so the books match the wire exactly; without the in-jit codec
    (or with top-k, which has no in-jit path) it is what-if accounting.

``DeploymentPlan``
    Registry-driven deployment: turns a ``framework.registry.match``
    result (stage→machine assignment + the pairwise region latency
    matrix) into per-link ``LinkSpec``s, a ready-made transport, and the
    planner input (``max_link_latency``) that ``EngineConfig.plan``
    consumes instead of a scalar ``--latency`` guess.

The timing model mirrors §4.3's ring: stage ``s`` sends its output over
link ``s → (s+1) mod N_S`` after each tick; the last link doubles as the
paper's *return* link — a drained microbatch's token ids must travel it
before the engine can re-inject that microbatch, which is exactly the
dependency that makes the round-flush schedule pay ``(N_S+N_B−1)(T_S+L)``
per token round while the circular schedule hides the latency entirely
once ``N_B ≥ N_S·(T_S+L)/T_S``.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Links and the virtual clock
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkSpec:
    """One directed inter-stage link: fixed one-way latency plus a
    bandwidth term per payload byte plus optional uniform jitter."""
    latency_s: float = 0.0
    bandwidth_bps: float = 0.0        # bytes/second; 0 = infinite
    jitter_s: float = 0.0             # max extra delay, drawn per send

    def __post_init__(self):
        if self.latency_s < 0 or self.bandwidth_bps < 0 or self.jitter_s < 0:
            raise ValueError(f"link parameters must be >= 0, got {self}")

    def delay(self, nbytes: int, rng: Optional[np.random.RandomState] = None
              ) -> float:
        d = self.latency_s
        if self.bandwidth_bps:
            d += nbytes / self.bandwidth_bps
        if self.jitter_s and rng is not None:
            d += float(rng.uniform(0.0, self.jitter_s))
        return d


class VirtualClock:
    """Monotonic simulated time — advanced by transport ticks, never by
    wall time, so WAN-scale latencies cost nothing to test."""

    def __init__(self):
        self.now = 0.0

    def advance_to(self, t: float) -> None:
        if t > self.now:
            self.now = t


@dataclass
class TickObs:
    """What one transport tick observed: per-stage link-induced stall
    seconds (feeds :class:`~repro.distributed.elastic.StragglerMitigator`
    through ``drain_stage_times``), the virtual completion time of the
    draining stage (0.0 when the last stage was a bubble), and the
    virtual time at which the drained payload's *return* trip lands back
    at the injector (the engine keys re-injection readiness off it)."""
    stalls: np.ndarray
    drain_done: float = 0.0
    return_ready: float = 0.0


# ---------------------------------------------------------------------------
# Transport interface
# ---------------------------------------------------------------------------


class Transport(abc.ABC):
    """Inter-stage link seam.  One instance serves one backend; ``tick``
    is called once per plane tick (decode and prefill both) with the
    stages' occupancy and the payload size crossing each boundary."""

    name: str = "abstract"
    recorder = None         # repro.obs.trace.TraceRecorder, or None

    def set_recorder(self, recorder) -> "Transport":
        """Attach a flight recorder (``repro.obs.trace.TraceRecorder``).
        Recording transports log link sends and per-tick stalls at the
        exact sites where their books accumulate, so the recorded
        ledger reconciles bitwise with ``stats()``.  The no-op paths
        keep the reference but record nothing (no books, no clock)."""
        self.recorder = recorder
        return self

    @abc.abstractmethod
    def bind(self, n_stages: int) -> "Transport":
        """Attach to a pipe of ``n_stages`` stages (validates link count,
        sizes the timelines).  Returns self for chaining."""

    @abc.abstractmethod
    def tick(self, occupied: Sequence[bool], nbytes: int,
             compute_s: Sequence[float], inject_t: float = 0.0,
             plane: str = "decode") -> TickObs:
        """Account one pipe tick.  ``occupied[s]`` — stage ``s`` held a
        real entry (bubbles neither compute nor send); ``nbytes`` — the
        activation payload each occupied stage ships downstream;
        ``compute_s[s]`` — stage compute seconds this tick;
        ``inject_t`` — earliest virtual time the entry injected at stage
        0 was available (its previous drain's return arrival);
        ``plane`` — which shift register is advancing ("decode" /
        "prefill"): the stage timelines are shared (one device per
        stage), but in-flight messages are per plane."""

    def for_stages(self, n_stages: int) -> "Transport":
        """A transport for a resized pipe (reshard): same link policy,
        fresh timelines.  Default: rebind in place."""
        return self.bind(n_stages)

    def stats(self) -> Dict:
        """Accounting snapshot for reports (empty on the no-op path)."""
        return {}

    def audit(self) -> None:
        """Strict-mode hook (``EngineConfig(strict=True)``): raise
        ``AssertionError`` if the transport's internal books are
        inconsistent.  The no-op paths keep no books to check."""


class InProcessTransport(Transport):
    """Zero-cost links — the single-process shard_map behaviour.  Keeps
    no clock; ``tick`` returns all-zero observations."""

    name = "inprocess"

    def __init__(self):
        self._zeros = np.zeros((0,))

    def bind(self, n_stages: int) -> "InProcessTransport":
        self._zeros = np.zeros((n_stages,))
        return self

    def tick(self, occupied, nbytes, compute_s, inject_t=0.0,
             plane="decode") -> TickObs:
        return TickObs(stalls=self._zeros)


class SimulatedLinkTransport(Transport):
    """Per-link simulated WAN on a virtual clock.

    Each stage keeps a virtual timeline: its tick starts at
    ``max(previous tick done, input arrival)`` and runs for the stage
    compute time (``stage_time_s`` when set — deterministic benchmarks —
    else the measured per-stage share the backend passes in).  Occupied
    stages then ship ``nbytes`` over their downstream link; the arrival
    constrains the receiver's *next* tick.  Stage 0's input comes from
    the engine (``inject_t``), not the ring — the last link instead
    prices the drained payload's return trip (``TickObs.return_ready``),
    which is the §4.3 re-injection dependency.
    """

    name = "simulated"

    def __init__(self, links: Sequence[LinkSpec], *,
                 stage_time_s: Optional[float] = None, seed: int = 0,
                 return_bytes: int = 64):
        self.links: List[LinkSpec] = list(links)
        if not self.links:
            raise ValueError("SimulatedLinkTransport needs >= 1 link")
        self.stage_time_s = stage_time_s
        self.seed = seed
        self.return_bytes = return_bytes  # token ids, not activations
        self.clock = VirtualClock()
        self._rng = np.random.RandomState(seed)
        self._jittery = any(l.jitter_s for l in self.links)
        self._done: Optional[np.ndarray] = None     # per-stage tick-done t
                                                    # (shared: one device
                                                    # serves both planes)
        self._arrival: Dict[str, np.ndarray] = {}   # plane -> next input
                                                    # arrival per stage
                                                    # (in-flight messages
                                                    # are per plane)
        self.wire_bytes = 0
        self.sends = 0
        self.stall_s = 0.0

    @classmethod
    def uniform(cls, n_stages: int, latency_s: float, *,
                bandwidth_bps: float = 0.0, jitter_s: float = 0.0,
                **kw) -> "SimulatedLinkTransport":
        return cls([LinkSpec(latency_s, bandwidth_bps, jitter_s)
                    for _ in range(n_stages)], **kw).bind(n_stages)

    def bind(self, n_stages: int) -> "SimulatedLinkTransport":
        if len(self.links) != n_stages:
            raise ValueError(
                f"transport has {len(self.links)} link(s) but the pipe has "
                f"{n_stages} stage(s) — a ring needs one link per stage "
                "(use for_stages() to retarget after a reshard)")
        if self._done is None or self._done.shape[0] != n_stages:
            self._done = np.zeros((n_stages,))
            self._arrival = {}
        return self

    def for_stages(self, n_stages: int) -> "SimulatedLinkTransport":
        if n_stages == len(self.links):
            links = self.links
        else:
            # a reshard changed the ring size: keep the conservative
            # envelope — every link as slow as the slowest old one
            worst = max(self.links, key=lambda l: l.latency_s)
            links = [worst] * n_stages
        fresh = SimulatedLinkTransport(
            links, stage_time_s=self.stage_time_s, seed=self.seed,
            return_bytes=self.return_bytes).bind(n_stages)
        # accounting continuity across the rebuild (the recorder rides
        # along: a reshard must not cut the flight recording)
        fresh.clock.now = self.clock.now
        fresh.wire_bytes, fresh.sends = self.wire_bytes, self.sends
        fresh.stall_s = self.stall_s
        fresh.recorder = self.recorder
        return fresh

    def tick(self, occupied, nbytes, compute_s, inject_t=0.0,
             plane="decode") -> TickObs:
        n = len(self.links)
        assert self._done is not None, "tick() before bind()"
        rec = self.recorder
        occ = np.asarray(occupied, bool)
        stalls = np.zeros((n,))
        done = self._done
        arr = self._arrival.get(plane)
        arr = np.zeros((n,)) if arr is None else arr.copy()
        if occ[0]:
            arr[0] = max(arr[0], inject_t)
        new_arrival = np.zeros((n,))
        rng = self._rng if self._jittery else None
        for s in range(n):
            if not occ[s]:
                continue
            ts = self.stage_time_s if self.stage_time_s is not None \
                else float(compute_s[s])
            start = max(done[s], arr[s])
            stalls[s] = max(0.0, arr[s] - done[s])
            done[s] = start + ts
            if rec is not None:
                rec.stage_busy(plane, s, float(start), float(done[s]))
            if s != n - 1:                  # ship downstream for next tick
                t_arr = done[s] + self.links[s].delay(nbytes, rng)
                new_arrival[s + 1] = t_arr
                self.wire_bytes += nbytes
                self.sends += 1
                if rec is not None:         # the ledger event: exactly the
                    rec.link_send(plane, s, nbytes,  # bytes booked above
                                  float(done[s]), float(t_arr))
        # stage 0's next input comes from the engine, so the ring's last
        # link carries the drained *return* payload instead
        drain_done = float(done[n - 1]) if occ[n - 1] else 0.0
        return_ready = 0.0
        if occ[n - 1]:
            return_ready = drain_done + self.links[n - 1].delay(
                self.return_bytes, rng)
            self.wire_bytes += self.return_bytes
            self.sends += 1
            if rec is not None:
                rec.link_send(plane, n - 1, self.return_bytes,
                              drain_done, return_ready, return_trip=True)
        self._arrival[plane] = new_arrival
        tick_stall = float(stalls.sum())
        self.stall_s += tick_stall
        if occ.any():
            self.clock.advance_to(float(done[occ].max()))
        if rec is not None:
            # the same float the book accumulated, one entry per tick in
            # call order: a left-to-right sum reproduces stall_s bitwise
            rec.tick_stall(plane, tick_stall, self.clock.now)
        return TickObs(stalls=stalls, drain_done=drain_done,
                       return_ready=return_ready)

    def stats(self) -> Dict:
        return {
            "transport": self.name,
            "virtual_time_s": self.clock.now,
            "wire_bytes": int(self.wire_bytes),
            "link_sends": int(self.sends),
            "link_stall_s": float(self.stall_s),
            "max_link_latency_s": max(l.latency_s for l in self.links),
        }

    def audit(self) -> None:
        books = {"virtual_time_s": self.clock.now,
                 "wire_bytes": self.wire_bytes, "link_sends": self.sends,
                 "link_stall_s": self.stall_s}
        for k, v in books.items():
            assert np.isfinite(v) and v >= 0, \
                f"transport book {k}={v!r} is negative or non-finite"
        assert self.sends == 0 or self.wire_bytes > 0, \
            f"{self.sends} link send(s) accounted but zero wire bytes"
        if self._done is not None and self._done.size:
            assert np.isfinite(self._done).all() and \
                (self._done >= 0).all(), \
                f"per-stage timelines corrupt: {self._done!r}"
            assert self.clock.now + 1e-9 >= float(self._done.max()), \
                (f"virtual clock {self.clock.now} is behind a stage "
                 f"timeline ({float(self._done.max())}) — advance_to was "
                 "skipped on some tick")
        for plane, arr in self._arrival.items():
            assert np.isfinite(arr).all() and (arr >= 0).all(), \
                f"{plane} arrival timeline corrupt: {arr!r}"


class CompressedTransport(Transport):
    """Activation wire-byte pricing through the codecs of
    :mod:`repro.distributed.compression`: every payload is re-priced as
    int8- or top-k-compressed before the wrapped link carries it.

    With ``EngineConfig(wire_dtype="int8")`` the pipelined backend wraps
    its transport in this class automatically (setting ``elem_bytes`` to
    the compute dtype and ``row_elems`` to ``d_model``), and ``_wire``
    then computes exactly the bytes the jit ships: the per-row packed
    payload of ``int8_compress_rows`` — 1 B/element plus one f32 scale
    per row.  Accounting and reality agree by construction (see the
    parity test in ``tests/test_compression.py``).  Used standalone on
    an uncompressed run (``wire_dtype="fp32"``), it is what-if
    accounting: the ratio is the headroom the codec would buy.  Top-k
    has no in-jit path and is always accounting-only."""

    name = "compressed"

    def __init__(self, inner: Transport, *, method: str = "int8",
                 topk_frac: float = 0.01, elem_bytes: int = 4,
                 row_elems: int = 0):
        if method not in ("int8", "topk"):
            raise ValueError(f"method must be 'int8'|'topk', got {method!r}")
        self.inner = inner
        self.method = method
        self.topk_frac = topk_frac
        self.elem_bytes = elem_bytes
        self.row_elems = row_elems      # elements per scale row (d_model);
                                        # 0 = one scale per payload
        self.raw_bytes = 0
        self._wire_cache: Dict[int, int] = {}

    def _wire(self, nbytes: int) -> int:
        w = self._wire_cache.get(nbytes)
        if w is None:
            n_elems = max(1, nbytes // self.elem_bytes)
            if self.method == "int8":
                from repro.distributed.compression import int8_wire_bytes
                n_rows = max(1, n_elems // self.row_elems) \
                    if self.row_elems else 1
                w = int8_wire_bytes(n_elems, n_rows)
            else:
                w = max(1, int(n_elems * self.topk_frac)) * 8
            self._wire_cache[nbytes] = w
        return w

    def set_recorder(self, recorder) -> "CompressedTransport":
        # the inner transport accumulates the books, so the inner
        # transport records — the ledger then carries the *re-priced*
        # wire bytes, exactly what the books accumulate
        self.recorder = recorder
        self.inner.set_recorder(recorder)
        return self

    def bind(self, n_stages: int) -> "CompressedTransport":
        self.inner.bind(n_stages)
        return self

    def for_stages(self, n_stages: int) -> "CompressedTransport":
        fresh = CompressedTransport(self.inner.for_stages(n_stages),
                                    method=self.method,
                                    topk_frac=self.topk_frac,
                                    elem_bytes=self.elem_bytes,
                                    row_elems=self.row_elems)
        fresh.raw_bytes = self.raw_bytes
        fresh.recorder = self.recorder
        return fresh

    def tick(self, occupied, nbytes, compute_s, inject_t=0.0,
             plane="decode") -> TickObs:
        self.raw_bytes += nbytes * int(np.count_nonzero(
            np.asarray(occupied, bool)[:-1]))
        return self.inner.tick(occupied, self._wire(nbytes), compute_s,
                               inject_t, plane)

    @property
    def clock(self):
        return getattr(self.inner, "clock", None)

    def stats(self) -> Dict:
        st = dict(self.inner.stats())
        st["transport"] = f"{self.name}[{self.method}]>" \
                          f"{st.get('transport', self.inner.name)}"
        st["raw_bytes"] = int(self.raw_bytes)
        wire = st.get("wire_bytes", 0)
        if wire:
            st["compression_ratio"] = self.raw_bytes / wire
        return st

    def audit(self) -> None:
        assert self.raw_bytes >= 0, \
            f"raw_bytes={self.raw_bytes} went negative"
        for raw, wire in self._wire_cache.items():
            assert wire > 0, f"codec priced {raw}B payload at {wire}B"
            if self.method == "int8" and raw > 4 * self.elem_bytes * \
                    max(1, self.row_elems):
                assert wire < raw, \
                    (f"int8 codec inflated a {raw}B payload to {wire}B — "
                     "elem_bytes/row_elems are mis-tuned for the wire")
        self.inner.audit()


# ---------------------------------------------------------------------------
# Deployment plans — registry output -> links + planner input
# ---------------------------------------------------------------------------


@dataclass
class DeploymentPlan:
    """A concrete stage→machine placement with its latency geometry.

    ``stages`` are display labels (miner names or regions), ``regions``
    the per-stage region used for latency lookup, and ``latency_matrix``
    the full pairwise one-way matrix in seconds (symmetric).  The ring
    link ``s → (s+1) mod N_S`` inherits the matrix entry of its two
    endpoint stages; ``max_link_latency`` is what the §4.3 planner
    consumes (``EngineConfig.plan(deployment=...)``) — the slowest link
    sets the bubble budget."""

    stages: List[str]
    regions: List[str]
    latency_matrix: np.ndarray          # (n, n) seconds, one-way
    bandwidth_bps: float = 0.0
    jitter_s: float = 0.0
    machines: Optional[list] = None     # MachineSpec refs when registry-built
    task: Optional[object] = None

    def __post_init__(self):
        self.latency_matrix = np.asarray(self.latency_matrix, float)
        n = len(self.stages)
        if len(self.regions) != n or self.latency_matrix.shape != (n, n):
            raise ValueError(
                f"inconsistent plan: {n} stage(s), {len(self.regions)} "
                f"region(s), latency matrix {self.latency_matrix.shape}")

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def link_latencies(self) -> List[float]:
        """One-way latency of each ring link ``s → (s+1) mod N_S``."""
        n = self.n_stages
        return [float(self.latency_matrix[s, (s + 1) % n])
                for s in range(n)]

    @property
    def link_specs(self) -> List[LinkSpec]:
        return [LinkSpec(lat, self.bandwidth_bps, self.jitter_s)
                for lat in self.link_latencies]

    @property
    def max_link_latency(self) -> float:
        return max(self.link_latencies)

    @property
    def worst_link(self) -> LinkSpec:
        """The slowest ring link (highest latency; bandwidth/jitter are
        plan-wide) — what `EngineConfig.plan` sizes the prefill chunk
        against: the thinnest pipe bounds every chunk's wire time."""
        return max(self.link_specs, key=lambda l: l.latency_s)

    @property
    def max_pairwise_latency(self) -> float:
        n = self.n_stages
        if n == 1:
            return float(self.latency_matrix[0, 0])
        iu = np.triu_indices(n, k=1)
        return float(self.latency_matrix[iu].max())

    def transport(self, *, stage_time_s: Optional[float] = None,
                  seed: int = 0, compress: Optional[str] = None,
                  topk_frac: float = 0.01) -> Transport:
        """The per-link :class:`SimulatedLinkTransport` this plan implies
        (optionally wrapped in wire-byte :class:`CompressedTransport`)."""
        t: Transport = SimulatedLinkTransport(
            self.link_specs, stage_time_s=stage_time_s,
            seed=seed).bind(self.n_stages)
        if compress:
            t = CompressedTransport(t, method=compress, topk_frac=topk_frac)
        return t

    # -- stage placement ----------------------------------------------------

    def placement_cost(self, order: Sequence[int],
                       stage_weights: Optional[Sequence[float]] = None
                       ) -> float:
        """Cost of visiting the machines in ``order`` (a ring): each
        link's latency weighted by the mean compute weight of its two
        endpoint stages —

            Σ_s  L(order[s] → order[s+1]) · (w[s] + w[s+1]) / 2

        With uniform weights this is exactly the ring latency sum that
        enters the §4.3 round trip (``plan_schedule``'s ``Σ L_i``), so
        minimising it is the shortest-Hamiltonian-cycle placement; with
        heterogeneous weights the slowest links are pushed to border the
        lightest stages (a stall behind a slow link costs less where
        there is less compute to starve)."""
        n = self.n_stages
        w = [1.0] * n if stage_weights is None else \
            [float(x) for x in stage_weights]
        if len(w) != n:
            raise ValueError(f"{len(w)} stage weight(s) for {n} stage(s)")
        cost = 0.0
        for s in range(n):
            a, b = order[s], order[(s + 1) % n]
            cost += float(self.latency_matrix[a, b]) * \
                (w[s] + w[(s + 1) % n]) / 2.0
        return cost

    def place_stages(self, stage_weights: Optional[Sequence[float]] = None
                     ) -> "DeploymentPlan":
        """The stage-*placement* pass: reorder the machines so the ring
        pays the least for its geography (see :meth:`placement_cost`).

        The registry's match order is arbitrary with respect to the
        ring; this picks the cheapest cycle instead — exhaustively for
        small rings (≤ 8 stages, rotations deduped by anchoring stage
        0), greedily (cheapest-next-hop) beyond.  Returns a new plan
        with stages/regions/machines and the latency matrix permuted
        consistently; the original is untouched."""
        import itertools
        n = self.n_stages
        if n <= 2:
            return self
        if n <= 8:
            best = min(
                ((0,) + rest for rest in
                 itertools.permutations(range(1, n))),
                key=lambda o: self.placement_cost(o, stage_weights))
        else:
            remaining = set(range(1, n))
            best_l = [0]
            while remaining:
                cur = best_l[-1]
                nxt = min(remaining,
                          key=lambda j: float(self.latency_matrix[cur, j]))
                best_l.append(nxt)
                remaining.discard(nxt)
            best = tuple(best_l)
        return self._reordered(best)

    def _reordered(self, order: Sequence[int]) -> "DeploymentPlan":
        idx = list(order)
        mat = self.latency_matrix[np.ix_(idx, idx)]
        return DeploymentPlan(
            stages=[self.stages[i] for i in idx],
            regions=[self.regions[i] for i in idx],
            latency_matrix=mat, bandwidth_bps=self.bandwidth_bps,
            jitter_s=self.jitter_s,
            machines=[self.machines[i] for i in idx]
            if self.machines is not None else None,
            task=self.task)

    def describe(self) -> str:
        lines = [f"deployment: {self.n_stages} stage(s)"]
        for s, (label, reg, lat) in enumerate(
                zip(self.stages, self.regions, self.link_latencies)):
            lines.append(f"  stage {s}: {label} [{reg}] --"
                         f"{lat * 1000:.0f}ms--> stage "
                         f"{(s + 1) % self.n_stages}")
        lines.append(f"  max link latency: "
                     f"{self.max_link_latency * 1000:.0f}ms "
                     "(planner input)")
        return "\n".join(lines)

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_regions(cls, regions: Sequence[str], *,
                     bandwidth_bps: float = 0.0,
                     jitter_s: float = 0.0) -> "DeploymentPlan":
        """One stage per entry, latencies from the registry's region
        table (``framework.registry.region_latency``)."""
        from repro.framework.registry import region_latency
        regions = list(regions)
        n = len(regions)
        mat = np.zeros((n, n))
        for i in range(n):
            for j in range(n):
                mat[i, j] = region_latency(regions[i], regions[j])
        return cls(stages=list(regions), regions=regions,
                   latency_matrix=mat, bandwidth_bps=bandwidth_bps,
                   jitter_s=jitter_s)

    @classmethod
    def from_match(cls, match, *, bandwidth_bps: float = 0.0,
                   jitter_s: float = 0.0) -> "DeploymentPlan":
        """Registry-driven plan: the ``framework.registry.match`` result's
        machine order *is* the stage order (inter-layer partitioning,
        §2.3), latencies from each machine pair's regions."""
        from repro.framework.registry import region_latency
        machines = list(match.machines)
        regions = [m.region for m in machines]
        n = len(machines)
        mat = np.zeros((n, n))
        for i in range(n):
            for j in range(n):
                mat[i, j] = region_latency(regions[i], regions[j])
        return cls(stages=[f"{m.miner}#{m.machine_id}" for m in machines],
                   regions=regions, latency_matrix=mat,
                   bandwidth_bps=bandwidth_bps, jitter_s=jitter_s,
                   machines=machines, task=match.task)

    @classmethod
    def uniform(cls, n_stages: int, latency_s: float, *,
                bandwidth_bps: float = 0.0,
                jitter_s: float = 0.0) -> "DeploymentPlan":
        mat = np.full((n_stages, n_stages), latency_s)
        return cls(stages=[f"stage{s}" for s in range(n_stages)],
                   regions=["uniform"] * n_stages, latency_matrix=mat,
                   bandwidth_bps=bandwidth_bps, jitter_s=jitter_s)


def make_transport(kind, n_stages: int, **kw) -> Transport:
    """Factory: ``kind`` is None / "inprocess" (zero-cost), a float
    (uniform simulated latency), a :class:`DeploymentPlan`, or an already
    constructed :class:`Transport` (bound and passed through)."""
    if kind is None or kind == "inprocess":
        return InProcessTransport().bind(n_stages)
    if isinstance(kind, Transport):
        return kind.bind(n_stages)
    if isinstance(kind, DeploymentPlan):
        return kind.transport(**kw).bind(n_stages)
    if isinstance(kind, (int, float)):
        return SimulatedLinkTransport.uniform(n_stages, float(kind), **kw)
    raise ValueError(f"unknown transport {kind!r} (want None, 'inprocess', "
                     "a latency in seconds, a DeploymentPlan, or a "
                     "Transport instance)")
