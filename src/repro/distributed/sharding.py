"""Sharding rules: params / caches / activations → PartitionSpec per mesh.

Conventions (single-pod mesh ``("data", "model")``, multi-pod adds "pod"):

  * TP ("model"): attention heads (packed q/kv output dims), FFN hidden,
    MoE experts (expert parallelism), vocab (embedding/unembedding), and
    recurrent expanded width.  A dim is sharded only when divisible by the
    axis size; otherwise it stays replicated (no ragged shards).
  * FSDP ("data", training only): the complementary matmul dim of every
    large matrix.  Serving replicates params over "data" (weights stay put,
    activations move — the paper's rule for slow links applies to "pod").
  * KV caches: batch over "data"; kv-heads over "model" when divisible,
    else the *sequence* dim over "model" (sequence-parallel KV, needed for
    small-kv-head archs and ``long_500k``).
  * Pipeline ("pod"): stage-stacked leaves get P("pod", ...) — weights
    never cross the slow link; only (mb, S, D) activations do.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.config import ModelConfig


def _axis(mesh_shape: dict, name: Optional[str], dim: int) -> Optional[str]:
    """Return ``name`` if it exists in the mesh and divides ``dim``."""
    if name is None or name not in mesh_shape:
        return None
    return name if dim % mesh_shape[name] == 0 and dim > 0 else None


def _mesh_shape(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape)) if hasattr(
        mesh, "devices") else dict(mesh.shape)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

# rule table: leaf name -> (tp_dim, fsdp_dim) indices into the *unstacked*
# shape (None = do not shard).  tp gets "model", fsdp gets "data".
_PARAM_RULES = {
    "wq": (1, 0), "wk": (1, 0), "wv": (1, 0), "wo": (0, 1),
    "wg": (1, 0), "wu": (1, 0), "wd": (0, 1), "wg_mlp": (1, 0),
    # embeddings: vocab-TP only — FSDP-sharding D as well makes the gather
    # unpartitionable (XLA "involuntary full rematerialization")
    "tok": (0, None), "untok": (0, None),
    "frame_proj": (1, 0), "patch_proj": (1, 0),
    "wx": (1, 0), "conv_w": (1, None), "conv_b": (0, None),
    "gate_a_w": (0, None), "gate_x_w": (0, None),
    "gate_a_b": (0, None), "gate_x_b": (0, None), "lam": (0, None),
    "wm": (1, 0), "wz": (1, 0),
    "w_in": (2, 1), "b_in": (1, None), "r": (1, None),
    "w_i": (0, None), "w_f": (0, None), "b_i": (None, None),
    "b_f": (None, None),
    "router": (None, 0),
    "ln1": (None, None), "ln2": (None, None), "q_norm": (None, None),
    "k_norm": (None, None), "final_norm": (None, None),
}

# MoE expert tensors: expert dim 0 over "model" (EP), fsdp on dim 1
_MOE_RULES = {"wg": (0, 1), "wu": (0, 1), "wd": (0, 2)}


def _leaf_spec(name: str, shape: tuple, mesh_shape: dict, *, lead: tuple,
               fsdp: bool, in_moe: bool) -> P:
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES) else _PARAM_RULES
    tp_dim, fsdp_dim = rules.get(name, (None, None))
    n_lead = len(lead)
    spec = list(lead) + [None] * (len(shape) - n_lead)
    if tp_dim is not None and tp_dim + n_lead < len(shape):
        ax = _axis(mesh_shape, "model", shape[tp_dim + n_lead])
        if ax:
            spec[tp_dim + n_lead] = ax
    if fsdp and fsdp_dim is not None and fsdp_dim + n_lead < len(shape):
        if spec[fsdp_dim + n_lead] is None:
            ax = _axis(mesh_shape, "data", shape[fsdp_dim + n_lead])
            if ax:
                spec[fsdp_dim + n_lead] = ax
    return P(*spec)


def param_specs(params, cfg: ModelConfig, mesh, *, fsdp: bool = False,
                pipeline: bool = False):
    """PartitionSpec pytree matching ``params``.

    ``pipeline=True`` is for stage-split params (leading (n_stages, pps)
    axes → P("pod", None, ...))."""
    ms = _mesh_shape(mesh)

    def spec_for(path, leaf):
        name = None
        in_scan = False
        in_moe = False
        for k in path:
            if isinstance(k, DictKey):
                if k.key == "scan":
                    in_scan = True
                if k.key == "moe":
                    in_moe = True
                name = k.key
        if pipeline:
            lead = ("pod", None)
        elif in_scan:
            lead = (None,)
        else:
            lead = ()
        return _leaf_spec(name, leaf.shape, ms, lead=lead, fsdp=fsdp,
                          in_moe=in_moe)

    return jax.tree_util.tree_map_with_path(spec_for, params)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _cache_leaf_spec(name: str, shape: tuple, cfg: ModelConfig,
                     mesh_shape: dict, n_lead: int, lead: tuple,
                     seq_kv: bool = False) -> P:
    """Cache leaves: batch over data; kv-heads over model when divisible,
    else sequence over model.  ``n_lead``/``lead`` describe stacking dims.
    ``seq_kv`` forces sequence-sharding (the pipeline's stage caches: XLA's
    partitioner CHECK-crashes expanding head-sharded KV device groups inside
    the partial-manual pod region)."""
    spec = list(lead) + [None] * (len(shape) - n_lead)
    md = mesh_shape.get("model", 1)

    def set_dim(i, ax_name):
        ax = _axis(mesh_shape, ax_name, shape[i])
        if ax and spec[i] is None:
            spec[i] = ax
            return True
        return False

    head_shard = cfg.num_kv_heads % md == 0 and not seq_kv
    if name in ("k_scale", "v_scale"):        # (B, C, Hk)
        set_dim(n_lead + 0, "data")
        if head_shard:
            set_dim(n_lead + 2, "model")
        else:
            set_dim(n_lead + 1, "model")
        return P(*spec)
    if name in ("k", "v"):                    # (B, C, Hk, Dh)
        set_dim(n_lead + 0, "data")
        if head_shard:
            set_dim(n_lead + 2, "model")
        else:
            set_dim(n_lead + 1, "model")      # sequence-parallel KV
        if spec[n_lead + 0] is None and spec[n_lead + 1] != "model":
            # tiny batch (long-context decode): shard seq over data too
            set_dim(n_lead + 1, "data")
    elif name in ("k_pages", "v_pages"):      # (P, page, Hk, Dh)
        set_dim(n_lead + 0, "data")           # pages over data
        if head_shard:
            set_dim(n_lead + 2, "model")
    elif name == "pos":                       # (B, C)
        set_dim(n_lead + 0, "data")
        if not head_shard:
            set_dim(n_lead + 1, "model")
        elif spec[n_lead + 0] is None:
            set_dim(n_lead + 1, "data")
    elif name == "page_table":                # (B, max_pages)
        set_dim(n_lead + 0, "data")
    elif name in ("h", "conv"):               # rglru (B, Dr)/(B, cw-1, Dr)
        set_dim(n_lead + 0, "data")
        set_dim(len(shape) - 1, "model")
    elif name in ("c", "n", "m"):             # lstm states
        set_dim(n_lead + 0, "data")
        if len(shape) - n_lead >= 2:
            if cfg.num_heads % md == 0:        # heads over model
                set_dim(n_lead + 1, "model")
            else:                              # else last (unit/hidden) dim
                set_dim(len(shape) - 1, "model")
    return P(*spec)


def cache_specs(caches, cfg: ModelConfig, mesh, *, pipeline: bool = False):
    """PartitionSpec pytree for a cache pytree (dense, paged or pipeline)."""
    ms = _mesh_shape(mesh)

    def spec_for(path, leaf):
        name = None
        section = None
        for k in path:
            if isinstance(k, DictKey):
                if k.key in ("scan", "tail", "stage", "epi_scan"):
                    section = k.key
                else:
                    name = k.key
        if section == "stage":                 # (n_stages, n_mb, pps, ...)
            lead = ("pod", None, None)
        elif section in ("scan", "epi_scan"):  # (n_periods, ...)
            lead = (None,)
        else:
            lead = ()
        return _cache_leaf_spec(name, leaf.shape, cfg, ms, len(lead), lead,
                                seq_kv=(section == "stage"))

    return jax.tree_util.tree_map_with_path(spec_for, caches)


# ---------------------------------------------------------------------------
# Batches / activations
# ---------------------------------------------------------------------------


def batch_specs(batch_shapes: dict, mesh, *, batch_axes=("data",),
                fold_pod: bool = True) -> dict:
    """Input batch specs: leading batch dim over data (and pod, folded into
    DP, when present)."""
    ms = _mesh_shape(mesh)
    axes = []
    if fold_pod and "pod" in ms:
        axes.append("pod")
    axes.extend(a for a in batch_axes if a in ms)

    def spec_for(leaf):
        shape = leaf.shape
        if not shape:
            return P()
        group = tuple(axes)
        total = int(np.prod([ms[a] for a in group])) if group else 1
        if group and shape[0] % total == 0:
            first = group[0] if len(group) == 1 else group
            return P(first, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree.map(spec_for, batch_shapes)


def opt_state_specs(pspecs):
    """Optimizer moments inherit the param sharding; step is replicated."""
    from repro.training.optimizer import AdamWState
    return AdamWState(step=P(), m=pspecs, v=pspecs)
