"""Elastic scaling, failure handling and straggler mitigation.

The decentralized-mining substrate (paper Table 1: "intermittent"
availability) makes node loss the common case, not the exception.  This
module contains the *control-plane* logic — pure, deterministic, fully
testable on CPU:

  * :class:`ElasticPlanner` — given the live-device count, choose the
    largest legal mesh (data dim shrinks first, model dim preserved so TP
    sharding stays valid) and emit a resharding plan.
  * :class:`FailureDetector` — heartbeat bookkeeping with configurable
    timeout; drives checkpoint-restart (see ``repro.checkpoint``) and
    ``OfflineEngine.reshard``.  A device that misses the timeout and then
    beats again is a *flap* — recorded per device, never silently
    resurrected.
  * :class:`StragglerMitigator` — EWMA per-stage tick times; flags outliers
    and re-weights microbatch assignment (slow stage gets smaller
    microbatches) or recommends demotion to spare.
  * :class:`FaultPlan` — deterministic fault injection for tests and
    drills: drop (lose the microbatch at stage ``s`` at backend tick
    ``t``) or delay (synthetic straggling) events, consumed by the
    serving ``PipelinedBackend``.

On a real deployment these drive ``jax.distributed`` re-initialisation plus
checkpoint restore; the dry-run exercises plan generation for every legal
device count, and the serving engine consumes all four for mid-run
recovery (see ``docs/architecture.md`` — Fault tolerance & elasticity).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    devices_used: int
    devices_spare: int

    @property
    def data(self) -> int:
        return self.shape[self.axes.index("data")]

    @property
    def model(self) -> int:
        return self.shape[self.axes.index("model")]


class ElasticPlanner:
    """Choose meshes as devices come and go.

    Invariants: the "model" axis is preserved (param TP sharding stays
    valid → only batch-dim resharding on resize, which is a cheap
    redistribution, not a weight reshuffle); "data" is the largest power of
    two that fits; the pod axis only exists while >= 2 full pods are live.
    """

    def __init__(self, model_parallel: int = 16, pod_size: int = 256):
        self.model_parallel = model_parallel
        self.pod_size = pod_size

    def plan(self, live_devices: int) -> MeshPlan:
        mp = self.model_parallel
        if live_devices < mp:
            raise RuntimeError(
                f"cannot serve: {live_devices} devices < model parallel {mp}")
        pods = live_devices // self.pod_size
        if pods >= 2:
            per_pod = self.pod_size
            data = self._pow2(per_pod // mp)
            used = pods * data * mp
            return MeshPlan(shape=(pods, data, mp),
                            axes=("pod", "data", "model"),
                            devices_used=used,
                            devices_spare=live_devices - used)
        data = self._pow2(live_devices // mp)
        used = data * mp
        return MeshPlan(shape=(data, mp), axes=("data", "model"),
                        devices_used=used,
                        devices_spare=live_devices - used)

    @staticmethod
    def _pow2(n: int) -> int:
        return 1 << max(0, n.bit_length() - 1)

    def resharding_plan(self, old: MeshPlan, new: MeshPlan) -> dict:
        """What must move when the mesh changes."""
        dp_changed = (old.data != new.data or
                      old.devices_used != new.devices_used)
        return {
            "model_axis_changed": old.model != new.model,
            "params_move": old.model != new.model,     # TP reshard = heavy
            "batch_reshard": dp_changed,               # cheap redistribution
            "restore_from_checkpoint": old.model != new.model,
            "old": old, "new": new,
        }


@dataclass
class Heartbeat:
    last_seen: float
    failures: int = 0                  # dead->live transitions (flaps)


class FailureDetector:
    """Heartbeat bookkeeping.  ``dead``/``live`` use a strict timeout
    (``now - last_seen == timeout`` is still live, so a boundary probe can
    never double-count a failure); a beat from a device that had already
    missed the timeout is a dead->live *flap* and increments its failure
    record instead of silently resurrecting it."""

    def __init__(self, timeout: float = 30.0):
        self.timeout = timeout
        self._beats: Dict[int, Heartbeat] = {}

    def beat(self, device_id: int, now: float) -> None:
        hb = self._beats.get(device_id)
        if hb is None:
            self._beats[device_id] = Heartbeat(last_seen=now)
            return
        if now - hb.last_seen > self.timeout:
            hb.failures += 1           # resurrection: record the flap
        hb.last_seen = now

    def flap_count(self, device_id: Optional[int] = None) -> int:
        """Dead->live transitions for one device (0 if unseen), or summed
        across all devices when ``device_id`` is None."""
        if device_id is not None:
            hb = self._beats.get(device_id)
            return hb.failures if hb is not None else 0
        return sum(hb.failures for hb in self._beats.values())

    def dead(self, now: float) -> List[int]:
        return [d for d, hb in self._beats.items()
                if now - hb.last_seen > self.timeout]

    def live(self, now: float) -> List[int]:
        return [d for d, hb in self._beats.items()
                if now - hb.last_seen <= self.timeout]

    def should_restart(self, now: float, required: int) -> bool:
        return len(self.live(now)) < required


class StragglerMitigator:
    """EWMA stage-time tracking + microbatch re-weighting.

    The circular schedule (§4.3) is only bubble-free if every stage keeps
    pace; one slow stage sets the ring tick.  Mitigation: shrink the slow
    stage's share of per-microbatch work (fewer sequences routed to the
    microbatches it bottlenecks) or — beyond a threshold — recommend the
    planner demote the node and promote a spare.
    """

    def __init__(self, n_stages: int, alpha: float = 0.2,
                 slow_factor: float = 1.5, demote_factor: float = 3.0):
        self.n_stages = n_stages
        self.alpha = alpha
        self.slow_factor = slow_factor
        self.demote_factor = demote_factor
        self.ewma = [0.0] * n_stages

    def observe(self, stage: int, tick_time: float) -> None:
        cur = self.ewma[stage]
        self.ewma[stage] = tick_time if cur == 0.0 else (
            self.alpha * tick_time + (1 - self.alpha) * cur)

    def median(self) -> float:
        s = sorted(t for t in self.ewma if t > 0)
        return s[len(s) // 2] if s else 0.0

    def stragglers(self) -> List[int]:
        med = self.median()
        if med == 0:
            return []
        return [i for i, t in enumerate(self.ewma)
                if t > self.slow_factor * med]

    def demotions(self) -> List[int]:
        med = self.median()
        if med == 0:
            return []
        return [i for i, t in enumerate(self.ewma)
                if t > self.demote_factor * med]

    def microbatch_weights(self) -> List[float]:
        """Relative per-stage work shares ∝ 1/EWMA.  Observed stages are
        normalised to mean 1.0 *among themselves*; a cold stage (no
        observation yet, ewma == 0) gets exactly 1.0 — it must neither be
        penalised nor skew the normalisation.  Feed into the engine's
        per-tick admission budget (slow stage → lighter microbatches)."""
        med = self.median()
        if med == 0:
            return [1.0] * self.n_stages
        inv = [med / t if t > 0 else None for t in self.ewma]
        observed = [w for w in inv if w is not None]
        mean = sum(observed) / len(observed)
        return [1.0 if w is None else w / mean for w in inv]


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault: at backend tick ``tick`` of ``plane`` ("decode"
    or "prefill"), stage ``stage`` either *drops* (the microbatch/chunk at
    that stage is lost — never drains, its remaining cache writes never
    happen) or is *delayed* (the tick completes but the stage's observed
    time is inflated by ``delay_s`` — feeds straggler mitigation).  Tick
    indices are plane-local and count only ticks where the plane actually
    advanced (something was in flight)."""
    plane: str                         # "decode" | "prefill"
    tick: int
    stage: int
    kind: str = "drop"                 # "drop" | "delay"
    delay_s: float = 0.0

    def __post_init__(self):
        if self.plane not in ("decode", "prefill"):
            raise ValueError(f"plane must be 'decode'|'prefill', "
                             f"got {self.plane!r}")
        if self.kind not in ("drop", "delay"):
            raise ValueError(f"kind must be 'drop'|'delay', "
                             f"got {self.kind!r}")
        if self.tick < 0 or self.stage < 0:
            raise ValueError("tick and stage must be >= 0")


class FaultPlan:
    """A consumable schedule of :class:`FaultEvent`.  The serving
    ``PipelinedBackend`` calls :meth:`take` once per plane tick; consumed
    events move to ``triggered`` so tests can assert the plan fired."""

    def __init__(self, events=()):
        self.events: List[FaultEvent] = sorted(events,
                                               key=lambda e: e.tick)
        self.triggered: List[FaultEvent] = []

    def __bool__(self) -> bool:
        return bool(self.events)

    def take(self, plane: str, tick: int) -> List[FaultEvent]:
        hit = [e for e in self.events
               if e.plane == plane and e.tick == tick]
        if hit:
            self.events = [e for e in self.events if e not in hit]
            self.triggered.extend(hit)
        return hit

    def pending(self) -> int:
        return len(self.events)

    @classmethod
    def parse(cls, specs) -> "FaultPlan":
        """Build a plan from CLI specs: ``kind@plane:tick:stage[:delay_s]``
        e.g. ``drop@decode:12:1`` or ``delay@prefill:3:0:0.25``."""
        events = []
        for spec in specs:
            try:
                kind, rest = spec.split("@", 1)
                parts = rest.split(":")
                plane, tick, stage = parts[0], int(parts[1]), int(parts[2])
                delay = float(parts[3]) if len(parts) > 3 else 0.0
            except (ValueError, IndexError) as e:
                raise ValueError(
                    f"bad fault spec {spec!r} (want "
                    f"kind@plane:tick:stage[:delay_s], e.g. "
                    f"drop@decode:12:1): {e}") from e
            events.append(FaultEvent(plane=plane, tick=tick, stage=stage,
                                     kind=kind, delay_s=delay))
        return cls(events)
