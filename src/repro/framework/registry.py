"""Task & GPU registries (paper §6.2, Figure 1) — protocol-faithful local
implementation of the on-chain service-discovery components.

Users register offline inference tasks (workload + escrowed budget); miners
register machines (GPU memory, region, stake).  ``match`` builds serving
pipelines: it selects a set of machines whose pooled memory fits the model
(inter-layer partitioning, §2.3) while minimising the maximum pairwise
latency inside the pipeline (latency sets the bubble budget, §4.3).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class TaskSpec:
    task_id: int
    owner: str
    model_name: str
    model_bytes: int                  # weights footprint
    n_requests: int
    max_price_per_mtok: float
    deadline_hours: float = 24.0
    status: str = "open"              # open | matched | done | disputed


@dataclass
class MachineSpec:
    machine_id: int
    miner: str
    gpu_memory_bytes: int
    region: str
    stake: float
    status: str = "idle"              # idle | serving | offline

    def usable_memory(self, weight_fraction: float = 0.8) -> int:
        return int(self.gpu_memory_bytes * weight_fraction)


# symmetric inter-region one-way latencies (seconds)
REGION_LATENCY = {
    ("us-east", "us-east"): 0.002,
    ("us-east", "us-west"): 0.058,
    ("us-east", "eu"): 0.090,
    ("us-west", "us-west"): 0.002,
    ("us-west", "eu"): 0.140,
    ("eu", "eu"): 0.002,
}


def region_latency(a: str, b: str) -> float:
    return REGION_LATENCY.get((a, b), REGION_LATENCY.get((b, a), 0.2))


@dataclass
class Match:
    task: TaskSpec
    machines: List[MachineSpec]
    max_latency: float

    @property
    def n_stages(self) -> int:
        return len(self.machines)


class Registry:
    def __init__(self):
        self.tasks: Dict[int, TaskSpec] = {}
        self.machines: Dict[int, MachineSpec] = {}
        self._next_task = 0
        self._next_machine = 0

    # -- registration ---------------------------------------------------

    def register_task(self, owner: str, model_name: str, model_bytes: int,
                      n_requests: int, max_price: float) -> TaskSpec:
        t = TaskSpec(task_id=self._next_task, owner=owner,
                     model_name=model_name, model_bytes=model_bytes,
                     n_requests=n_requests, max_price_per_mtok=max_price)
        self.tasks[t.task_id] = t
        self._next_task += 1
        return t

    def register_machine(self, miner: str, gpu_memory_bytes: int,
                         region: str, stake: float) -> MachineSpec:
        m = MachineSpec(machine_id=self._next_machine, miner=miner,
                        gpu_memory_bytes=gpu_memory_bytes, region=region,
                        stake=stake)
        self.machines[m.machine_id] = m
        self._next_machine += 1
        return m

    # -- matching ---------------------------------------------------------

    # past this many distinct regions, exact region-subset enumeration
    # (2^R) gives way to the greedy heuristic
    EXACT_REGION_LIMIT = 12

    @staticmethod
    def _group_latency(group: List[MachineSpec]) -> float:
        return max((region_latency(a.region, b.region)
                    for a, b in itertools.combinations(group, 2)),
                   default=region_latency(group[0].region,
                                          group[0].region))

    def match(self, task_id: int, *, min_stake: float = 0.0) -> Optional[Match]:
        """Machine set with pooled memory >= model_bytes that *minimises*
        the maximum pairwise latency inside the pipeline (§4.3: the
        slowest link sets the bubble budget), tie-broken by fewer
        machines.

        A machine set's max pairwise latency is a function of the set of
        regions it spans, so enumerating region subsets in latency order
        and checking feasibility (pooled idle memory of those regions)
        is exact: the first feasible subset is optimal.  Within the
        winning subset the machines are taken largest-memory-first, so
        the pipeline is also the shortest one that region choice admits.
        Beyond :attr:`EXACT_REGION_LIMIT` distinct regions the old greedy
        heuristic (per-region prefixes + global memory-greedy prefix)
        bounds the work.
        """
        task = self.tasks[task_id]
        idle = [m for m in self.machines.values()
                if m.status == "idle" and m.stake >= min_stake]
        if not idle:
            return None
        by_region: Dict[str, List[MachineSpec]] = {}
        for m in idle:
            by_region.setdefault(m.region, []).append(m)
        regions = sorted(by_region)

        candidates: List[List[MachineSpec]] = []
        if len(regions) <= self.EXACT_REGION_LIMIT:
            for ms in by_region.values():       # sort each region once;
                ms.sort(key=lambda m: -m.gpu_memory_bytes)  # combos merge
            for r in range(1, len(regions) + 1):
                for combo in itertools.combinations(regions, r):
                    ms = heapq.merge(*(by_region[reg] for reg in combo),
                                     key=lambda m: -m.gpu_memory_bytes)
                    chosen, total = [], 0
                    for m in ms:
                        chosen.append(m)
                        total += m.usable_memory()
                        if total >= task.model_bytes:
                            candidates.append(chosen)
                            break
        else:                                   # heuristic fallback
            for region, ms in by_region.items():
                ms = sorted(ms, key=lambda m: -m.gpu_memory_bytes)
                for k in range(1, len(ms) + 1):
                    if sum(m.usable_memory()
                           for m in ms[:k]) >= task.model_bytes:
                        candidates.append(ms[:k])
                        break
            all_ms = sorted(idle, key=lambda m: -m.gpu_memory_bytes)
            for k in range(1, len(all_ms) + 1):
                if sum(m.usable_memory()
                       for m in all_ms[:k]) >= task.model_bytes:
                    candidates.append(all_ms[:k])
                    break

        best: Optional[Match] = None
        for group in candidates:
            lat = self._group_latency(group)
            cand = Match(task=task, machines=group, max_latency=lat)
            if best is None or (lat, len(group)) < (best.max_latency,
                                                    best.n_stages):
                best = cand
        if best is not None:
            task.status = "matched"
            for m in best.machines:
                m.status = "serving"
        return best

    def release(self, match: Match, *, done: bool = True) -> None:
        match.task.status = "done" if done else "open"
        for m in match.machines:
            m.status = "idle"
