"""Escrow payment module (paper §6.2): lock user funds on task registration,
release to the miner on signed delivery, refund on arbitration."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List


class PaymentError(Exception):
    pass


@dataclass
class Escrow:
    escrow_id: int
    task_id: int
    payer: str
    amount: float
    status: str = "locked"            # locked | released | refunded


class PaymentModule:
    def __init__(self):
        self.balances: Dict[str, float] = {}
        self.escrows: Dict[int, Escrow] = {}
        self._next = 0

    def deposit(self, account: str, amount: float) -> None:
        if amount <= 0:
            raise PaymentError("deposit must be positive")
        self.balances[account] = self.balances.get(account, 0.0) + amount

    def balance(self, account: str) -> float:
        return self.balances.get(account, 0.0)

    def lock(self, payer: str, task_id: int, amount: float) -> Escrow:
        if self.balance(payer) < amount:
            raise PaymentError(f"{payer}: insufficient funds")
        self.balances[payer] -= amount
        e = Escrow(escrow_id=self._next, task_id=task_id, payer=payer,
                   amount=amount)
        self.escrows[e.escrow_id] = e
        self._next += 1
        return e

    def release(self, escrow_id: int, miner: str) -> None:
        e = self._get_locked(escrow_id)
        e.status = "released"
        self.balances[miner] = self.balances.get(miner, 0.0) + e.amount

    def refund(self, escrow_id: int) -> None:
        e = self._get_locked(escrow_id)
        e.status = "refunded"
        self.balances[e.payer] = self.balances.get(e.payer, 0.0) + e.amount

    def _get_locked(self, escrow_id: int) -> Escrow:
        e = self.escrows[escrow_id]
        if e.status != "locked":
            raise PaymentError(f"escrow {escrow_id} already {e.status}")
        return e
