"""Inference-correctness protection (paper §6.1/6.2).

The paper's three design principles, which this module implements exactly:

  1. *No heavy extra serving compute* — the miner's only added cost is an
     HMAC signature over (task, request, output) per response.
  2. *Inputs/outputs stay off-chain* — the arbitration record stores only
     hashes; payloads travel peer-to-peer.
  3. *No arbitrary-party challenges* — only the task owner (key-holder) may
     open a dispute, and only against a response the miner actually signed
     (possession of a valid signature is the challenge ticket), so miners
     cannot be DoS-ed by third-party verifiers.

The pluggable ``verifier`` is where opML/spML/zkML-style re-execution would
attach (the paper: "different mechanisms can be applied here
interchangeably"); the default re-runs the pinned deterministic reference.
"""

from __future__ import annotations

import dataclasses
import hashlib
import hmac
import json
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


def _digest(payload: dict) -> bytes:
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).digest()


@dataclass
class SignedResult:
    task_id: int
    request_id: int
    miner: str
    output_hash: str
    signature: str

    @staticmethod
    def sign(task_id: int, request_id: int, miner: str, output_tokens,
             miner_key: bytes) -> "SignedResult":
        oh = _digest({"o": list(map(int, output_tokens))}).hex()
        mac = hmac.new(miner_key, _digest(
            {"t": task_id, "r": request_id, "h": oh}), "sha256").hexdigest()
        return SignedResult(task_id=task_id, request_id=request_id,
                            miner=miner, output_hash=oh, signature=mac)

    def verify_signature(self, miner_key: bytes) -> bool:
        mac = hmac.new(miner_key, _digest(
            {"t": self.task_id, "r": self.request_id,
             "h": self.output_hash}), "sha256").hexdigest()
        return hmac.compare_digest(mac, self.signature)

    def matches_output(self, output_tokens) -> bool:
        return self.output_hash == _digest(
            {"o": list(map(int, output_tokens))}).hex()


@dataclass
class Dispute:
    dispute_id: int
    result: SignedResult
    claimant: str
    outcome: str = "pending"          # pending | slashed | dismissed


class ArbitrationModule:
    def __init__(self, payment, *, verifier: Optional[Callable] = None):
        self.payment = payment
        self.stakes: Dict[str, float] = {}
        self.miner_keys: Dict[str, bytes] = {}
        self.task_owner: Dict[int, str] = {}
        self.disputes: List[Dispute] = []

    # -- staking / identity ----------------------------------------------

    def register_miner(self, miner: str, stake: float) -> bytes:
        if stake <= 0:
            raise ValueError("stake must be positive")
        self.payment.balances[miner] = self.payment.balance(miner) - stake
        if self.payment.balances[miner] < 0:
            self.payment.balances[miner] += stake
            raise ValueError(f"{miner}: insufficient funds to stake")
        self.stakes[miner] = self.stakes.get(miner, 0.0) + stake
        key = hashlib.sha256(f"key:{miner}".encode()).digest()
        self.miner_keys[miner] = key
        return key

    def register_task_owner(self, task_id: int, owner: str) -> None:
        self.task_owner[task_id] = owner

    # -- dispute ----------------------------------------------------------

    def open_dispute(self, claimant: str, result: SignedResult,
                     claimed_output, reference_output) -> Dispute:
        """Only the task owner may dispute, and only with a validly signed
        result (principles 2+3)."""
        if self.task_owner.get(result.task_id) != claimant:
            raise PermissionError("only the task owner may dispute")
        key = self.miner_keys.get(result.miner)
        if key is None or not result.verify_signature(key):
            raise PermissionError("dispute requires a validly signed result")
        d = Dispute(dispute_id=len(self.disputes), result=result,
                    claimant=claimant)
        self.disputes.append(d)
        # adjudicate: the miner is at fault iff the signed hash matches the
        # delivered (wrong) output and that output differs from the reference
        delivered_matches = result.matches_output(claimed_output)
        correct = list(map(int, claimed_output)) == list(
            map(int, reference_output))
        if delivered_matches and not correct:
            self._slash(result.miner, d)
        else:
            d.outcome = "dismissed"
        return d

    def _slash(self, miner: str, dispute: Dispute) -> None:
        stake = self.stakes.get(miner, 0.0)
        self.stakes[miner] = 0.0
        claimant = dispute.claimant
        self.payment.balances[claimant] = (
            self.payment.balance(claimant) + stake)
        dispute.outcome = "slashed"

    def withdraw_stake(self, miner: str) -> float:
        s = self.stakes.get(miner, 0.0)
        self.stakes[miner] = 0.0
        self.payment.balances[miner] = self.payment.balance(miner) + s
        return s
