"""Chrome-trace-event (Perfetto-loadable) timeline export.

``chrome_trace_events`` turns a :class:`~repro.obs.trace.TraceRecorder`
into the JSON object format Perfetto / ``chrome://tracing`` load
directly: open https://ui.perfetto.dev and drop the file in.

Track layout — the picture the §4.3 schedule comparison needs:

* **pid 1 "wall clock"** — the engine's real time: one ``engine`` track
  of step phases (reap / prefill / decode), one ``pipe/<plane>`` track
  per pipe plane (each tick a slice whose args carry the stage
  occupancy), the ``offload`` swap windows, and instant markers for
  prefix-cache hits/evictions, SLO budget decisions, faults, reshard
  drain/rebuild.
* **pid 2 "virtual clock"** — the transport's simulated time: one
  ``stage<s>`` track per pipeline stage (busy windows — the circular
  schedule shows as a dense brick wall, round-flush as bubbles), and
  per-link transfers as async slices (``ph "b"/"e"`` — transfers
  legitimately overlap when the link delay exceeds a stage tick, which
  complete-X slices cannot express).  Each transfer's ``nbytes`` rides
  in its args: summing them over the exported JSON reconciles bitwise
  with ``SimulatedLinkTransport.wire_bytes`` (ints survive the JSON
  round trip exactly), and the ``stall`` counter series reconciles the
  same way against ``stall_s``.

``validate_chrome_trace`` is the schema check the CI audit job runs
(also exposed as ``python -m repro.obs.timeline --check out.json``):
structural keys per phase type, finite non-negative timestamps, b/e
pairing, and per-track monotonicity of complete slices.
"""

from __future__ import annotations

import json
import math
import sys
from typing import Dict, List, Optional, Union

from repro.obs.trace import (ASYNC, COUNTER, INSTANT, SPAN, TraceRecorder,
                             VIRTUAL, WALL)

__all__ = ["chrome_trace_events", "write_chrome_trace",
           "validate_chrome_trace"]

_PIDS = {WALL: 1, VIRTUAL: 2}
_US = 1e6


def chrome_trace_events(rec: TraceRecorder) -> Dict:
    """``{"traceEvents": [...], ...}`` in Chrome JSON object format."""
    events: List[Dict] = []
    for clock, pid in _PIDS.items():
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"{clock} clock"}})
    tids: Dict = {}

    def tid_of(pid: int, track: str) -> int:
        key = (pid, track)
        t = tids.get(key)
        if t is None:
            t = tids[key] = len(tids) + 1
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": t, "args": {"name": track}})
        return t

    # normalise the wall clock so the timeline starts near 0 (perf_counter
    # has an arbitrary epoch); the virtual clock already starts at 0
    wall0 = min((e.t0 for e in rec.events if e.clock == WALL),
                default=0.0)
    async_id = 0
    for e in rec.events:
        pid = _PIDS[e.clock]
        tid = tid_of(pid, e.track)
        t0 = e.t0 - wall0 if e.clock == WALL else e.t0
        args = dict(e.data)
        if e.kind == SPAN:
            events.append({"name": e.name, "ph": "X", "pid": pid,
                           "tid": tid, "ts": t0 * _US,
                           "dur": max(e.dur, 0.0) * _US, "args": args})
        elif e.kind == ASYNC:
            async_id += 1
            base = {"name": e.name, "cat": e.track, "pid": pid,
                    "tid": tid, "id": async_id}
            events.append({**base, "ph": "b", "ts": t0 * _US,
                           "args": args})
            events.append({**base, "ph": "e",
                           "ts": (t0 + max(e.dur, 0.0)) * _US})
        elif e.kind == COUNTER:
            events.append({"name": e.name, "ph": "C", "pid": pid,
                           "tid": tid, "ts": t0 * _US, "args": args})
        else:                       # INSTANT
            events.append({"name": e.name, "ph": "i", "pid": pid,
                           "tid": tid, "ts": t0 * _US, "s": "t",
                           "args": args})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"recorder_events": len(rec.events),
                          "recorder_dropped": rec.dropped}}


def write_chrome_trace(rec: TraceRecorder, path: str) -> Dict:
    """Export ``rec`` to ``path`` (Perfetto-loadable JSON); returns the
    trace object it wrote."""
    trace = chrome_trace_events(rec)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


# ---------------------------------------------------------------------------
# Schema check (CI audit job)
# ---------------------------------------------------------------------------


def validate_chrome_trace(trace: Union[Dict, List]) -> List[str]:
    """Structural validation of a Chrome-trace JSON object; returns a
    list of problems (empty = valid).  Checks: ``traceEvents`` shape,
    required keys per phase type, finite non-negative timestamps and
    durations, b/e async pairing, and per-``(pid, tid)`` monotone
    ordering of complete ("X") slices."""
    errs: List[str] = []
    if isinstance(trace, dict):
        events = trace.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level 'traceEvents' missing or not a list"]
    elif isinstance(trace, list):
        events = trace
    else:
        return [f"trace must be a dict or list, got {type(trace).__name__}"]

    def bad(i, msg):
        if len(errs) < 50:
            errs.append(f"event[{i}]: {msg}")

    last_x_ts: Dict = {}
    open_async: Dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            bad(i, f"not an object: {ev!r}")
            continue
        ph = ev.get("ph")
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            bad(i, "missing event name")
        if ph not in ("X", "i", "I", "b", "e", "n", "C", "M", "B", "E"):
            bad(i, f"unknown phase {ph!r}")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts) \
                or ts < 0:
            bad(i, f"ts={ts!r} must be a finite number >= 0")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or \
                    not math.isfinite(dur) or dur < 0:
                bad(i, f"complete slice dur={dur!r} must be >= 0")
            if "pid" not in ev or "tid" not in ev:
                bad(i, "complete slice missing pid/tid")
            else:
                key = (ev["pid"], ev["tid"])
                prev = last_x_ts.get(key)
                if prev is not None and ts < prev:
                    bad(i, f"track {key}: slice ts {ts} < previous "
                           f"{prev} — per-track timestamps must be "
                           "monotone")
                last_x_ts[key] = ts
        elif ph in ("b", "e"):
            if "id" not in ev or "cat" not in ev:
                bad(i, f"async event missing id/cat: {ev}")
                continue
            key = (ev["cat"], ev["id"])
            if ph == "b":
                if key in open_async:
                    bad(i, f"async {key} begun twice")
                open_async[key] = ts
            else:
                t0 = open_async.pop(key, None)
                if t0 is None:
                    bad(i, f"async end {key} without a begin")
                elif ts < t0:
                    bad(i, f"async {key} ends at {ts} before its begin "
                           f"{t0}")
    for key in open_async:
        if len(errs) < 50:
            errs.append(f"async {key} never ended")
    return errs


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.timeline",
        description="validate an exported Chrome-trace timeline")
    ap.add_argument("--check", metavar="PATH", required=True,
                    help="trace JSON to validate")
    args = ap.parse_args(argv)
    try:
        with open(args.check) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"timeline: cannot load {args.check}: {e}", file=sys.stderr)
        return 2
    errs = validate_chrome_trace(trace)
    for e in errs:
        print(f"timeline: {e}")
    n = len(trace.get("traceEvents", trace)) if isinstance(trace, (dict,
                                                                   list)) \
        else 0
    print(f"timeline: {args.check}: "
          + (f"{len(errs)} problem(s) in {n} event(s)" if errs
             else f"valid ({n} events)"))
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
