"""Flight recorder for the serving core: low-overhead tracing
(:mod:`repro.obs.trace`), a metrics registry with Prometheus/JSONL
exposition (:mod:`repro.obs.metrics`), and Chrome-trace-event timeline
export for Perfetto (:mod:`repro.obs.timeline`).

Everything here is **host-side bookkeeping**: recording reads only
values the engine already materialises per tick (perf_counter stamps,
the transport's virtual-clock floats, host ints from the sanctioned
return-link syncs) and is gated behind ``EngineConfig(trace=...)`` so
the hot path pays nothing when tracing is off.  The ``obs-hot-path``
repro-audit rule enforces that no recording call ever runs inside a
tick-jit body or touches a traced value.
"""

from repro.obs.metrics import Metrics
from repro.obs.trace import Event, TraceRecorder
from repro.obs.timeline import (chrome_trace_events, validate_chrome_trace,
                                write_chrome_trace)

__all__ = ["TraceRecorder", "Event", "Metrics", "chrome_trace_events",
           "write_chrome_trace", "validate_chrome_trace"]
